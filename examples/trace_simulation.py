"""Paper-scale trace experiment: OServe vs every baseline on a calibrated
synthetic Azure-like trace (the Fig. 9-11 reproduction, one command).

    PYTHONPATH=src python examples/trace_simulation.py --trace 2 --spans 30
"""
import argparse

from benchmarks.common import Bench
from repro.serving.baselines import (DynamoPolicy, LlumnixPolicy,
                                     OServePolicy, RoundRobinPolicy,
                                     VLLMReloadPolicy, VLLMStaticPolicy)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="opt-30b")
    ap.add_argument("--chips", type=int, default=16)
    ap.add_argument("--spans", type=int, default=30)
    ap.add_argument("--trace", type=int, default=2)
    ap.add_argument("--hw", choices=["h100", "tpu"], default="h100")
    args = ap.parse_args()

    print(f"calibrating {args.model} on {args.chips} x {args.hw} ...")
    bench = Bench(args.model, args.chips, args.spans, args.trace, hw=args.hw)
    print(f"trace: {len(bench.requests)} requests over {args.spans} spans "
          f"(~{bench.rate:.0f}/span)")
    cm, cl, arch, avg = (bench.cm, bench.cluster, bench.archetypes,
                         bench.avg_rates)
    policies = {
        "oserve": OServePolicy(cm, cl, arch),
        "oserve(naive-reload)": OServePolicy(cm, cl, arch, naive_reload=True),
        "vllm-static": VLLMStaticPolicy(cm, cl, arch, avg),
        "vllm-reload": VLLMReloadPolicy(cm, cl, arch),
        "llumnix": LlumnixPolicy(cm, cl, arch, avg),
        "round-robin": RoundRobinPolicy(cm, cl, arch, avg),
        "dynamo": DynamoPolicy(cm, cl, arch, avg),
    }
    print(f"{'policy':22s} {'p99':>8s} {'avg':>8s} {'thr':>7s} "
          f"{'drops':>6s} {'switches':>8s}")
    for name, pol in policies.items():
        res, m = bench.run(pol)
        print(f"{name:22s} {m.get('p99', float('nan')):7.1f}s "
              f"{m.get('avg_latency', float('nan')):7.1f}s "
              f"{m['throughput_rps']:6.2f} {m['dropped']:6d} "
              f"{res.switch_spans:8d}")


if __name__ == "__main__":
    main()
