"""OServe end-to-end: predictor -> scheduler -> switch planner -> cluster.

Default mode drives the full control loop over a fluctuating trace at paper
scale (via the calibrated discrete-event cluster) and prints per-span
decisions: predicted rates, chosen heterogeneous deployment, workload
assignment, and switch cost (ad hoc vs naive reload).

    PYTHONPATH=src python examples/serve_orchestrated.py [--spans 12]

``--real`` executes the same orchestrator's plans on *real* JAX engines via
``ClusterRuntime`` (smoke-scale model so it runs on CPU): heterogeneous
replicas partition one device KV pool, typed requests route through the
plan's fractions, deployment switches drain/migrate live requests, and each
span reports predicted vs achieved per-replica traffic shares — the
simulator's predictions validated against actual engine behavior.

    PYTHONPATH=src python examples/serve_orchestrated.py --real --spans 2

``--trace out.json`` (with ``--real``) additionally records the full
request-lifecycle telemetry and writes a Chrome-trace-event JSON loadable
in Perfetto / ``chrome://tracing``: one track per replica, per-request
residency slices with flow arrows across migrations, switch phases on the
orchestrator track.  The exported file is validated in-process (the same
checks ``python -m repro.serving.telemetry`` runs) and a latency-histogram
summary plus the planner's prediction calibration error are printed.
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core.costmodel import CostModel
from repro.core.orchestrator import Orchestrator
from repro.core.predictor import LSTMWorkloadPredictor, WorkloadClusterer, count_series
from repro.core.types import ClusterSpec, H100_SPEC, WorkloadType
from repro.serving.request import span_of, synthesize_trace


def run_analytic(args) -> None:
    cfg = get_config(args.model)
    cm = CostModel(cfg.profile(), hw=H100_SPEC)
    cluster = ClusterSpec(args.chips, hw=H100_SPEC)
    orch = Orchestrator(cm, cluster)

    reqs = synthesize_trace(args.spans, 800, trace_id=2, seed=0)
    il = np.array([r.in_len for r in reqs])
    ol = np.array([r.out_len for r in reqs])
    clusterer, labels = WorkloadClusterer.fit(il, ol, k=4, seed=0)
    archetypes = [WorkloadType(int(c[0]), int(c[1]))
                  for c in clusterer.raw_centroids]
    counts = count_series(labels, np.array([span_of(r) for r in reqs]),
                          4, args.spans)

    # small LSTM warm-started on the first spans (window shrunk to fit demo)
    window = max(2, args.spans // 4)
    lstm = LSTMWorkloadPredictor(4, window=window, hidden=8, seed=0)
    lstm.fit(counts[: max(window + 2, args.spans // 2)] + 1.0, epochs=40)

    print(f"{args.model} on {args.chips} x H100 | "
          f"types: {[(w.in_len, w.out_len) for w in archetypes]}")
    for s in range(args.spans):
        pred = (lstm.predict(counts[:s + 1]) if s >= window else counts[s])
        ws = [a.with_rate(float(r)) for a, r in zip(archetypes, pred)]
        plan = orch.plan_span(ws)
        frac = np.array(plan.fractions)
        dominant = [int(np.argmax(frac[:, j])) if frac[:, j].sum() > 0 else -1
                    for j in range(4)]
        switch = (f"switch {plan.switch_seconds:.2f}s "
                  f"(reload would be {plan.reload_seconds:.0f}s)"
                  if plan.changed_replicas else "no switch")
        print(f"span {s:2d} | pred={np.round(pred).astype(int)} | "
              f"{plan.deployment} | type->replica {dominant} | {switch} | "
              f"search {plan.search_time:.2f}s")

    # fault tolerance: lose 4 chips, re-plan on survivors
    ws = [a.with_rate(float(r)) for a, r in zip(archetypes, counts[-1])]
    plan = orch.on_cluster_change(args.chips - 4, ws)
    print(f"FAILURE of 4 chips -> re-planned {plan.deployment} "
          f"on {args.chips - 4} chips, switch {plan.switch_seconds:.2f}s")


def run_real(args) -> None:
    from repro.serving.validation import run_real_spans

    telemetry = None
    if args.trace:
        from repro.serving.telemetry import Telemetry
        telemetry = Telemetry()
    outcomes, runtime = run_real_spans(
        model=args.model, chips=args.chips, n_spans=args.spans,
        requests_per_span=args.requests_per_span, seed=args.seed,
        shard=args.shard, telemetry=telemetry, rebalance=args.rebalance,
        disagg=args.disagg)
    mode = "sharded engines" if args.shard else "real engines"
    print(f"{runtime.cfg.name} ({mode}) planning as {args.model} on "
          f"{args.chips} chips")
    for o in outcomes:
        switch, report = o.switch, o.report
        if o.span == 0:
            sw = "initial build"
        elif switch.changed:
            sw = (f"switch: rebuilt {switch.changed}, "
                  f"drained {switch.drained}, migrated {switch.migrated} "
                  f"(handoff {switch.handoff}, copied {switch.copied}, "
                  f"re-prefilled {switch.reprefilled}), "
                  f"requeued {switch.requeued}")
        else:
            sw = "no switch"
        print(f"span {o.span} | {o.plan.deployment} | {sw}")
        print(f"  predicted replica share {np.round(o.predicted_share, 2)} | "
              f"achieved (tokens) {np.round(o.achieved_share, 2)} | "
              f"completed {report.completed}/{o.n_requests} | "
              f"health {np.round(report.achieved_fraction, 2)} | "
              f"observed-rate EWMA {np.round(o.observed_rates, 1)}")
        if args.disagg and report.handoffs:
            ho = report.handoff
            print(f"  disagg: {report.handoffs} prefill->decode handoffs "
                  f"(page-handoff {ho.handoff}, copied {ho.copied}, "
                  f"recompute {ho.recompute_tokens} tokens) | "
                  f"role util {report.role_util}")
        if args.rebalance:
            rb = report.rebalance
            print(f"  rebalance: moved {report.rebalanced} "
                  f"(handoff {rb.handoff}, copied {rb.copied}, "
                  f"re-prefilled {rb.reprefilled}, requeued {rb.requeued}) | "
                  f"preempted {report.preempted}")
        if report.prefix_hit_rate is not None:
            rate = np.round(np.nan_to_num(report.prefix_hit_rate), 2)
            print(f"  prefix cache: hits {report.prefix_hits} / "
                  f"misses {report.prefix_misses} | "
                  f"per-type hit rate {rate} | "
                  f"evicted {report.prefix_evicted_bytes}B / "
                  f"restored {report.prefix_restored_bytes}B")
    stats = runtime.load_stats()
    eff = [s.get("free_blocks_effective") for s in stats]
    if any(e is not None for e in eff):
        print(f"  hit-rate-adjusted free capacity (blocks, incl. cold "
              f"cached pages): {eff}")
    total = args.spans * args.requests_per_span
    done = sum(1 for r in runtime.results.values() if r.done)
    # span 0 is the initial build, not a switch (same convention as
    # bench_e2e's real rows)
    print(f"total completed {done}/{total}; "
          f"switches executed: "
          f"{sum(1 for r in runtime.switch_reports[1:] if r.changed)}")
    assert done == total, "some requests never completed"
    if telemetry is not None:
        from repro.serving.telemetry import (export_chrome_trace,
                                             validate_chrome_trace)
        obj = export_chrome_trace(telemetry, path=args.trace)
        counts = validate_chrome_trace(obj)
        print(f"\ntrace written to {args.trace}: {counts['events']} events, "
              f"{counts['tracks']} tracks, {counts['slices']} slices, "
              f"{counts['flows']} migration flows "
              f"(load in Perfetto / chrome://tracing)")
        print(telemetry.metrics.summary_table())
        calib = telemetry.audit.calibration_error()
        if calib is not None:
            print(f"planner calibration error (mean L1, predicted vs "
                  f"realized replica token share): {calib:.3f} over "
                  f"{sum(1 for r in telemetry.audit.records if r.joined)} "
                  f"joined decisions")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spans", type=int, default=12)
    ap.add_argument("--chips", type=int, default=16)
    ap.add_argument("--model", default="opt-30b")
    ap.add_argument("--real", action="store_true",
                    help="execute plans on real engines (smoke-scale model)")
    ap.add_argument("--shard", action="store_true",
                    help="with --real: execute each replica's (tp, pp) on a "
                         "per-replica device sub-mesh (needs >= --chips jax "
                         "devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--rebalance", action="store_true",
                    help="with --real: enable the live rebalancer (watchdog "
                         "straggler drains, hot-spot relief, priority "
                         "preemption) and print per-span move counters")
    ap.add_argument("--disagg", action="store_true",
                    help="with --real: let the planner split replicas into "
                         "prefill/decode roles; first-token-ready contexts "
                         "hand off to decode replicas (zero recompute)")
    ap.add_argument("--requests-per-span", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="with --real: record lifecycle telemetry and write "
                         "a Chrome-trace-event JSON (Perfetto-loadable)")
    args = ap.parse_args(argv)
    if args.real:
        run_real(args)
    else:
        run_analytic(args)


if __name__ == "__main__":
    main()
