"""End-to-end training driver: ~100M-parameter model, few hundred steps.

Demonstrates the full train substrate: synthetic packed data pipeline,
AdamW + cosine schedule + remat + (optional) int8 gradient compression,
async sharded checkpointing, and crash-safe restart (rerun the same command
and it resumes from the last committed step).

    PYTHONPATH=src python examples/train_100m.py --steps 300
    PYTHONPATH=src python examples/train_100m.py --steps 300  # resumes

Defaults are sized for CPU smoke runs; --full-100m builds the real ~100M
config (slow on CPU, the intended shape for a single TPU host).
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig, packed_batches
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def build_config(full: bool):
    base = get_config("yi-9b")
    if full:
        # ~100M params: 12L, d=768, vocab 32k
        return dataclasses.replace(
            base, name="yi-100m", n_layers=12, d_model=768, n_q_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000,
            max_seq_len=1024)
    return dataclasses.replace(
        base, name="yi-20m", n_layers=4, d_model=256, n_q_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8_000,
        max_seq_len=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    cfg = build_config(args.full_100m)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
        remat=True, microbatches=2,
        grad_compression=args.grad_compression)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, seed=0)
    trainer = Trainer(cfg, tcfg, iter(packed_batches(dc)),
                      checkpoint_dir=args.ckpt, checkpoint_every=50)
    if trainer.step:
        print(f"resumed from checkpoint at step {trainer.step}")
    history = trainer.run(args.steps - trainer.step, log_every=10)
    for h in history:
        print(f"step {h['step']:4d} nll={h['nll']:.3f} "
              f"acc={h['accuracy']:.3f} gnorm={h['grad_norm']:.2f} "
              f"lr={h['lr']:.2e} wall={h['wall']:.0f}s")
    if history:
        first, last = history[0], history[-1]
        print(f"loss {first['nll']:.3f} -> {last['nll']:.3f} "
              f"over {last['step'] - first['step']} steps")


if __name__ == "__main__":
    main()
