"""Quickstart: serve a small model with the continuous-batching engine.

Runs entirely on CPU in under a minute:
  1. build a reduced yi-9b-family model,
  2. submit a handful of requests,
  3. watch the engine batch prefills/decodes over the paged KV cache.

    PYTHONPATH=src python examples/quickstart.py [--arch yi-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import init_params, param_count
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"arch={args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    print(f"params: {param_count(params):,}")

    engine = ServingEngine(cfg, params, num_blocks=128, block_size=8,
                           max_seqs=4)
    rng = np.random.RandomState(0)
    for rid in range(args.requests):
        prompt = rng.randint(0, cfg.vocab_size, rng.randint(8, 24))
        engine.submit(rid, prompt.astype(np.int32), max_new_tokens=12)

    t0 = time.time()
    finished = engine.run_to_completion()
    dt = time.time() - t0
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.generated}")
    print(f"{engine.tokens_out} tokens in {dt:.1f}s "
          f"({engine.steps} engine steps, "
          f"{engine.tokens_out / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
