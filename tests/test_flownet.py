"""Max-flow / LP / flow-network unit + property tests."""
import random

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.flownet import (WorkloadFlowNetwork, maxflow_edmonds_karp,
                                maxflow_preflow_push, simplex_maximize)


def random_graph(rng, n_max=10, e_max=25, c_max=20):
    n = rng.randint(2, n_max)
    edges = []
    for _ in range(rng.randint(0, e_max)):
        u, v = rng.randrange(n), rng.randrange(n)
        if u != v:
            edges.append((u, v, rng.randint(0, c_max)))
    return n, edges


def test_preflow_push_matches_edmonds_karp():
    rng = random.Random(1)
    for _ in range(150):
        n, edges = random_graph(rng)
        f1, per = maxflow_preflow_push(n, edges, 0, n - 1)
        f2 = maxflow_edmonds_karp(n, edges, 0, n - 1)
        assert f1 == f2


def test_preflow_push_returns_valid_flow():
    rng = random.Random(2)
    for _ in range(150):
        n, edges = random_graph(rng)
        f, per = maxflow_preflow_push(n, edges, 0, n - 1)
        net = [0] * n
        for (u, v, c), fl in zip(edges, per):
            assert 0 <= fl <= c
            net[u] -= fl
            net[v] += fl
        for v in range(1, n - 1):
            assert net[v] == 0
        assert net[n - 1] == f


def test_simplex_known_solution():
    x, val = simplex_maximize([1, 1], [[1, 0], [0, 1], [1, 1]], [2, 3, 4])
    assert abs(val - 4.0) < 1e-8


def test_simplex_degenerate_ok():
    # degenerate constraints (Bland's rule must not cycle)
    x, val = simplex_maximize([1, 1, 1],
                              [[1, 1, 0], [0, 1, 1], [1, 0, 1],
                               [1, 1, 1]],
                              [1, 1, 1, 1.5])
    assert val <= 1.5 + 1e-9


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 10_000))
def test_lp_feasibility_and_bounds(K, J, seed):
    """Solution respects C1-C3 and is demand/capacity bounded."""
    rng = np.random.RandomState(seed)
    rates = rng.uniform(0, 100, J).tolist()
    n = rng.uniform(0, 80, (K, J))
    n[rng.rand(K, J) < 0.2] = 0.0
    net = WorkloadFlowNetwork(rates, n.tolist())
    sol = net.solve()
    x = np.array(sol.x)
    assert (x >= -1e-6).all()
    # C1
    assert (x.sum(0) <= np.array(rates) + 1e-6).all()
    # C2/C3
    for k in range(K):
        u = sum(x[k][j] / n[k][j] for j in range(J) if n[k][j] > 0)
        assert u <= 1.0 + 1e-6
        for j in range(J):
            if n[k][j] == 0:
                assert x[k][j] <= 1e-9
    assert sol.throughput <= sum(rates) + 1e-6


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 4), st.integers(1, 4), st.integers(0, 10_000))
def test_balance_preserves_totals_and_reduces_max_util(K, J, seed):
    rng = np.random.RandomState(seed)
    rates = rng.uniform(10, 100, J).tolist()
    n = rng.uniform(10, 80, (K, J))
    net = WorkloadFlowNetwork(rates, n.tolist())
    sol = net.solve()
    bal = net.balance(sol)
    assert abs(bal.throughput - sol.throughput) < 1e-4 * max(sol.throughput, 1)
    assert max(bal.utilization) <= max(sol.utilization) + 1e-6
    # per-type totals preserved
    for j in range(J):
        t0 = sum(sol.x[k][j] for k in range(K))
        t1 = sum(bal.x[k][j] for k in range(K))
        assert abs(t0 - t1) < 1e-4 * max(t0, 1.0)


def test_unit_uniform_uses_preflow_push():
    # one workload type -> exact standard max-flow instance
    net = WorkloadFlowNetwork([100.0], [[30.0], [50.0]])
    sol = net.solve()
    assert sol.solver == "preflow_push"
    assert abs(sol.throughput - 80.0) < 1e-9


def test_lcm_normalization():
    net = WorkloadFlowNetwork([10, 10], [[80, 50], [40, 40]])
    assert net.M[0] == 400
    assert net.m_units[0] == [5, 8]
    assert net.M[1] == 40


def test_appendix_d_example():
    """Paper Appendix D case 3: 150 requests complete by ~13.67s."""
    horizon = 13.67
    net = WorkloadFlowNetwork(
        [100.0, 50.0],
        [[10 * horizon, 5 * horizon],
         [5 * horizon, 3 * horizon],
         [5 * horizon, 3 * horizon]])
    sol = net.solve()
    assert sol.throughput >= 149.9
