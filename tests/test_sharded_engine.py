"""Real tensor/pipeline sharding inside serving replicas.

Greedy token parity of the sharded ``ServingEngine`` (params via
``param_pspecs``, paged pools head-sharded via ``pool_pspecs``, jits traced
under the serve plan's logical-axis rules) against the unsharded engine,
for tp=2, pp=2, and a 2-replica heterogeneous ``ClusterRuntime`` span with
a mid-span deployment switch that reshards in-flight KV pages between
per-replica meshes (``kvcache.reshard_blocks`` — zero tokens recomputed).

Each sharded test spawns a subprocess so XLA_FLAGS installs 8 simulated
host devices before jax initializes, without polluting the main test
process (smoke tests must keep seeing 1 device); the ``sharded`` marker
lets CI run them in a dedicated multi-device job while the single-device
job deselects them.  The ``pad_heads`` unit tests at the bottom are plain
in-process tests.
"""
import dataclasses
import os
import subprocess
import sys

import pytest

from repro.configs import get_smoke_config
from repro.launch import sharding as shd


def _run_subprocess(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=1200,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.mesh import make_replica_mesh
from repro.launch.sharding import make_plan, pool_pspecs
from repro.models import init_params
from repro.serving.engine import ServingEngine

assert len(jax.devices()) == 8
cfg = get_smoke_config("yi-9b")        # 2 layers, 4 q heads / 2 kv heads
params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.RandomState(0)
jobs = [(rng.randint(0, cfg.vocab_size, n).astype(np.int32), new)
        for n, new in ((8, 7), (8, 9), (12, 6), (12, 8))]


def run(mesh=None, plan=None, **kw):
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8,
                        max_seqs=4, mesh=mesh, shard_plan=plan, **kw)
    for i, (p, n) in enumerate(jobs):
        eng.submit(i, p, n)
    return eng, {r.rid: r.generated for r in eng.run_to_completion()}


_, ref = run()
for tp, pp in ((2, 1), (1, 2), (2, 2)):
    mesh = make_replica_mesh(jax.devices()[: tp * pp], tp, pp)
    plan, run_cfg = make_plan(cfg, "serve", False, 1, tp=tp, pp=pp)
    assert run_cfg is cfg               # heads divide: no padding needed
    eng, got = run(mesh=mesh, plan=plan)
    assert got == ref, f"tp={tp} pp={pp} diverged from the unsharded engine"
    # the pool is REALLY sharded, not silently replicated (shard shapes,
    # not spec equality: XLA trims trailing Nones off round-tripped specs)
    assert pool_pspecs(cfg, plan) is not None
    shard_shape = eng.cache.k.addressable_shards[0].data.shape
    full = eng.cache.k.shape
    assert shard_shape[0] == full[0] // pp      # layers over pipe
    assert shard_shape[2] == full[2] // tp      # KV heads over model
    w = eng.params["blocks"]["attn"]["wq"]
    assert w.addressable_shards[0].data.shape[-1] == w.shape[-1] // tp

# horizon decode loop and chunked prefill keep parity under sharding too
mesh = make_replica_mesh(jax.devices()[:2], 2, 1)
plan, _ = make_plan(cfg, "serve", False, 1, tp=2)
assert run(mesh=mesh, plan=plan, decode_horizon=4)[1] == ref
assert run(mesh=mesh, plan=plan, prefill_chunk_tokens=4)[1] == ref

# head-padded MHA replica (attn 'pad' mode: 2 -> 4 heads at tp=4) matches
# the unpadded unsharded engine — the padding is function-preserving
import dataclasses
from repro.launch.sharding import pad_attention_params
mha = dataclasses.replace(cfg, n_q_heads=2, n_kv_heads=2, head_dim=32,
                          attn_sharding="pad")
mparams = init_params(mha, jax.random.PRNGKey(0), jnp.float32)
mjobs = [(rng.randint(0, mha.vocab_size, 8).astype(np.int32), 6)
         for _ in range(2)]
def run_mha(mesh=None, plan=None, run_cfg=None, p=None):
    eng = ServingEngine(run_cfg or mha, p if p is not None else mparams,
                        num_blocks=64, block_size=8, max_seqs=2,
                        mesh=mesh, shard_plan=plan)
    for i, (pr, n) in enumerate(mjobs):
        eng.submit(i, pr, n)
    return {r.rid: r.generated for r in eng.run_to_completion()}
mref = run_mha()
plan, run_cfg = make_plan(mha, "serve", False, 1, tp=4)
assert plan.attn_mode == "pad" and run_cfg.n_q_heads == 4
padded = pad_attention_params(mparams, mha, run_cfg)
got = run_mha(make_replica_mesh(jax.devices()[:4], 4, 1), plan,
              run_cfg, padded)
assert got == mref, "padded-head sharded engine diverged"
print("PARITY_OK")
"""


CLUSTER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.types import Deployment, ReplicaConfig
from repro.models import init_params
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import ServingEngine
from repro.serving.router import FlowRouter


class PlanStub:
    def __init__(self, rcs, fractions):
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions


cfg = get_smoke_config("yi-9b")
params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.RandomState(0)
jobs = {i: (rng.randint(0, cfg.vocab_size,
                        8 + 4 * (i % 2)).astype(np.int32), 7 + i % 3)
        for i in range(6)}

rt = ClusterRuntime(cfg, params, total_chips=8, blocks_per_chip=16,
                    seqs_per_chip=2, block_size=8, drain_steps=0,
                    router=FlowRouter([[0.5], [0.5]]), shard=True)
# span 1: heterogeneous (tp=2) + (tp=1); each replica on its own sub-mesh
rt.apply_plan(PlanStub([ReplicaConfig(2, 1), ReplicaConfig(1, 1)],
                       [[0.5], [0.5]]))
meshes = [h.engine._mesh for h in rt.replicas]
assert meshes[0].devices.size == 2 and meshes[1].devices.size == 1
assert not set(meshes[0].devices.flat) & set(meshes[1].devices.flat)
for i in range(6):
    rt.submit(i, *jobs[i])
for _ in range(3):
    rt.step()                       # leave every request in flight

# span 2: the switch reshapes BOTH replicas (and their device slices);
# drain_steps=0 forces every in-flight sequence through migration
sw = rt.apply_plan(PlanStub([ReplicaConfig(1, 1), ReplicaConfig(2, 2)],
                            [[0.25], [0.75]]))
assert sw.changed == [0, 1]
assert sw.migrated >= 3, sw
# per-replica pools on different meshes: pages moved by the reshard path,
# never recomputed
assert sw.copied >= 3 and sw.reprefilled == 0, sw
assert sw.recompute_tokens == 0
assert sw.pages_copied > 0
rt.run_until_idle()
assert len(rt.results) == 6
# every prompt went through prefill exactly once, cluster-wide (a queued
# never-prefilled request pays its FIRST prefill after the switch)
assert rt.total_prefill_tokens == sum(len(p) for p, _ in jobs.values())

ref = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
for i, (p, n) in jobs.items():
    ref.submit(i, p, n)
expected = {r.rid: r.generated for r in ref.run_to_completion()}
for i in range(6):
    assert rt.results[i].generated == expected[i], f"rid {i} diverged"
print("CLUSTER_OK")
"""


@pytest.mark.sharded
def test_sharded_engine_token_parity_tp_pp():
    assert "PARITY_OK" in _run_subprocess(PARITY_SCRIPT)


@pytest.mark.sharded
def test_sharded_cluster_switch_reshards_kv_pages():
    assert "CLUSTER_OK" in _run_subprocess(CLUSTER_SCRIPT)


# ---------------------------------------------------------------------------
# pad_heads: degrade gracefully (None) instead of padding past the 4x bound.
# ---------------------------------------------------------------------------


def test_pad_heads_returns_none_when_tp_exceeds_padded_heads():
    cfg = dataclasses.replace(get_smoke_config("yi-9b"),
                              n_q_heads=4, n_kv_heads=4)   # MHA
    assert shd.pad_heads(cfg, 4) == (4, 4)
    assert shd.pad_heads(cfg, 16) == (16, 16)              # 4x: still legal
    assert shd.pad_heads(cfg, 32) is None                  # 8x: too far
    # downstream callers degrade instead of asserting/over-padding
    assert shd.resolve_attn_mode(cfg, 32) == "replicate"
    assert shd.padded_config(cfg, 32) is cfg
    plan, run_cfg = shd.make_plan(cfg, "serve", False, 1, tp=32)
    assert plan.attn_mode == "replicate" and run_cfg is cfg
    assert plan.rules["heads"] is None and plan.rules["kv_heads"] is None


def test_pad_heads_gqa_preserving_bound():
    cfg = dataclasses.replace(get_smoke_config("yi-9b"),
                              n_q_heads=6, n_kv_heads=2)   # GQA, g=3
    qp, kvp = shd.pad_heads(cfg, 4)
    assert kvp == 2 and qp % 4 == 0 and 6 <= qp <= 24
    # GQA honors the same 4x bound as MHA: kv*gp % 25 == 0 needs qp=50,
    # which is > 4 * 8 — degrade to None instead of over-padding
    cfg = dataclasses.replace(cfg, n_q_heads=8, n_kv_heads=2)
    assert shd.pad_heads(cfg, 25) is None


def test_explicit_pad_mode_degrades_to_replicate():
    """attn_sharding='pad' (the hillclimb override) must not produce a plan
    that shards UNPADDED heads when no preserving padding exists."""
    cfg = dataclasses.replace(get_smoke_config("yi-9b"),
                              n_q_heads=2, n_kv_heads=2,
                              attn_sharding="pad")
    assert shd.pad_heads(cfg, 16) is None                  # 16 > 4 * 2
    assert shd.resolve_attn_mode(cfg, 16) == "replicate"
    plan, run_cfg = shd.make_plan(cfg, "serve", False, 1, tp=16)
    assert plan.attn_mode == "replicate" and run_cfg is cfg
    assert plan.rules["heads"] is None
    # when a preserving padding DOES exist, explicit pad still pads
    assert shd.resolve_attn_mode(cfg, 4) == "pad"
    assert shd.padded_config(cfg, 4).n_q_heads == 4
