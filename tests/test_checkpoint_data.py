"""Checkpoint save/restore/restart + data pipeline tests."""
import os

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticCorpus, packed_batches
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import TrainConfig, Trainer, init_train_state


def tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.allclose(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("yi-9b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(state, 7, blocking=True)
    restored, step = mgr.restore_latest(state)
    assert step == 7
    assert tree_equal(state, restored)


def test_checkpoint_gc_keeps_latest(tmp_path):
    cfg = get_smoke_config("yi-9b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(state, s, blocking=True)
    assert mgr.list_steps() == [3, 4]


def test_checkpoint_ignores_uncommitted(tmp_path):
    cfg = get_smoke_config("yi-9b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(state, 1, blocking=True)
    # fake a crashed save
    os.makedirs(tmp_path / "step_000000099", exist_ok=True)
    assert mgr.list_steps() == [1]


def test_trainer_restart_resumes(tmp_path):
    cfg = get_smoke_config("yi-9b")
    tcfg = TrainConfig(remat=False)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    tr = Trainer(cfg, tcfg, iter(packed_batches(dc)),
                 checkpoint_dir=str(tmp_path), checkpoint_every=5)
    tr.run(6, log_every=100)
    tr2 = Trainer(cfg, tcfg, iter(packed_batches(dc)),
                  checkpoint_dir=str(tmp_path))
    assert tr2.step == 6
    assert tree_equal(tr.state["params"], tr2.state["params"])


def test_data_pipeline_shapes_and_determinism():
    dc = DataConfig(vocab_size=1000, seq_len=64, batch_size=4, seed=3)
    a = next(packed_batches(dc))
    b = next(packed_batches(dc))
    assert a["tokens"].shape == (4, 64)
    assert a["labels"].shape == (4, 64)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    corpus_next = a["tokens"][:, 1:]
    np.testing.assert_array_equal(a["labels"][:, :-1], corpus_next)


def test_corpus_learnable_structure():
    dc = DataConfig(vocab_size=500, seq_len=128, batch_size=1, seed=0)
    corpus = SyntheticCorpus(dc)
    rng = np.random.RandomState(0)
    doc = corpus.doc(rng, 2000)
    # the n-gram machine makes bigrams predictive: conditional entropy of the
    # successor given (a, b) must be far below the unigram entropy
    pairs = {}
    for i in range(len(doc) - 2):
        pairs.setdefault((doc[i], doc[i + 1]), []).append(doc[i + 2])
    repeat = [len(set(v)) == 1 for v in pairs.values() if len(v) > 1]
    assert repeat and np.mean(repeat) > 0.5
