"""Horizon-batched device decode + async dispatch + SLO shedding.

Covers the horizon contract end to end:
  * token parity of the fused multi-step loop vs per-step paged decode,
    greedy AND sampled-with-fixed-key (the per-step key folding makes the
    sampled stream horizon-invariant);
  * horizon truncation at retire / admit / chunked-prefill boundaries and
    the power-of-two compilation bucketing;
  * one device→host transfer per horizon (``decode_syncs``);
  * a mid-horizon deployment switch whose migration still recomputes zero
    prefill tokens;
  * the round-robin chunked-prefill budget (no head-of-line serialization);
  * SLO-aware queue shedding on the engine and its cluster-level reporting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.costmodel import CostModel
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.types import (ClusterSpec, H100_SPEC, ReplicaConfig,
                              WorkloadType)
from repro.models import init_params
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _jobs(cfg, spec, seed=1):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, n).astype(np.int32), new)
            for n, new in spec]


def _run(cfg, params, jobs, horizon, *, greedy=True, max_seqs=2, **kw):
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8,
                        max_seqs=max_seqs, greedy=greedy,
                        decode_horizon=horizon, **kw)
    for i, (p, n) in enumerate(jobs):
        eng.submit(i, p, n)
    return {r.rid: r.generated for r in eng.run_to_completion()}, eng


# ---------------------------------------------------------------------------
# Token parity: the fused horizon loop is invisible in the token stream.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("horizon", [2, 8, 16])
def test_horizon_matches_per_step_greedy(cfg_params, horizon):
    """Mixed lengths + staggered retirement: every horizon size produces
    exactly the per-step token stream under greedy decoding."""
    cfg, params = cfg_params
    jobs = _jobs(cfg, ((8, 9), (8, 17), (12, 5)))
    got_1, e1 = _run(cfg, params, jobs, 1)
    got_h, eh = _run(cfg, params, jobs, horizon)
    assert got_h == got_1
    # the horizon engine really batched steps: fewer syncs than token-steps
    assert eh.decode_syncs < e1.decode_syncs


def test_horizon_matches_per_step_sampled_fixed_key(cfg_params):
    """Per-step key folding (sampling.step_key) makes the SAMPLED stream
    horizon-invariant too: decode step t draws fold_in(key, t) whether it
    runs alone or inside a fused horizon."""
    cfg, params = cfg_params
    jobs = _jobs(cfg, ((8, 9), (8, 17), (12, 5)))
    got_1, _ = _run(cfg, params, jobs, 1, greedy=False)
    got_8, _ = _run(cfg, params, jobs, 8, greedy=False)
    assert got_8 == got_1


def test_horizon_parity_local_window_arch(cfg_params):
    """gemma2-style local/global alternation through the fused loop."""
    cfg = get_smoke_config("gemma2-2b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    jobs = _jobs(cfg, ((8, 6), (8, 11)), seed=2)
    got_1, _ = _run(cfg, params, jobs, 1)
    got_8, _ = _run(cfg, params, jobs, 8)
    assert got_8 == got_1


def test_horizon_parity_ssm_arch():
    """The SSM state row round-trips through the scan carry (mamba2)."""
    cfg = get_smoke_config("mamba2-370m")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    jobs = _jobs(cfg, ((8, 6), (8, 11)), seed=2)
    got_1, _ = _run(cfg, params, jobs, 1)
    got_8, _ = _run(cfg, params, jobs, 8)
    assert got_8 == got_1


# ---------------------------------------------------------------------------
# Horizon scheduling: truncation at retire / admit / chunk boundaries.
# ---------------------------------------------------------------------------


def test_horizon_truncates_at_retire_boundary(cfg_params):
    """min remaining max_new_tokens bounds the horizon (pow2-floored), so a
    sequence never overshoots its budget mid-horizon."""
    cfg, params = cfg_params
    jobs = _jobs(cfg, ((8, 4), (8, 20)))      # retire at token 4 vs 20
    got, eng = _run(cfg, params, jobs, 16, max_seqs=2)
    assert {r: len(g) for r, g in got.items()} == {0: 4, 1: 20}
    # first dispatch: both seqs active, min remaining = 3 (prefill emitted
    # token 1) -> pow2 floor 2; never a horizon beyond the remaining budget
    hist = eng.horizon_counts
    assert max(hist) <= 16
    assert eng.last_horizon >= 1
    # all dispatched horizons are powers of two (compile-count bound)
    assert all(h & (h - 1) == 0 for h in hist)
    # the long tail after seq 0 retired ran real multi-step horizons
    assert max(hist) >= 8


def test_horizon_collapses_on_admission(cfg_params):
    """A step that admits a prompt dispatches horizon 1, so the admitted
    sequence joins the decode batch on the very next step (no TPOT cliff
    for late arrivals)."""
    cfg, params = cfg_params

    def drive(horizon):
        eng = ServingEngine(cfg, params, num_blocks=64, block_size=8,
                            max_seqs=4, decode_horizon=horizon)
        rng = np.random.RandomState(3)
        done = []
        eng.submit(0, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 12)
        done += eng.step()                     # prefill request 0
        done += eng.step()                     # pure decode
        h_decode = eng.last_horizon
        eng.submit(1, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 12)
        done += eng.step()                     # admits rid 1
        h_admit = eng.last_horizon
        done += eng.run_to_completion()
        return ({r.rid: r.generated for r in done}, h_decode, h_admit)

    got_h, h_decode, h_admit = drive(8)
    assert h_decode > 1                        # pure-decode step batched
    assert h_admit == 1                        # admit step collapsed it
    got_1, _, _ = drive(1)
    assert got_h == got_1


def test_horizon_collapses_during_chunked_prefill(cfg_params):
    """While a long prompt streams in chunk by chunk, decode must keep
    emitting one token per step (the Sarathi property), so the horizon
    pins to 1 until the prefill completes."""
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=128, block_size=8,
                        max_seqs=2, decode_horizon=8,
                        prefill_chunk_tokens=8)
    rng = np.random.RandomState(4)
    done = []
    eng.submit(0, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 24)
    done += eng.step()                         # one-shot prefill rid 0
    eng.submit(1, rng.randint(0, cfg.vocab_size, 32).astype(np.int32), 4)
    saw_chunk_step = False
    while any(r.prefilling for r in eng.active.values()) or eng.waiting:
        t0 = {s: len(r.generated) for s, r in eng.active.items()
              if not r.prefilling}
        done += eng.step()
        assert eng.last_horizon == 1          # chunk in flight: per-step
        for s, n in t0.items():
            if s in eng.active:
                assert len(eng.active[s].generated) == n + 1
        saw_chunk_step = True
    assert saw_chunk_step
    done += eng.step()
    assert eng.last_horizon > 1               # prefill done: horizon reopens
    done += eng.run_to_completion()
    got = {r.rid: r.generated for r in done}

    ref = ServingEngine(cfg, params, num_blocks=128, block_size=8,
                        max_seqs=2, prefill_chunk_tokens=8)
    rng = np.random.RandomState(4)
    ref.submit(0, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 24)
    ref.step()
    ref.submit(1, rng.randint(0, cfg.vocab_size, 32).astype(np.int32), 4)
    expected = {r.rid: r.generated for r in ref.run_to_completion()}
    assert got == expected


def test_one_transfer_per_horizon(cfg_params):
    """decode_syncs counts device→host transfers: one per horizon, not one
    per token — H=8 needs ~8x fewer syncs than H=1 on a long generation."""
    cfg, params = cfg_params
    jobs = _jobs(cfg, ((8, 33), (8, 33)))
    _, e1 = _run(cfg, params, jobs, 1)
    _, e8 = _run(cfg, params, jobs, 8)
    # 32 decode token-steps: H=1 -> 32 syncs; H=8 -> 8,8,8,8 = 4 syncs
    assert e1.decode_syncs == 32
    assert e8.decode_syncs == 4
    assert e8.horizon_counts == {8: 4}


# ---------------------------------------------------------------------------
# Mid-horizon deployment switch: still zero recompute, still token-exact.
# ---------------------------------------------------------------------------


def test_mid_horizon_switch_zero_recompute(cfg_params):
    """A deployment switch landing between horizon dispatches (sequences
    mid-generation, host/device lens advanced by whole horizons) migrates
    by page handoff: zero prefill tokens recomputed, tokens identical to
    an uninterrupted engine."""
    cfg, params = cfg_params
    cm = CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)
    orch = Orchestrator(cm, ClusterSpec(6, hw=H100_SPEC),
                        OrchestratorConfig(search_patience=10))
    arch = [WorkloadType(1275, 287), WorkloadType(139, 133),
            WorkloadType(1181, 1824), WorkloadType(282, 1121)]
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=16,
                        seqs_per_chip=1, block_size=8, drain_steps=0,
                        decode_horizon=4)
    rng = np.random.RandomState(0)
    jobs = {}
    rid = 0
    prompt_tokens = 0
    for rates in ([5, 300, 2, 3], [40, 10, 60, 40]):
        plan = orch.plan_span([a.with_rate(float(r))
                               for a, r in zip(arch, rates)])
        rt.apply_plan(plan)
        for i in range(6):
            t = int(rng.randint(0, 4))
            prompt = rng.randint(0, cfg.vocab_size, 6 + 2 * t).astype(np.int32)
            jobs[rid] = (prompt, 8 + t)
            rt.submit(rid, prompt, 8 + t, type_id=t)
            prompt_tokens += len(prompt)
            rid += 1
        for _ in range(4):
            rt.step()
        rt.finish_span()
    rt.run_until_idle()

    assert len(rt.results) == rid
    # zero-recompute: cluster-wide prefill forwards == admitted prompt tokens
    assert rt.total_prefill_tokens == prompt_tokens
    ref = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
    for r, (prompt, n) in jobs.items():
        ref.submit(r, prompt, n)
    expected = {r.rid: r.generated for r in ref.run_to_completion()}
    for r in range(rid):
        assert rt.results[r].generated == expected[r], f"rid {r} diverged"


# ---------------------------------------------------------------------------
# Round-robin chunked prefill: no head-of-line serialization.
# ---------------------------------------------------------------------------


def test_chunked_prefill_round_robin_no_hol(cfg_params):
    """Two long prompts admitted together both make progress every step —
    the per-step chunk budget is split across them instead of dedicating
    it all to the oldest."""
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=128, block_size=8,
                        max_seqs=2, prefill_chunk_tokens=16)
    rng = np.random.RandomState(5)
    p0 = rng.randint(0, cfg.vocab_size, 64).astype(np.int32)
    p1 = rng.randint(0, cfg.vocab_size, 64).astype(np.int32)
    eng.submit(0, p0, 3)
    eng.submit(1, p1, 3)
    eng.step()
    by_rid = {r.rid: r for r in eng.active.values()}
    # after one step BOTH are mid-prefill and BOTH advanced (old behavior:
    # rid 0 got the whole budget, rid 1 sat at 0)
    assert 0 < by_rid[0].prefill_pos < 64
    assert 0 < by_rid[1].prefill_pos < 64
    done = []
    while any(r.prefilling for r in eng.active.values()):
        done += eng.step()
        pos = sorted(r.prefill_pos for r in eng.active.values())
        assert pos[-1] - pos[0] <= eng.prefill_chunk_tokens, (
            "round-robin budget drifted into head-of-line behavior")
    got = {r.rid: r.generated for r in done + eng.run_to_completion()}

    # parity: chunk scheduling must not change the tokens
    ref = ServingEngine(cfg, params, num_blocks=128, block_size=8, max_seqs=2)
    ref.submit(0, p0, 3)
    ref.submit(1, p1, 3)
    expected = {r.rid: r.generated for r in ref.run_to_completion()}
    assert got == expected


# ---------------------------------------------------------------------------
# SLO-aware queue shedding.
# ---------------------------------------------------------------------------


def test_engine_sheds_blown_ttft_before_prefill(cfg_params):
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2)
    now = [0.0]
    eng.clock = lambda: now[0]
    rng = np.random.RandomState(6)
    eng.submit(0, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 4,
               ttft_deadline=10.0)
    eng.submit(1, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 4,
               ttft_deadline=0.5)
    now[0] = 1.0                       # rid 1's TTFT budget is already blown
    finished = eng.run_to_completion()
    assert sorted(r.rid for r in finished) == [0]
    assert eng.shed_rids == [1]
    assert eng.load_stats()["shed"] == 1
    assert eng.prefill_tokens == 8     # the shed request never prefilled
    assert eng.cache.allocator.n_free == 64


def test_cluster_reports_shed_in_span(cfg_params):
    cfg, params = cfg_params
    rt = ClusterRuntime(cfg, params, total_chips=2, blocks_per_chip=32,
                        seqs_per_chip=2, block_size=8)

    class _Plan:
        deployment = type("D", (), {"replicas": [ReplicaConfig(2)]})()
        fractions = [[1.0]]

    rt.apply_plan(_Plan())
    now = [0.0]
    rt.replicas[0].engine.clock = lambda: now[0]
    rng = np.random.RandomState(7)
    rt.submit(0, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 4,
              ttft_deadline=0.25)
    now[0] = 1.0
    rt.run_until_idle()
    report = rt.finish_span()
    assert report.shed == 1
    assert rt.total_shed == 1
    assert rt.load_stats()[0]["shed"] == 1
    # the next span starts from a clean mark
    assert rt.finish_span().shed == 0
