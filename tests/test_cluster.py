"""ClusterRuntime: orchestrator plans executed on real engines.

Covers the acceptance path (Orchestrator -> ClusterRuntime, heterogeneous
replicas, an executed deployment switch, token parity with an uninterrupted
engine), the replica lifecycle API (drain / export / import), the shared
block pool, the unified router interface, submit validation, and the
observe_health / observe_rates feedback loops.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.costmodel import CostModel
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.types import (ClusterSpec, Deployment, H100_SPEC,
                              ReplicaConfig, WorkloadType)
from repro.models import init_params
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import BlockPool, PagedKVCache
from repro.serving.router import (FlowRouter, LeastLoadedRouter,
                                  RoundRobinRouter)

ARCH = [WorkloadType(1275, 287), WorkloadType(139, 133),
        WorkloadType(1181, 1824), WorkloadType(282, 1121)]


def ws(rates):
    return [a.with_rate(float(r)) for a, r in zip(ARCH, rates)]


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _orchestrator(chips: int) -> Orchestrator:
    cm = CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)
    return Orchestrator(cm, ClusterSpec(chips, hw=H100_SPEC),
                        OrchestratorConfig(search_patience=10))


# ---------------------------------------------------------------------------
# Acceptance: 2 spans through Orchestrator -> ClusterRuntime, heterogeneous
# replicas, >=1 executed switch, token parity with an uninterrupted engine.
# ---------------------------------------------------------------------------


def test_cluster_e2e_orchestrated_switch_token_parity(cfg_params):
    cfg, params = cfg_params
    orch = _orchestrator(6)
    # drain_steps=0: everything in flight at the switch must migrate
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=16,
                        seqs_per_chip=1, block_size=8, drain_steps=0)
    rng = np.random.RandomState(0)
    jobs = {}
    rid = 0
    deployments = []
    reports = []
    # span 0 favors short tasks; span 1 flips to long-output types
    for rates in ([5, 300, 2, 3], [40, 10, 60, 40]):
        plan = orch.plan_span(ws(rates))
        deployments.append(plan.deployment)
        reports.append(rt.apply_plan(plan))
        for i in range(6):
            t = int(rng.randint(0, 4))
            prompt = rng.randint(0, cfg.vocab_size, 6 + 2 * t).astype(np.int32)
            jobs[rid] = (prompt, 8 + t)
            rt.submit(rid, prompt, 8 + t, type_id=t)
            rid += 1
        for _ in range(4):        # partial progress: in flight at span end
            rt.step()
        rt.finish_span()
    rt.run_until_idle()

    # the switch actually happened, onto a heterogeneous deployment
    assert deployments[0].replicas != deployments[1].replicas
    assert len(set(deployments[1].replicas)) >= 2, "not heterogeneous"
    switch = reports[1]
    assert switch.changed, "no replica was rebuilt"
    assert switch.migrated >= 1, "no in-flight request was migrated"

    # every request completed with the tokens an uninterrupted single
    # engine produces (greedy, same params)
    assert len(rt.results) == rid
    ref = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
    for r, (prompt, n) in jobs.items():
        ref.submit(r, prompt, n)
    expected = {r.rid: r.generated for r in ref.run_to_completion()}
    for r in range(rid):
        assert rt.results[r].generated == expected[r], f"rid {r} diverged"


# ---------------------------------------------------------------------------
# Engine lifecycle: drain / export / import parity (incl. paged kernel path).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("attn_impl", ["jnp", "kernel"])
def test_engine_drain_export_import_parity(cfg_params, attn_impl):
    cfg, params = cfg_params
    rng = np.random.RandomState(1)
    jobs = [(rng.randint(0, cfg.vocab_size, n).astype(np.int32), new)
            for n, new in ((8, 7), (8, 9), (12, 6))]

    def fresh(max_seqs=4):
        return ServingEngine(cfg, params, num_blocks=64, block_size=8,
                             max_seqs=max_seqs, attn_impl=attn_impl)

    eng = fresh()
    for i, (p, n) in enumerate(jobs):
        eng.submit(i, p, n)
    expected = {r.rid: r.generated for r in eng.run_to_completion()}

    # interrupted: a few live steps, bounded drain, export the rest, resume
    # on a freshly built engine
    src = fresh()
    for i, (p, n) in enumerate(jobs):
        src.submit(i, p, n)
    got = {}
    for _ in range(3):
        for r in src.step():
            got[r.rid] = r.generated
    for r in src.drain(max_steps=2):          # short sequences finish here
        got[r.rid] = r.generated
    snaps = src.export_inflight()
    assert snaps, "expected sequences still in flight after the drain window"
    assert src.cache.allocator.n_free == 64   # exported blocks released
    assert all(s.generated for s in snaps)    # all were mid-generation
    dst = fresh()
    dst.import_inflight(snaps)
    for r in dst.run_to_completion():
        got[r.rid] = r.generated
    assert got == expected


def test_drain_finishes_all_without_budget(cfg_params):
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2)
    rng = np.random.RandomState(2)
    eng.submit(0, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 5)
    eng.step()
    done = eng.drain()                        # unbounded: empties the engine
    assert [r.rid for r in done] == [0]
    assert not eng.active and not eng.admitting
    eng.resume_admission()
    assert eng.admitting


# ---------------------------------------------------------------------------
# Submit validation: prompts that cannot fit the block table are rejected.
# ---------------------------------------------------------------------------


def test_submit_rejects_oversize_requests(cfg_params):
    cfg, params = cfg_params        # smoke max_seq_len=512
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2)
    assert eng.max_context == 512
    with pytest.raises(ValueError, match="block"):
        eng.submit(0, np.zeros(600, np.int32), 4)
    with pytest.raises(ValueError, match="block"):
        eng.submit(1, np.zeros(500, np.int32), 20)   # 500 + 19 > 512
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(2, np.zeros(8, np.int32), 0)
    assert not eng.waiting                    # nothing was half-accepted
    eng.submit(3, np.zeros(8, np.int32), 4)   # a legal one still works
    assert len(eng.waiting) == 1


def test_small_replica_has_smaller_context_ceiling(cfg_params):
    """A 1-chip replica's per-sequence context is capped by its quota."""
    cfg, params = cfg_params
    orch = _orchestrator(4)
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=8,
                        seqs_per_chip=1, block_size=8)
    plan = orch.plan_span(ws([5, 300, 2, 3]))
    rt.apply_plan(plan)
    eng = rt.replicas[0].engine
    # 2-chip replica: quota 16 blocks -> 128-token ceiling, not 512
    assert eng.max_context == 16 * 8
    assert not eng.fits(200, 4)
    # no replica can hold it -> rejected before any router/state mutation
    with pytest.raises(ValueError, match="context ceiling"):
        rt.submit(0, np.zeros(200, np.int32), 4, type_id=1)
    assert rt._span_type_counts[1] == 0     # rejected: not an observed rate


# ---------------------------------------------------------------------------
# Shared block pool: replicas partition one device allocation.
# ---------------------------------------------------------------------------


def test_shared_pool_quota_partition():
    cfg = get_smoke_config("yi-9b")
    pool = BlockPool(cfg, num_blocks=16, block_size=4)
    a = PagedKVCache.from_pool(pool, max_seqs=2, max_blocks_per_seq=8,
                               quota=8)
    b = PagedKVCache.from_pool(pool, max_seqs=2, max_blocks_per_seq=8,
                               quota=8)
    a.admit(0, prompt_len=24)                 # 6 of a's 8 blocks
    assert pool.allocator.n_free == 10
    assert a.n_free_blocks == 2               # quota-, not pool-limited
    assert not a.can_admit(12)                # needs 3 + headroom 2 > 2
    assert b.n_free_blocks == 8
    assert b.can_admit(12)
    b.admit(0, prompt_len=12)
    assert pool.allocator.n_free == 7
    assert pool.reserved == 9
    a.release_all()
    b.release_all()
    assert pool.allocator.n_free == 16
    assert pool.reserved == 0
    assert a.used_blocks == b.used_blocks == 0


def test_decode_growth_cannot_starve_sibling_replica(cfg_params):
    """Admission reserves a sequence's full lifetime footprint, so one
    replica's decode growth stays inside its quota instead of draining the
    shared pool out from under its sibling."""
    cfg, params = cfg_params
    pool = BlockPool(cfg, num_blocks=8, block_size=4)
    a = ServingEngine(cfg, params, block_size=4, max_seqs=2, pool=pool,
                      kv_quota=4, max_blocks_per_seq=4)
    b = ServingEngine(cfg, params, block_size=4, max_seqs=2, pool=pool,
                      kv_quota=4, max_blocks_per_seq=4)
    rng = np.random.RandomState(6)
    # lifetime footprint larger than the quota: rejected up front, not
    # allowed to admit and then overflow mid-decode
    with pytest.raises(ValueError, match="block capacity"):
        a.submit(9, rng.randint(0, cfg.vocab_size, 4).astype(np.int32), 18)
    # two quota-sized requests reserve the full 4 blocks each, so they run
    # one at a time; b's share of the pool is never touched
    a.submit(0, rng.randint(0, cfg.vocab_size, 4).astype(np.int32), 12)
    a.submit(1, rng.randint(0, cfg.vocab_size, 4).astype(np.int32), 12)
    b.submit(2, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 9)
    done = {}
    while (a.waiting or a.active) or (b.waiting or b.active):
        for eng in (a, b):
            for r in eng.step():
                done[r.rid] = r.generated
        assert a.cache.used_blocks <= 4 and b.cache.used_blocks <= 4
    assert set(done) == {0, 1, 2}
    assert pool.allocator.n_free == 8 and pool.reserved == 0


def test_two_engines_share_one_pool_token_parity(cfg_params):
    """Interleaved stepping of two engines over one pool must not corrupt
    each other's pages: tokens match private-pool runs."""
    cfg, params = cfg_params
    rng = np.random.RandomState(3)
    jobs = [(rng.randint(0, cfg.vocab_size, n).astype(np.int32), new)
            for n, new in ((8, 6), (12, 5), (8, 7), (12, 4))]

    def solo(job_ids):
        eng = ServingEngine(cfg, params, num_blocks=64, block_size=8,
                            max_seqs=2)
        for i in job_ids:
            eng.submit(i, *jobs[i])
        return {r.rid: r.generated for r in eng.run_to_completion()}

    expected = {**solo([0, 1]), **solo([2, 3])}

    pool = BlockPool(cfg, num_blocks=64, block_size=8)
    e1 = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool,
                       kv_quota=32)
    e2 = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool,
                       kv_quota=32)
    e1.submit(0, *jobs[0]); e1.submit(1, *jobs[1])
    e2.submit(2, *jobs[2]); e2.submit(3, *jobs[3])
    got = {}
    while (e1.waiting or e1.active) or (e2.waiting or e2.active):
        for eng in (e1, e2):
            if eng.waiting or eng.active:
                for r in eng.step():
                    got[r.rid] = r.generated
    assert got == expected
    assert pool.allocator.n_free == 64


# ---------------------------------------------------------------------------
# Unified router interface.
# ---------------------------------------------------------------------------


def test_routers_share_one_interface():
    routers = [FlowRouter([[1.0, 0.0], [0.0, 1.0]]),
               RoundRobinRouter(2),
               LeastLoadedRouter(2)]
    up = np.array([True, True])
    for r in routers:                  # no isinstance dispatch needed
        r.update_loads([0.0, 1.0])
        k = r.route(0, up)
        assert k in (0, 1)
        r.reconfigure([[0.5, 0.5], [0.5, 0.5], [0.0, 0.0]])
        assert r.route(1, np.array([True, True, True])) in (0, 1, 2)


def test_least_loaded_router_follows_injected_loads():
    r = LeastLoadedRouter(3)
    r.update_loads([0.9, 0.1, 0.5])
    assert r.route(0) == 1
    assert r.route(0, up=np.array([True, False, True])) == 2


# ---------------------------------------------------------------------------
# Health feedback: a straggler's traffic share shrinks over spans.
# ---------------------------------------------------------------------------


def test_straggler_share_shrinks_over_spans(cfg_params):
    cfg, params = cfg_params
    orch = _orchestrator(4)           # rates below keep DP=2 [(TP=2),(TP=2)]
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=16,
                        seqs_per_chip=2, block_size=8)
    rates = [5, 300, 2, 3]
    rng = np.random.RandomState(4)
    shares = []
    for s in range(3):
        plan = orch.plan_span(ws(rates))
        rt.apply_plan(plan)
        if s == 0:
            assert len(plan.deployment.replicas) == 2
            rt.set_throttle(1, 0.25)  # replica 1 serves 1/4 of the ticks
        frac = np.array(plan.fractions)
        load = frac @ np.asarray(rates, float)
        shares.append(float(load[1] / load.sum()))
        for i in range(6):
            prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
            rt.submit(1000 * s + i, prompt, 5, type_id=1)
        rt.run_until_idle()
        report = rt.finish_span()
        if s == 0:
            assert report.achieved_fraction[1] < 0.6   # straggler detected
            assert report.achieved_fraction[0] > 0.9
    assert orch.health is not None and orch.health[1] < 0.6
    # deployment was kept, but the plan routes away from the straggler
    assert shares[2] < shares[0] - 0.1, shares


def test_orchestrator_observed_rates_blend():
    orch = _orchestrator(4)
    orch.observe_rates([10.0, 2.0, 0.0, 0.0])
    blended = orch.blended_workloads(ws([0, 0, 0, 0]), trust=0.5)
    assert blended[0].rate == pytest.approx(5.0)
    assert blended[1].rate == pytest.approx(1.0)
    orch.observe_rates([10.0, 2.0, 0.0, 0.0])  # EWMA stays put
    assert orch.observed_rates[0] == pytest.approx(10.0)
    # pass-through when no observation matches
    orch.observed_rates = None
    same = orch.blended_workloads(ws([7, 0, 0, 0]))
    assert same[0].rate == 7


def test_simulator_driver_reports_health():
    from repro.serving.baselines import OServePolicy
    from repro.serving.request import synthesize_trace
    from repro.serving.simulator import simulate
    cm = CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)
    cluster = ClusterSpec(16, hw=H100_SPEC)
    reqs = synthesize_trace(4, 120, trace_id=2, seed=0)
    for r in reqs:
        r.type_id = int(r.out_len > 500) * 2 + int(r.in_len > 600)
    pol = OServePolicy(cm, cluster, ARCH)
    simulate(reqs, pol, cm, ARCH, 4)
    assert pol.orch.health is not None          # driver fed observe_health
    assert len(pol.orch.health) == pol.orch.current.dp
    assert np.all(pol.orch.health > 0)


# ---------------------------------------------------------------------------
# Drain-window / mid-span edge cases around switches and removals.
# ---------------------------------------------------------------------------


class _ManualPlan:
    def __init__(self, rcs, fractions):
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions


def _manual_cluster(cfg, params, **kw):
    kw.setdefault("drain_steps", 1)
    rt = ClusterRuntime(cfg, params, total_chips=4, blocks_per_chip=32,
                        seqs_per_chip=4, block_size=8,
                        router=FlowRouter([[0.5], [0.5]]), **kw)
    rt.apply_plan(_ManualPlan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                              [[0.5], [0.5]]))
    return rt


def test_submit_during_paused_admission_stays_queued(cfg_params):
    """A request that arrives while admission is paused (a switch is in
    progress) queues — it is neither lost nor admitted early — and the
    cluster routes around the paused replica."""
    cfg, params = cfg_params
    rt = _manual_cluster(cfg, params)
    prompt = np.arange(8, dtype=np.int32)
    rt.replicas[0].engine.pause_admission()
    # cluster-level: routing masks the paused replica
    for rid in range(3):
        assert rt.submit(rid, prompt, 4) == 1
    # engine-level: a direct submit to the paused engine queues, and two
    # steps later it is still queued, untouched
    rt.replicas[0].engine.submit(90, prompt, 4)
    rt.step(); rt.step()
    assert [r.rid for r in rt.replicas[0].engine.waiting] == [90]
    assert not rt.replicas[0].engine.active
    rt.replicas[0].engine.resume_admission()
    done = rt.run_until_idle()
    assert {r.rid for r in done} == {0, 1, 2, 90}


def test_switch_where_drain_window_empties_migration(cfg_params):
    """When every in-flight request finishes inside the drain window the
    switch migrates nothing — and must still complete cleanly."""
    cfg, params = cfg_params
    rt = _manual_cluster(cfg, params, drain_steps=16)
    expected = {}
    eng = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
    rng = np.random.RandomState(3)
    for rid in range(4):
        p = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
        rt.submit(rid, p, 4)
        eng.submit(rid, p, 4)
    expected = {r.rid: r.generated for r in eng.run_to_completion()}
    rt.step()                       # everything mid-flight, 3 tokens to go
    # both replicas change config, so both must drain (and fully succeed)
    sw = rt.apply_plan(_ManualPlan([ReplicaConfig(2, 1), ReplicaConfig(2, 1)],
                                   [[0.5], [0.5]]))
    assert sw.drained == 4
    assert sw.migrated == 0 and sw.requeued == 0 and sw.moved == 0
    assert rt.pending == 0
    assert {r: rt.results[r].generated for r in rt.results} == expected


def test_router_routes_only_to_survivors_after_removal(cfg_params):
    """After a replica is removed mid-span, every new request lands on a
    survivor and the cluster still drains to idle."""
    cfg, params = cfg_params
    rt = _manual_cluster(cfg, params)
    prompt = np.arange(8, dtype=np.int32)
    k = rt.submit(0, prompt, 6)
    rt.step()
    rep = rt.fail_replica(k, reason="mid-span removal")
    surv = 1 - k
    assert rt.load_stats()[k]["dead"]
    assert rep.migrated == 1          # rid 0 moved to the survivor, mid-flight
    for rid in range(1, 6):
        assert rt.submit(rid, prompt, 4) == surv, \
            "router sent a request to a dead replica"
    rt.run_until_idle()
    assert rt.pending == 0
    assert set(rt.results) | set(rt.all_shed_rids) == set(range(6))
    span = rt.finish_span()
    assert span.dead_replicas == [k]
    assert span.achieved_fraction[k] == 0.0


# ---------------------------------------------------------------------------
# Replica repair / rejoin: recovered capacity re-enters the planning budget
# (inverse of observe_failures).
# ---------------------------------------------------------------------------


def test_repair_replica_rejoins_planning_budget(cfg_params):
    cfg, params = cfg_params
    orch = _orchestrator(6)
    plan = orch.plan_span(ws([5, 300, 2, 3]))
    assert plan.deployment.dp >= 2
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=16,
                        seqs_per_chip=2, block_size=8, drain_steps=1)
    rt.apply_plan(plan)
    n_live = len(rt.replicas)
    k = 0
    rt.fail_replica(k, reason="test kill")
    rt.finish_span()                  # feeds observe_failures
    lost = rt.replicas[k].rc.chips
    assert rt.lost_chips == lost
    assert orch.cluster.chips == rt.total_chips - lost
    assert orch.current is not None and orch.current.dp == n_live - 1

    rt.repair_replica(k)
    assert not rt.replicas[k].dead
    assert rt.lost_chips == 0
    assert rt.repaired_replicas == [k]
    # the orchestrator got the inverse of observe_failures: full chip
    # budget, full deployment, health re-aligned with a neutral entry
    assert orch.cluster.chips == rt.total_chips
    assert orch.current.dp == n_live
    assert orch.current.replicas == tuple(h.rc for h in rt.replicas)
    assert orch.health is None or (len(orch.health) == n_live
                                   and orch.health[k] == 1.0)
    # repairing a live replica is a no-op
    rt.repair_replica(k)
    assert rt.repaired_replicas == [k] and rt.lost_chips == 0
    # the repaired replica serves traffic again
    prompt = np.arange(8, dtype=np.int32)
    for rid in range(4):
        rt.submit(rid, prompt, 4)
    rt.run_until_idle()
    assert set(rt.results) == set(range(4))
    # and the next plan solves over the restored budget without error
    plan2 = orch.plan_span(ws([40, 10, 60, 40]))
    assert plan2.deployment.total_chips <= rt.total_chips


# ---------------------------------------------------------------------------
# CI smoke: the orchestrator->runtime example path must keep working.
# ---------------------------------------------------------------------------


@pytest.mark.real_smoke
def test_example_serve_orchestrated_real_smoke():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, os.path.join(repo, "examples",
                                      "serve_orchestrated.py"),
         "--real", "--spans", "2", "--chips", "4",
         "--requests-per-span", "4"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "total completed 8/8" in out.stdout, out.stdout
