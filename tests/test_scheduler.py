"""Cost model, assignment, and deployment-search behaviour tests."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.assignment import assign_workloads
from repro.core.costmodel import CostModel
from repro.core.deployment import (enumerate_deployments, exhaustive_search,
                                   flow_guided_search, uniform_initial)
from repro.core.types import (Deployment, H100_SPEC, ReplicaConfig,
                              WorkloadType, valid_strategies)

ARCH = [WorkloadType(1275, 287), WorkloadType(139, 133),
        WorkloadType(1181, 1824), WorkloadType(282, 1121)]


@pytest.fixture(scope="module")
def cm():
    return CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)


def test_valid_strategies_factorizations():
    s = valid_strategies(12, max_tp=8, max_pp=4)
    assert ReplicaConfig(6, 2) in s
    assert ReplicaConfig(3, 4) in s
    assert all(r.tp * r.pp == 12 for r in s)
    assert all(r.tp <= 8 and r.pp <= 4 for r in s)


def test_cost_model_monotonicity(cm):
    """More chips -> no worse throughput; longer outputs -> lower throughput."""
    w = ARCH[1]
    t2 = cm.capacity(ReplicaConfig(2), w)
    t4 = cm.capacity(ReplicaConfig(4), w)
    t8 = cm.capacity(ReplicaConfig(8), w)
    assert t2 < t4 < t8 * 1.2
    short, long_ = ARCH[1], ARCH[2]
    assert (cm.capacity(ReplicaConfig(8), short)
            > cm.capacity(ReplicaConfig(8), long_))


def test_cost_model_min_chips(cm):
    assert cm.min_chips() >= 1
    assert not cm.fits(ReplicaConfig(1))   # 30B bf16 > one 80GB H100 * 0.9


def test_dp_vs_tp_tradeoff(cm):
    """The Fig-1 pattern: DP-sliced favors short/compute workloads,
    consolidation favors long/memory workloads."""
    short, long_ = ARCH[1], ARCH[2]
    dp_short = 4 * cm.capacity(ReplicaConfig(2), short)
    tp_short = cm.capacity(ReplicaConfig(8), short)
    dp_long = 4 * cm.capacity(ReplicaConfig(2), long_)
    tp_long = cm.capacity(ReplicaConfig(8), long_)
    assert (dp_short / tp_short) > (dp_long / tp_long)


def test_assignment_respects_demand(cm):
    dep = Deployment((ReplicaConfig(8), ReplicaConfig(8)))
    ws = [a.with_rate(10.0) for a in ARCH]
    res = assign_workloads(cm, dep, ws)
    assert res.throughput <= 40.0 + 1e-6
    x = np.array(res.solution.x)
    assert (x.sum(0) <= 10.0 + 1e-6).all()


def test_capacity_scale_reroutes(cm):
    """Straggler mitigation: degrading one replica moves its flow away."""
    dep = Deployment((ReplicaConfig(8), ReplicaConfig(8)))
    ws = [a.with_rate(1000.0) for a in ARCH]
    healthy = assign_workloads(cm, dep, ws)
    degraded = assign_workloads(cm, dep, ws, capacity_scale=[1.0, 0.3])
    x_h = np.array(healthy.solution.x)
    x_d = np.array(degraded.solution.x)
    assert x_d[1].sum() < x_h[1].sum()
    assert degraded.throughput <= healthy.throughput + 1e-6


def test_enumerate_deployments_cover_chips(cm):
    deps = enumerate_deployments(16, cm.min_chips(), max_tp=8, max_pp=4)
    assert deps
    assert all(d.total_chips == 16 for d in deps)


def test_flow_guided_close_to_exhaustive(cm):
    ws = [a.with_rate(2000.0) for a in ARCH]
    ex = exhaustive_search(cm, 8, ws, max_tp=8, max_pp=4)
    fg = flow_guided_search(cm, 8, ws, max_tp=8, max_pp=4, seed=0)
    assert fg.throughput >= 0.90 * ex.throughput


def test_uniform_initial_fills_cluster(cm):
    dep = uniform_initial(cm, 16, max_tp=8, max_pp=4)
    assert dep.total_chips == 16


def test_search_deterministic(cm):
    ws = [a.with_rate(500.0) for a in ARCH]
    a = flow_guided_search(cm, 16, ws, seed=3)
    b = flow_guided_search(cm, 16, ws, seed=3)
    assert a.deployment == b.deployment
