"""KV migration subsystem: page handoff, device copy/relayout, chunked
prefill, and the migration-aware switch cost.

Acceptance (ISSUE 3): a 2-span heterogeneous deployment switch with long
in-flight contexts is token-for-token identical to an uninterrupted run
while recomputing ZERO prefill tokens for same-pool migrations — asserted
through the engines' prefill-token counters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.costmodel import CostModel
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.types import ClusterSpec, H100_SPEC, WorkloadType
from repro.models import init_params
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import BlockPool
from repro.serving.migration import (MigrationReport, migrate_batch,
                                     release_snapshot_pages)

ARCH = [WorkloadType(1275, 287), WorkloadType(139, 133),
        WorkloadType(1181, 1824), WorkloadType(282, 1121)]


def ws(rates):
    return [a.with_rate(float(r)) for a, r in zip(ARCH, rates)]


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _orchestrator(chips: int) -> Orchestrator:
    cm = CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)
    return Orchestrator(cm, ClusterSpec(chips, hw=H100_SPEC),
                        OrchestratorConfig(search_patience=10))


def _jobs(cfg, rng, specs):
    return [(rng.randint(0, cfg.vocab_size, n).astype(np.int32), new)
            for n, new in specs]


def _reference(cfg, params, jobs):
    eng = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
    for i, (p, n) in enumerate(jobs):
        eng.submit(i, p, n)
    return {r.rid: r.generated for r in eng.run_to_completion()}


# ---------------------------------------------------------------------------
# Page handoff: same-pool migration recomputes nothing and moves no data.
# ---------------------------------------------------------------------------


def test_same_pool_handoff_zero_recompute_token_parity(cfg_params):
    cfg, params = cfg_params
    rng = np.random.RandomState(0)
    jobs = _jobs(cfg, rng, ((40, 6), (8, 8), (21, 5)))
    expected = _reference(cfg, params, jobs)

    pool = BlockPool(cfg, 64, 8)
    src = ServingEngine(cfg, params, block_size=8, max_seqs=4, pool=pool,
                        kv_quota=32)
    dst = ServingEngine(cfg, params, block_size=8, max_seqs=4, pool=pool,
                        kv_quota=32)
    for i, (p, n) in enumerate(jobs):
        src.submit(i, p, n)
    got = {}
    for _ in range(3):
        for r in src.step():
            got[r.rid] = r.generated
    snaps = src.export_inflight(release=False)
    assert snaps and all(s.blocks for s in snaps)
    src.release_all()

    report = migrate_batch(dst, snaps)
    assert report.handoff == len(snaps)
    assert report.copied == report.reprefilled == 0
    assert report.pages_handoff > 0 and report.recompute_tokens == 0
    for r in dst.run_to_completion():
        got[r.rid] = r.generated
    assert got == expected
    assert dst.prefill_tokens == 0          # the zero-recompute guarantee
    assert pool.allocator.n_free == 64 and pool.reserved == 0


def test_handoff_rejected_falls_back_to_reprefill(cfg_params):
    """A destination without slot/quota headroom re-prefills instead of
    adopting — and the snapshot's orphaned pages are released, not leaked."""
    cfg, params = cfg_params
    rng = np.random.RandomState(1)
    jobs = _jobs(cfg, rng, ((16, 6), (16, 6)))
    expected = _reference(cfg, params, jobs)

    pool = BlockPool(cfg, 32, 8)
    src = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool,
                        kv_quota=16)
    # dst quota too small to adopt both sequences' lifetime reservations
    dst = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool,
                        kv_quota=4, max_blocks_per_seq=4)
    for i, (p, n) in enumerate(jobs):
        src.submit(i, p, n)
    got = {}
    for _ in range(2):
        for r in src.step():
            got[r.rid] = r.generated
    snaps = src.export_inflight(release=False)
    src.release_all()
    report = migrate_batch(dst, snaps)
    assert report.handoff + report.reprefilled == len(snaps)
    assert report.reprefilled >= 1           # at least one fell back
    assert report.recompute_tokens > 0
    for r in dst.run_to_completion():
        got[r.rid] = r.generated
    assert got == expected
    assert pool.allocator.n_free == 32 and pool.reserved == 0


def test_release_snapshot_pages_is_idempotent(cfg_params):
    cfg, params = cfg_params
    rng = np.random.RandomState(2)
    pool = BlockPool(cfg, 32, 8)
    eng = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool,
                        kv_quota=32)
    eng.submit(0, rng.randint(0, cfg.vocab_size, 16).astype(np.int32), 6)
    eng.step()
    (snap,) = eng.export_inflight(release=False)
    assert pool.allocator.n_free < 32
    release_snapshot_pages(snap)
    release_snapshot_pages(snap)             # second call is a no-op
    assert pool.allocator.n_free == 32


@pytest.mark.parametrize("arch", ["hymba-1.5b", "mamba2-370m"])
def test_handoff_carries_ssm_state(arch):
    """Hybrid (attn+SSM) and attn-free archs migrate too: the snapshot
    carries the SSM state rows alongside (or instead of) the KV pages."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.RandomState(8)
    jobs = _jobs(cfg, rng, ((16, 5), (9, 6)))
    expected = _reference(cfg, params, jobs)

    pool = BlockPool(cfg, 32, 8)
    src = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool,
                        kv_quota=32)
    dst = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool,
                        kv_quota=32)
    for i, (p, n) in enumerate(jobs):
        src.submit(i, p, n)
    got = {}
    for _ in range(2):
        for r in src.step():
            got[r.rid] = r.generated
    snaps = src.export_inflight(release=False)
    assert all(s.ssm is not None for s in snaps)
    src.release_all()
    report = migrate_batch(dst, snaps)
    assert report.handoff == len(snaps) and report.recompute_tokens == 0
    for r in dst.run_to_completion():
        got[r.rid] = r.generated
    assert got == expected


# ---------------------------------------------------------------------------
# Cross-pool migration: jitted page copy, and relayout across geometries.
# ---------------------------------------------------------------------------


def test_cross_pool_copy_token_parity(cfg_params):
    cfg, params = cfg_params
    rng = np.random.RandomState(3)
    jobs = _jobs(cfg, rng, ((40, 6), (12, 7)))
    expected = _reference(cfg, params, jobs)

    pool_a = BlockPool(cfg, 64, 8)
    pool_b = BlockPool(cfg, 64, 8)
    src = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool_a,
                        kv_quota=64)
    dst = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool_b,
                        kv_quota=64)
    for i, (p, n) in enumerate(jobs):
        src.submit(i, p, n)
    got = {}
    for _ in range(2):
        for r in src.step():
            got[r.rid] = r.generated
    snaps = src.export_inflight(release=False)
    src.release_all()
    report = migrate_batch(dst, snaps)
    assert report.copied == len(snaps) and report.handoff == 0
    assert report.pages_copied > 0
    for r in dst.run_to_completion():
        got[r.rid] = r.generated
    assert got == expected
    assert dst.prefill_tokens == 0           # copy still recomputes nothing
    assert pool_a.allocator.n_free == 64     # source pages released


def test_cross_pool_relayout_different_block_size(cfg_params):
    cfg, params = cfg_params
    rng = np.random.RandomState(4)
    jobs = _jobs(cfg, rng, ((21, 6), (9, 5)))
    expected = _reference(cfg, params, jobs)

    pool_a = BlockPool(cfg, 64, 8)
    pool_b = BlockPool(cfg, 128, 4)          # mismatched page geometry
    src = ServingEngine(cfg, params, block_size=8, max_seqs=2, pool=pool_a,
                        kv_quota=64)
    dst = ServingEngine(cfg, params, block_size=4, max_seqs=2, pool=pool_b,
                        kv_quota=128)
    for i, (p, n) in enumerate(jobs):
        src.submit(i, p, n)
    got = {}
    for _ in range(2):
        for r in src.step():
            got[r.rid] = r.generated
    snaps = src.export_inflight(release=False)
    src.release_all()
    report = migrate_batch(dst, snaps)
    assert report.copied == len(snaps)
    for r in dst.run_to_completion():
        got[r.rid] = r.generated
    assert got == expected


# ---------------------------------------------------------------------------
# Chunked prefill: parity with one-shot, and decode never stalls.
# ---------------------------------------------------------------------------


def test_chunked_prefill_token_parity(cfg_params):
    cfg, params = cfg_params
    rng = np.random.RandomState(5)
    jobs = _jobs(cfg, rng, ((40, 6), (8, 8), (21, 5), (33, 4)))
    expected = _reference(cfg, params, jobs)
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=4,
                        prefill_chunk_tokens=8)
    for i, (p, n) in enumerate(jobs):
        eng.submit(i, p, n)
    got = {r.rid: r.generated for r in eng.run_to_completion()}
    assert got == expected
    # chunking re-processes nothing: counter equals total context tokens
    assert eng.prefill_tokens == sum(len(p) for p, _ in jobs)


def test_chunked_prefill_interleaves_with_decode(cfg_params):
    """While a long prompt streams in chunk by chunk, the already-running
    sequence keeps emitting a token every step (no decode stall)."""
    cfg, params = cfg_params
    rng = np.random.RandomState(6)
    short = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    long = rng.randint(0, cfg.vocab_size, 64).astype(np.int32)
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2,
                        prefill_chunk_tokens=8)
    eng.submit(0, short, 20)
    eng.step()                               # short is admitted + prefilled
    eng.submit(1, long, 4)
    counts = []
    for _ in range(8):                       # 64/8 = 8 chunks to stream in
        before = len(eng.active[0].generated)
        eng.step()
        counts.append(len(eng.active[0].generated) - before)
    assert all(c == 1 for c in counts), counts   # one token every step
    r1 = eng.active[[s for s, r in eng.active.items() if r.rid == 1][0]]
    assert r1.generated                      # long prompt finished prefill
    got = {r.rid: r.generated for r in eng.run_to_completion()}
    ref = _reference(cfg, params, [(short, 20), (long, 4)])
    assert got == ref


def test_chunked_prefill_in_reprefill_fallback(cfg_params):
    """Cross-pool re-prefill fallback of a long migrated context runs
    through the chunked path on the destination."""
    cfg, params = cfg_params
    rng = np.random.RandomState(7)
    prompt = rng.randint(0, cfg.vocab_size, 40).astype(np.int32)
    expected = _reference(cfg, params, [(prompt, 8)])

    src = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2)
    src.submit(0, prompt, 8)
    src.step(); src.step()
    snaps = src.export_inflight()            # token-state export (release)
    dst = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2,
                        prefill_chunk_tokens=8)
    dst.import_inflight(snaps)
    got = {r.rid: r.generated for r in dst.run_to_completion()}
    assert got == expected
    ctx = len(prompt) + len(snaps[0].generated)
    assert dst.prefill_tokens == ctx         # chunked, but exactly once


# ---------------------------------------------------------------------------
# Acceptance: 2-span heterogeneous switch, long in-flight contexts, token
# parity with an uninterrupted run, ZERO prefill tokens recomputed.
# ---------------------------------------------------------------------------


def test_cluster_switch_page_handoff_zero_recompute(cfg_params):
    cfg, params = cfg_params
    orch = _orchestrator(6)
    # drain_steps=0: everything in flight at the switch must migrate
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=24,
                        seqs_per_chip=1, block_size=8, drain_steps=0)
    rng = np.random.RandomState(0)
    jobs = {}
    rid = 0
    deployments = []
    reports = []
    for rates in ([5, 300, 2, 3], [40, 10, 60, 40]):
        plan = orch.plan_span(ws(rates))
        deployments.append(plan.deployment)
        reports.append(rt.apply_plan(plan))
        for i in range(4):
            t = int(rng.randint(0, 4))
            # long prompts: in flight across the span boundary with real
            # multi-page contexts (24-40 tokens, 3-5 pages each)
            prompt = rng.randint(0, cfg.vocab_size,
                                 24 + 4 * t).astype(np.int32)
            jobs[rid] = (prompt, 10 + t)
            rt.submit(rid, prompt, 10 + t, type_id=t)
            rid += 1
        for _ in range(4):                   # partial progress only
            rt.step()
        rt.finish_span()
    rt.run_until_idle()

    assert deployments[0].replicas != deployments[1].replicas
    switch = reports[1]
    assert switch.changed, "no replica was rebuilt"
    assert switch.migrated >= 1, "no in-flight request was migrated"
    # every migration rode the page-handoff path: zero recompute
    assert switch.handoff == switch.migrated
    assert switch.reprefilled == 0 and switch.copied == 0
    assert switch.recompute_tokens == 0
    assert switch.pages_handoff > 0

    # prefill forwards processed each admitted context exactly once
    assert rt.total_prefill_tokens == sum(len(p) for p, _ in jobs.values())

    # token-for-token parity with an uninterrupted single engine
    assert len(rt.results) == rid
    ref = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
    for r, (prompt, n) in jobs.items():
        ref.submit(r, prompt, n)
    expected = {r.rid: r.generated for r in ref.run_to_completion()}
    for r in range(rid):
        assert rt.results[r].generated == expected[r], f"rid {r} diverged"


# ---------------------------------------------------------------------------
# Migration-aware switch cost in the orchestrator.
# ---------------------------------------------------------------------------


def test_orchestrator_prefers_handoff_friendly_switches():
    """With heavy in-flight contexts and NO shared pool, the KV stall raises
    the switch bar enough to hold the current deployment; the same state
    with page handoff available (shared pool) switches freely."""
    r1, r2 = [5, 300, 2, 3], [40, 10, 60, 40]
    lens = [8000] * 1000                     # long contexts, many requests

    base = _orchestrator(6)
    base.plan_span(ws(r1))
    plan = base.plan_span(ws(r2))
    assert plan.changed_replicas, "scenario must switch without a penalty"
    assert plan.kv_migration_seconds == 0.0  # nothing in flight observed

    shared = _orchestrator(6)
    shared.plan_span(ws(r1))
    shared.observe_inflight(lens, shared_pool=True)
    plan_shared = shared.plan_span(ws(r2))
    assert plan_shared.changed_replicas      # handoff is free: still switch
    assert plan_shared.kv_migration_seconds == 0.0

    sep = _orchestrator(6)
    sep.plan_span(ws(r1))
    sep.observe_inflight(lens, shared_pool=False)
    assert sep.switch_kv_seconds() > 10.0    # tens of seconds of KV moves
    plan_sep = sep.plan_span(ws(r2))
    assert not plan_sep.changed_replicas, (
        "a switch that stalls minutes of KV transfer must not clear the "
        "hysteresis bar")


def test_migration_report_merge():
    a = MigrationReport(handoff=1, pages_handoff=3)
    b = MigrationReport(copied=2, pages_copied=5, recompute_tokens=7,
                        reprefilled=1)
    a.merge(b)
    assert (a.handoff, a.copied, a.reprefilled) == (1, 2, 1)
    assert a.migrated == 4
    assert (a.pages_handoff, a.pages_copied, a.recompute_tokens) == (3, 5, 7)
