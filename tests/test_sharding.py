"""Distribution layer on a small in-process device mesh (8 CPU devices).

Spawned as a subprocess so XLA_FLAGS is set before jax initializes, without
polluting the main test process (smoke tests must see 1 device).
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_small_mesh
from repro.models import forward, init_params
from repro.pshard import sharding_rules

out = {}
mesh = make_small_mesh(8, model=2)
assert [d.platform for d in jax.devices()] == ["cpu"] * 8

for arch in ["yi-9b", "olmoe-1b-7b", "mamba2-370m"]:
    cfg = get_smoke_config(arch)
    plan, run_cfg = shd.make_plan(cfg, "train", False, 8, tp=2, fsdp=False)
    params = init_params(run_cfg, jax.random.PRNGKey(0), jnp.float32)
    pspecs = shd.param_pspecs(run_cfg, plan)
    named = shd.named(mesh, pspecs)
    params_sharded = jax.device_put(params, named)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                run_cfg.vocab_size)
    tok_sharding = NamedSharding(mesh, P(("data",), None))
    tokens_sharded = jax.device_put(tokens, tok_sharding)

    def fn(p, t):
        return forward(p, run_cfg, t)

    with mesh:
        with sharding_rules(mesh, plan.rules):
            jitted = jax.jit(fn, in_shardings=(named, tok_sharding))
            dist = jitted(params_sharded, tokens_sharded)
    local = forward(params, run_cfg, tokens)
    err = float(jnp.max(jnp.abs(dist - local)))
    scale = float(jnp.max(jnp.abs(local))) + 1e-9
    out[arch] = err / scale

# head padding function-equivalence (starcoder2: 4 heads -> pad on tp=8... use
# a case where padding triggers: granite smoke has 4 q heads / 2 kv, tp=2 ok;
# force tp where heads don't divide)
import dataclasses
cfg = dataclasses.replace(get_smoke_config("granite-moe-3b-a800m"),
                          n_q_heads=6, n_kv_heads=2, head_dim=16, d_model=96)
tp = 4
padded = shd.padded_config(cfg, tp)
assert padded.n_q_heads % tp == 0 and padded.n_q_heads > cfg.n_q_heads
params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
pparams = init_params(padded, jax.random.PRNGKey(0), jnp.float32)
pparams = {**pparams, "embed": params["embed"],
           "final_norm": params["final_norm"]}
pp = shd.pad_attention_params(params, cfg, padded)
# splice padded attention into the padded skeleton
blocks = dict(pparams["blocks"])
blocks.update({k: v for k, v in pp["blocks"].items() if k == "attn"})
for k in params["blocks"]:
    if k != "attn":
        blocks[k] = params["blocks"][k]
pparams["blocks"] = blocks
tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
a = forward(params, cfg, tokens)
b = forward(pparams, padded, tokens)
out["head_padding_rel"] = float(jnp.max(jnp.abs(a - b))) / (
    float(jnp.max(jnp.abs(a))) + 1e-9)
print(json.dumps(out))
"""


@pytest.mark.slow
def test_distributed_forward_matches_single_device(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1200,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for arch, rel in out.items():
        assert rel < 2e-2, (arch, rel)
