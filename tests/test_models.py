"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting shapes and finiteness; decode == forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.data.pipeline import DataConfig, packed_batches
from repro.models import (decode_step, forward, init_cache, init_params,
                          prefill)
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 64
    if cfg.modality == "audio_stub":
        embeds = jax.random.normal(jax.random.PRNGKey(1),
                                   (B, S, cfg.d_model)) * 0.02
        logits = forward(params, cfg, embeds=embeds)
    else:
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
        logits = forward(params, cfg, tokens)
    assert logits.shape == (B, S, cfg.padded_vocab())
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(remat=False)
    step = jax.jit(make_train_step(cfg, tcfg))
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    B, S = 2, 32
    rng = np.random.RandomState(0)
    batch = {"labels": rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    if cfg.modality == "audio_stub":
        batch["embeds"] = rng.randn(B, S, cfg.d_model).astype(np.float32) * .02
    else:
        batch["tokens"] = rng.randint(0, cfg.vocab_size,
                                      (B, S)).astype(np.int32)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["nll"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["yi-9b", "gemma2-2b", "olmoe-1b-7b",
                                  "mamba2-370m", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    lp, cache = prefill(params, cfg, tokens)
    nxt = jnp.argmax(lp[:, :cfg.vocab_size], -1).astype(jnp.int32)
    big = init_cache(cfg, B, S + 4, jnp.float32)
    if cache.k is not None:
        big.k = big.k.at[:, :, :S].set(cache.k)
        big.v = big.v.at[:, :, :S].set(cache.v)
    if cache.ssm is not None:
        big.ssm, big.conv = cache.ssm, cache.conv
    big.pos = cache.pos
    ld, _ = decode_step(params, cfg, nxt, big)
    ext = jnp.concatenate([tokens, nxt[:, None]], axis=1)
    lf = forward(params, cfg, ext)[:, -1]
    rel = float(jnp.max(jnp.abs(ld - lf))) / (float(jnp.max(jnp.abs(lf))) + 1e-9)
    assert rel < 2e-2


def test_training_reduces_loss():
    from repro.train.optimizer import AdamWConfig
    cfg = get_smoke_config("yi-9b")
    tcfg = TrainConfig(remat=False,
                       opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=100))
    step = jax.jit(make_train_step(cfg, tcfg))
    state = init_train_state(cfg, jax.random.PRNGKey(0), tcfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8)
    it = packed_batches(dc)
    losses = []
    for i in range(25):
        state, m = step(state, next(it))
        losses.append(float(m["nll"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_remat_equivalence():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    a = forward(params, cfg, tokens, remat=False)
    b = forward(params, cfg, tokens, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_gemma2_local_global_differ():
    """The local mask must actually change layer behaviour."""
    cfg = dataclasses.replace(get_smoke_config("gemma2-2b"), n_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    S = 200   # > reduced local window (64)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                                cfg.vocab_size)
    a = forward(params, cfg, tokens)
    no_local = dataclasses.replace(cfg, local_window=0)
    b = forward(params, no_local, tokens)
    assert float(jnp.max(jnp.abs(a - b))) > 1e-4


def test_grad_compression_roundtrip_close():
    from repro.train.optimizer import compress_roundtrip
    tree = {"w": jnp.asarray(np.random.RandomState(0).randn(64, 33),
                             jnp.float32)}
    out = compress_roundtrip(tree)
    err = float(jnp.max(jnp.abs(out["w"] - tree["w"])))
    assert err < 0.05   # int8 blockwise quantization error bound
