"""End-to-end behaviour tests for the OServe system.

The scenario tests tie the full loop together: predict -> schedule ->
switch -> serve, on the discrete-event cluster and on the real-JAX engine.
"""
import numpy as np
import pytest

from benchmarks.common import Bench
from repro.core.predictor import LSTMWorkloadPredictor
from repro.serving.baselines import OServePolicy, VLLMStaticPolicy


@pytest.fixture(scope="module")
def bench():
    return Bench("opt-30b", chips=16, n_spans=12, trace_id=2)


def test_oserve_not_worse_than_static(bench):
    """With the robust scheduler, OServe must at least match the static
    baseline on its own calibrated trace (paper: strictly better on real
    traces; our synthetic calibration yields parity-or-better)."""
    o_res, o_m = bench.run(OServePolicy(bench.cm, bench.cluster,
                                        bench.archetypes))
    s_res, s_m = bench.run(VLLMStaticPolicy(bench.cm, bench.cluster,
                                            bench.archetypes,
                                            bench.avg_rates))
    assert o_m["throughput_rps"] >= 0.95 * s_m["throughput_rps"]
    # on short traces the regime-flip switch transients dominate the tail;
    # bounded degradation is the invariant (parity on the 40-span benches)
    assert o_m.get("p99", 0) <= 2.5 * s_m.get("p99", 1e9)


def test_adhoc_switching_not_worse_than_reload(bench):
    a_res, a_m = bench.run(OServePolicy(bench.cm, bench.cluster,
                                        bench.archetypes, naive_reload=False))
    n_res, n_m = bench.run(OServePolicy(bench.cm, bench.cluster,
                                        bench.archetypes, naive_reload=True))
    assert a_m.get("p99", 0) <= n_m.get("p99", 0) + 1e-6
    assert a_m["dropped"] <= n_m["dropped"]


def test_lstm_predictor_in_the_loop(bench):
    lstm = LSTMWorkloadPredictor(len(bench.archetypes), window=6, hidden=8,
                                 seed=0)
    lstm.fit(np.maximum(bench.counts[:8], 0) + 1.0, epochs=20)
    pol = OServePolicy(bench.cm, bench.cluster, bench.archetypes,
                       predictor=lstm)
    res, m = bench.run(pol)
    assert m["completed"] > 0


def test_all_requests_accounted(bench):
    res, m = bench.run(OServePolicy(bench.cm, bench.cluster,
                                    bench.archetypes))
    done = sum(1 for r in res.requests if r.finish >= 0)
    assert done + res.dropped == len(res.requests)
