"""Greedy switch planner (Algorithm 2) property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.costmodel import CostModel
from repro.core.switching import (place_deployment, plan_kv_migration,
                                  plan_switch)
from repro.core.types import (ClusterSpec, Deployment, ReplicaConfig,
                              TPU_V5E_SPEC, valid_strategies)


@pytest.fixture(scope="module")
def cm():
    return CostModel(get_config("opt-66b").profile())


def deployments_of(chips, sizes_strats):
    return Deployment(tuple(ReplicaConfig(tp, pp) for tp, pp in sizes_strats))


def coverage(plan, placed_dst, cm):
    """Every target device's rectangle must be fully paid for."""
    total_needed = sum(1.0 for rep in placed_dst.replicas
                      for _ in rep.chips) * 0  # placeholder
    needed_bytes = sum(
        cm.p.param_bytes / (rep.config.tp * rep.config.pp)
        for rep in placed_dst.replicas for _ in rep.chips)
    supplied = plan.moved_bytes() + plan.local_bytes + plan.host_bytes
    return needed_bytes, supplied


CASES = [
    ([(8, 2)], [(4, 2), (4, 2)]),
    ([(2, 1)] * 8, [(8, 1), (8, 1)]),
    ([(8, 1), (4, 1), (4, 1)], [(4, 2), (4, 2)]),
    ([(3, 2), (2, 1), (8, 1)], [(8, 2)]),       # non-power-of-two TP=3
]


@pytest.mark.parametrize("src,dst", CASES)
def test_plan_covers_all_target_shards(cm, src, dst):
    cluster = ClusterSpec(16)
    ps = place_deployment(deployments_of(16, src), cluster)
    pd = place_deployment(deployments_of(16, dst), cluster)
    plan = plan_switch(ps, pd, cm)
    needed, supplied = coverage(plan, pd, cm)
    assert abs(needed - supplied) < 1e-3 * needed
    assert plan.host_bytes == 0.0        # sources exist for every grain


@pytest.mark.parametrize("src,dst", CASES)
def test_switch_beats_reload(cm, src, dst):
    cluster = ClusterSpec(16)
    ps = place_deployment(deployments_of(16, src), cluster)
    pd = place_deployment(deployments_of(16, dst), cluster)
    plan = plan_switch(ps, pd, cm)
    assert plan.estimate_seconds(TPU_V5E_SPEC) < cm.reload_seconds() / 3


def test_identity_switch_is_free(cm):
    cluster = ClusterSpec(16)
    dep = deployments_of(16, [(8, 1), (8, 1)])
    ps = place_deployment(dep, cluster)
    plan = plan_switch(ps, ps, cm)
    assert plan.moved_bytes() == 0.0
    assert plan.local_bytes > 0.0


def test_intra_pod_preferred(cm):
    """All chips in one pod -> every transfer must be intra-pod."""
    cluster = ClusterSpec(16)   # 16 < 256 chips/pod
    ps = place_deployment(deployments_of(16, [(8, 2)]), cluster)
    pd = place_deployment(deployments_of(16, [(4, 2), (4, 2)]), cluster)
    plan = plan_switch(ps, pd, cm)
    assert all(t.intra_pod for t in plan.transfers)


def test_load_balanced_sources(cm):
    """Greedy balancing: no source sends more than ~3x the mean."""
    cluster = ClusterSpec(16)
    ps = place_deployment(deployments_of(16, [(2, 1)] * 8), cluster)
    pd = place_deployment(deployments_of(16, [(8, 1), (8, 1)]), cluster)
    plan = plan_switch(ps, pd, cm)
    per_src = {}
    for t in plan.transfers:
        per_src[t.src] = per_src.get(t.src, 0.0) + t.bytes
    loads = np.array(list(per_src.values()))
    assert loads.max() <= 3.0 * loads.mean() + 1e-6


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_random_transitions_cover(cm, seed):
    rng = np.random.RandomState(seed)
    cluster = ClusterSpec(16)

    def random_dep():
        remaining = 16
        reps = []
        while remaining >= 2:
            size = int(rng.choice([s for s in (2, 3, 4, 6, 8, remaining)
                                   if s <= remaining]))
            strats = valid_strategies(size, max_tp=8, max_pp=4)
            if not strats:
                break
            reps.append(strats[rng.randint(len(strats))])
            remaining -= size
        return Deployment(tuple(reps))

    src, dst = random_dep(), random_dep()
    if not src.replicas or not dst.replicas:
        return
    ps = place_deployment(src, cluster)
    pd = place_deployment(dst, cluster)
    plan = plan_switch(ps, pd, cm)
    needed, supplied = coverage(plan, pd, cm)
    assert abs(needed - supplied) < 1e-3 * max(needed, 1.0)


def test_kv_migration_split(cm):
    plan = plan_kv_migration(cm, {1: 100, 2: 3000, 3: 8000},
                             drain_threshold=2048)
    assert plan.drained == [1]
    assert {r for r, _ in plan.migrated} == {2, 3}
    assert plan.moved_bytes() > 0
