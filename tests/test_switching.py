"""Greedy switch planner (Algorithm 2) property tests."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # only the property test needs it
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.core.costmodel import CostModel
from repro.core.switching import (place_deployment, plan_kv_migration,
                                  plan_switch)
from repro.core.types import (ClusterSpec, Deployment, ReplicaConfig,
                              TPU_V5E_SPEC, valid_strategies)


@pytest.fixture(scope="module")
def cm():
    return CostModel(get_config("opt-66b").profile())


def deployments_of(chips, sizes_strats):
    return Deployment(tuple(ReplicaConfig(tp, pp) for tp, pp in sizes_strats))


def coverage(plan, placed_dst, cm):
    """Every target device's rectangle must be fully paid for."""
    total_needed = sum(1.0 for rep in placed_dst.replicas
                      for _ in rep.chips) * 0  # placeholder
    needed_bytes = sum(
        cm.p.param_bytes / (rep.config.tp * rep.config.pp)
        for rep in placed_dst.replicas for _ in rep.chips)
    supplied = plan.moved_bytes() + plan.local_bytes + plan.host_bytes
    return needed_bytes, supplied


CASES = [
    ([(8, 2)], [(4, 2), (4, 2)]),
    ([(2, 1)] * 8, [(8, 1), (8, 1)]),
    ([(8, 1), (4, 1), (4, 1)], [(4, 2), (4, 2)]),
    ([(3, 2), (2, 1), (8, 1)], [(8, 2)]),       # non-power-of-two TP=3
]


@pytest.mark.parametrize("src,dst", CASES)
def test_plan_covers_all_target_shards(cm, src, dst):
    cluster = ClusterSpec(16)
    ps = place_deployment(deployments_of(16, src), cluster)
    pd = place_deployment(deployments_of(16, dst), cluster)
    plan = plan_switch(ps, pd, cm)
    needed, supplied = coverage(plan, pd, cm)
    assert abs(needed - supplied) < 1e-3 * needed
    assert plan.host_bytes == 0.0        # sources exist for every grain


@pytest.mark.parametrize("src,dst", CASES)
def test_switch_beats_reload(cm, src, dst):
    cluster = ClusterSpec(16)
    ps = place_deployment(deployments_of(16, src), cluster)
    pd = place_deployment(deployments_of(16, dst), cluster)
    plan = plan_switch(ps, pd, cm)
    assert plan.estimate_seconds(TPU_V5E_SPEC) < cm.reload_seconds() / 3


def test_identity_switch_is_free(cm):
    cluster = ClusterSpec(16)
    dep = deployments_of(16, [(8, 1), (8, 1)])
    ps = place_deployment(dep, cluster)
    plan = plan_switch(ps, ps, cm)
    assert plan.moved_bytes() == 0.0
    assert plan.local_bytes > 0.0


def test_intra_pod_preferred(cm):
    """All chips in one pod -> every transfer must be intra-pod."""
    cluster = ClusterSpec(16)   # 16 < 256 chips/pod
    ps = place_deployment(deployments_of(16, [(8, 2)]), cluster)
    pd = place_deployment(deployments_of(16, [(4, 2), (4, 2)]), cluster)
    plan = plan_switch(ps, pd, cm)
    assert all(t.intra_pod for t in plan.transfers)


def test_load_balanced_sources(cm):
    """Greedy balancing: no source sends more than ~3x the mean."""
    cluster = ClusterSpec(16)
    ps = place_deployment(deployments_of(16, [(2, 1)] * 8), cluster)
    pd = place_deployment(deployments_of(16, [(8, 1), (8, 1)]), cluster)
    plan = plan_switch(ps, pd, cm)
    per_src = {}
    for t in plan.transfers:
        per_src[t.src] = per_src.get(t.src, 0.0) + t.bytes
    loads = np.array(list(per_src.values()))
    assert loads.max() <= 3.0 * loads.mean() + 1e-6


def _hypothesis_seeds(f):
    """@given(seed) when hypothesis is available, else a clean skip."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=25, deadline=None)(
            given(st.integers(0, 10_000))(f))

    def skipped(cm):
        pytest.skip("hypothesis not installed")
    return skipped


@_hypothesis_seeds
def test_random_transitions_cover(cm, seed):
    rng = np.random.RandomState(seed)
    cluster = ClusterSpec(16)

    def random_dep():
        remaining = 16
        reps = []
        while remaining >= 2:
            size = int(rng.choice([s for s in (2, 3, 4, 6, 8, remaining)
                                   if s <= remaining]))
            strats = valid_strategies(size, max_tp=8, max_pp=4)
            if not strats:
                break
            reps.append(strats[rng.randint(len(strats))])
            remaining -= size
        return Deployment(tuple(reps))

    src, dst = random_dep(), random_dep()
    if not src.replicas or not dst.replicas:
        return
    ps = place_deployment(src, cluster)
    pd = place_deployment(dst, cluster)
    plan = plan_switch(ps, pd, cm)
    needed, supplied = coverage(plan, pd, cm)
    assert abs(needed - supplied) < 1e-3 * max(needed, 1.0)


def test_kv_migration_split(cm):
    plan = plan_kv_migration(cm, {1: 100, 2: 3000, 3: 8000},
                             drain_threshold=2048)
    assert plan.drained == [1]
    assert {r for r, _ in plan.migrated} == {2, 3}
    assert plan.moved_bytes() > 0


def test_kv_migration_moved_bytes_are_page_rounded(cm):
    """Whole pages move, not live tokens: bytes equal seq_mem of the
    page-rounded context, and grow only at page granularity."""
    page = 16
    plan = plan_kv_migration(cm, {1: 3001}, page_tokens=page)
    (rid, bytes_), = plan.migrated
    pages = -(-3001 // page)
    assert bytes_ == pytest.approx(cm.p.seq_mem_bytes(pages * page))
    # +1 token inside the same page: identical bytes
    same = plan_kv_migration(cm, {1: 3002}, page_tokens=page)
    assert same.migrated[0][1] == pytest.approx(bytes_)
    # crossing into a new page adds exactly one page of KV
    more = plan_kv_migration(cm, {1: pages * page + 1}, page_tokens=page)
    assert more.migrated[0][1] - bytes_ == pytest.approx(
        cm.p.seq_mem_bytes(page) - cm.p.state_bytes_per_seq)


def test_kv_migration_shared_pool_is_free(cm):
    """Page handoff: same request set, zero bytes moved, zero stall —
    but the destination still reserves the (headroom-inflated) buffers."""
    lens = {1: 100, 2: 3000, 3: 8000}
    copy = plan_kv_migration(cm, lens)
    hand = plan_kv_migration(cm, lens, shared_pool=True)
    assert hand.drained == copy.drained == [1]
    assert hand.handoff == [2, 3] and not hand.migrated
    assert hand.moved_bytes() == 0.0
    assert hand.estimate_seconds(TPU_V5E_SPEC) == 0.0
    assert hand.reserved_bytes == pytest.approx(copy.reserved_bytes)
    assert copy.reserved_bytes > copy.moved_bytes()     # +15% headroom
    assert copy.reserved_bytes == pytest.approx(1.15 * copy.moved_bytes())


def test_kv_migration_intra_vs_inter_pod_bandwidth(cm):
    plan = plan_kv_migration(cm, {1: 4096, 2: 4096})
    t_ici = plan.estimate_seconds(TPU_V5E_SPEC, intra_pod=True)
    t_dcn = plan.estimate_seconds(TPU_V5E_SPEC, intra_pod=False)
    assert t_ici > 0
    assert t_dcn / t_ici == pytest.approx(
        TPU_V5E_SPEC.ici_bw / TPU_V5E_SPEC.dcn_bw)
    assert t_dcn == pytest.approx(plan.moved_bytes() / TPU_V5E_SPEC.dcn_bw)


def test_switch_plan_estimate_prices_links_and_host(cm):
    """SwitchPlan.estimate_seconds: the bottleneck link pays, host reload
    adds serially, and slower DCN means slower inter-pod switches."""
    from repro.core.switching import SwitchPlan, Transfer
    g = (0, 1, 0, 1)
    intra = SwitchPlan([Transfer(0, 1, 1e9, True, g)], 0.0, 0.0, 1e9)
    inter = SwitchPlan([Transfer(0, 300, 1e9, False, g)], 0.0, 0.0, 1e9)
    hw = TPU_V5E_SPEC
    assert intra.estimate_seconds(hw) == pytest.approx(1e9 / hw.ici_bw)
    assert inter.estimate_seconds(hw) == pytest.approx(1e9 / hw.dcn_bw)
    # two sends from one source serialize on its ICI port; two sources don't
    fan_in = SwitchPlan([Transfer(0, 1, 1e9, True, g),
                         Transfer(0, 2, 1e9, True, g)], 0.0, 0.0, 2e9)
    spread = SwitchPlan([Transfer(0, 1, 1e9, True, g),
                         Transfer(3, 2, 1e9, True, g)], 0.0, 0.0, 2e9)
    assert fan_in.estimate_seconds(hw) == pytest.approx(2e9 / hw.ici_bw)
    assert spread.estimate_seconds(hw) == pytest.approx(1e9 / hw.ici_bw)
    # host reload is additive on top of the link time
    with_host = SwitchPlan([Transfer(0, 1, 1e9, True, g)], 0.0, 1e9, 2e9)
    assert with_host.estimate_seconds(hw) == pytest.approx(
        1e9 / hw.ici_bw + 1e9 / hw.host_load_bw)
