"""Disaggregated prefill/decode serving: role plumbing, the routing gate,
the zero-recompute handoff, and chaos recovery across a prefill-replica
death (ISSUE 10).

The routing invariant under test: while a role-compatible replica is up,
a prefill-phase request never lands on a ``decode`` replica and a
decode-phase one never lands on a ``prefill`` replica — and the moment
no compatible replica survives, the gate relaxes instead of wedging.
Everything runs on the CPU smoke model; the chaos-marked test joins the
CI chaos job.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.types import Deployment, ReplicaConfig
from repro.models import init_params
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.router import FlowRouter


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


class _Plan:
    """Minimal stand-in for SpanPlan in manual (orchestrator-less) tests."""

    def __init__(self, rcs, fractions):
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions


def _jobs(cfg, n=8, seed=7):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, 6 + (i % 3) * 2).astype(np.int32),
             6 + (i % 4)) for i in range(n)]


def _disagg_runtime(cfg, params, fractions=((0.5,), (0.5,)), faults=None,
                    **kw):
    """Replica 0 = prefill, replica 1 = decode, one shared pool."""
    fr = [list(f) for f in fractions]
    rt = ClusterRuntime(cfg, params, total_chips=4, blocks_per_chip=32,
                        seqs_per_chip=2, block_size=8, drain_steps=1,
                        router=FlowRouter(fr), faults=faults, **kw)
    rt.apply_plan(_Plan([ReplicaConfig(2, role="prefill"),
                         ReplicaConfig(2, role="decode")], fr))
    return rt


def _reference(cfg, params, jobs):
    eng = ServingEngine(cfg, params, num_blocks=256, block_size=8,
                        max_seqs=len(jobs))
    for rid, (p, n) in enumerate(jobs):
        eng.submit(rid, p, n)
    return {r.rid: list(r.generated) for r in eng.run_to_completion()}


# ---------------------------------------------------------------------------
# Role plumbing.
# ---------------------------------------------------------------------------


def test_replica_config_role_validation():
    assert ReplicaConfig(2).role == "mixed"
    rc = ReplicaConfig(2).with_role("prefill")
    assert rc.role == "prefill" and "prefill" in str(rc)
    assert rc.with_role("mixed") == ReplicaConfig(2)
    with pytest.raises(ValueError, match="role"):
        ReplicaConfig(2, role="draft")


# ---------------------------------------------------------------------------
# The routing gate: phase vs role, with the router arguing the other way.
# ---------------------------------------------------------------------------


def test_route_never_admits_new_requests_on_decode_replica(cfg_params):
    """Even with the plan's fractions pointing ALL traffic at the decode
    replica, every submission must land on the prefill one: the role gate
    narrows the router's mask before it argmaxes."""
    cfg, params = cfg_params
    rt = _disagg_runtime(cfg, params, fractions=((0.0,), (1.0,)))
    for rid, (p, n) in enumerate(_jobs(cfg, n=4)):
        assert rt.submit(rid, p, n) == 0, \
            "a new (prefill-phase) request was routed to a decode replica"


def test_route_decode_phase_avoids_prefill_replica(cfg_params):
    """The other direction, at the ``_route`` level the snapshot restore
    path uses: a decode-phase request must pick the decode replica even
    when the fractions argue for the prefill one."""
    cfg, params = cfg_params
    rt = _disagg_runtime(cfg, params, fractions=((1.0,), (0.0,)))
    assert rt._route(0, 16, 4, phase="decode") == 1
    assert rt._route(0, 16, 4, phase="prefill") == 0


def test_route_gate_relaxes_when_no_compatible_replica(cfg_params):
    """Roles are a preference, not a law: with the decode replica dead, a
    decode-phase request routes to the prefill survivor (and vice versa)
    rather than shedding — degrade, never wedge."""
    cfg, params = cfg_params
    rt = _disagg_runtime(cfg, params)
    rt.fail_replica(1)
    assert rt._route(0, 16, 4, phase="decode") == 0
    rt2 = _disagg_runtime(cfg, params)
    rt2.fail_replica(0)
    assert rt2._route(0, 16, 4, phase="prefill") == 1


# ---------------------------------------------------------------------------
# The handoff: every request moves exactly once, zero recompute, parity.
# ---------------------------------------------------------------------------


def test_handoff_zero_recompute_with_parity(cfg_params):
    cfg, params = cfg_params
    jobs = _jobs(cfg)
    rt = _disagg_runtime(cfg, params)
    for rid, (p, n) in enumerate(jobs):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert not rt.all_shed_rids
    # every request: admitted on the prefill replica, handed off exactly
    # once at first token, finished on the decode replica
    assert rep.handoffs == len(jobs)
    assert rep.handoff.handoff == len(jobs), \
        "a same-pool handoff left the zero-byte page path"
    assert rep.handoff.recompute_tokens == 0
    assert rt.total_prefill_tokens == sum(len(p) for p, _ in jobs), \
        "the decode replica recomputed prefill work"
    stats = rt.load_stats()
    assert stats[0]["handoff_out"] == len(jobs)
    assert stats[1]["handoff_in"] == len(jobs)
    assert set(rep.role_util) == {"prefill", "decode"}
    expected = _reference(cfg, params, jobs)
    for rid in range(len(jobs)):
        assert rt.results[rid].generated == expected[rid], \
            f"rid {rid} diverged across the prefill->decode handoff"


# ---------------------------------------------------------------------------
# Chaos: the prefill replica dies mid-handoff traffic.
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_prefill_replica_death_recovers_onto_decode_survivor(cfg_params):
    """Crash the prefill replica while requests are queued and mid-prefill
    on it: recovery must relax the role gate and move everything onto the
    decode survivor (handoff for first-token-ready residents, re-prefill /
    requeue for the rest), completing all requests with greedy parity."""
    cfg, params = cfg_params
    jobs = _jobs(cfg)
    # tick 1 admits + hands off the first wave and leaves the second wave
    # queued on the prefill replica; the tick-2 crash therefore hits a
    # replica that still owns queued work (handed-off residents are
    # already safe on the decode replica)
    faults = FaultPlan([FaultSpec("crash", 2, replica=0)])
    rt = _disagg_runtime(cfg, params, faults=faults)
    for rid, (p, n) in enumerate(jobs):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.dead_replicas == [0], "the armed crash never fired"
    assert rep.recovery.migrated + rep.recovery.requeued >= 1, \
        "the prefill replica's requests were not recovered"
    assert not rt.all_shed_rids, \
        "recovery shed despite a live (decode-role) survivor"
    expected = _reference(cfg, params, jobs)
    for rid in range(len(jobs)):
        assert rt.results[rid].generated == expected[rid], \
            f"rid {rid} diverged through prefill-replica death recovery"
    # new submissions keep working on the decode-role survivor
    extra = np.arange(8, dtype=np.int32)
    assert rt.submit(len(jobs), extra, 4) == 1
    rt.run_until_idle()
    assert len(jobs) in rt.results
