"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

R = np.random.RandomState(0)


def arr(*shape, dtype=np.float32, scale=0.5):
    return jnp.asarray(R.randn(*shape).astype(dtype) * scale)


FLASH_CASES = [
    # B, Sq, Hq, Hkv, D, softcap, window
    (2, 256, 4, 2, 64, 0.0, 0),
    (1, 128, 8, 8, 128, 50.0, 0),
    (2, 256, 4, 4, 64, 0.0, 64),       # local window
    (1, 200, 6, 2, 96, 0.0, 0),        # non-multiple seq + head_dim
    (1, 128, 2, 1, 256, 0.0, 0),       # gemma2-style head_dim
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,cap,win", FLASH_CASES)
def test_flash_attention_matches_ref(B, S, Hq, Hkv, D, cap, win):
    q = arr(B, S, Hq, D)
    k = arr(B, S, Hkv, D)
    v = arr(B, S, Hkv, D)
    o = ops.flash_attention(q, k, v, softcap=cap, window=win, interpret=True)
    r = ref.flash_attention_ref(q, k, v, softcap=cap, window=win)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


def test_flash_attention_bf16():
    q = arr(1, 128, 4, 64).astype(jnp.bfloat16)
    k = arr(1, 128, 2, 64).astype(jnp.bfloat16)
    v = arr(1, 128, 2, 64).astype(jnp.bfloat16)
    o = ops.flash_attention(q, k, v, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=2e-2)


DECODE_CASES = [
    (3, 256, 4, 2, 64),
    (2, 128, 8, 8, 128),
    (2, 100, 4, 1, 64),     # ragged S
    (1, 64, 25, 5, 64),     # hymba-style heads
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D", DECODE_CASES)
def test_flash_decode_matches_ref(B, S, Hq, Hkv, D):
    q = arr(B, Hq, D)
    k = arr(B, S, Hkv, D)
    v = arr(B, S, Hkv, D)
    lens = jnp.asarray(R.randint(1, S + 1, B), jnp.int32)
    o = ops.flash_decode(q, k, v, lens, interpret=True)
    r = ref.flash_decode_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


def test_flash_decode_paged_matches_ref():
    B, pages, page, Hkv, Hq, D, maxp = 3, 32, 16, 2, 4, 64, 8
    q = arr(B, Hq, D)
    kp = arr(pages, page, Hkv, D)
    vp = arr(pages, page, Hkv, D)
    tbl = jnp.asarray(R.randint(0, pages, (B, maxp)), jnp.int32)
    lens = jnp.asarray(R.randint(1, maxp * page, B), jnp.int32)
    o = ops.flash_decode_paged(q, kp, vp, tbl, lens, interpret=True)
    r = ref.flash_decode_paged_ref(q, kp, vp, tbl, lens)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


SSD_CASES = [
    (2, 3, 64, 4, 64, 32),
    (1, 2, 128, 2, 64, 128),    # mamba2-370m block shape
    (1, 1, 64, 8, 32, 16),      # hymba-style small state
]


@pytest.mark.parametrize("B,Nc,Q,H,P,N", SSD_CASES)
def test_ssd_chunk_matches_ref(B, Nc, Q, H, P, N):
    x = arr(B, Nc, Q, H, P, scale=0.3)
    dt = jnp.abs(arr(B, Nc, Q, H, scale=0.05)) + 0.01
    A = -jnp.abs(arr(H, scale=1.0))
    Bm = arr(B, Nc, Q, H, N, scale=0.3)
    Cm = arr(B, Nc, Q, H, N, scale=0.3)
    y, S_ = ops.ssd_chunk(x, dt, A, Bm, Cm, interpret=True)
    yr, Sr = ref.ssd_chunk_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_), np.asarray(Sr), atol=1e-4)


def test_ssd_kernel_matches_recurrent_oracle():
    """Chunked kernel path == token-by-token recurrence (independent oracle)."""
    from repro.models.ssm import ssd_reference
    B, L, H, P, N, Q = 1, 128, 2, 32, 16, 64
    x = arr(B, L, H, P, scale=0.3)
    dt = jnp.abs(arr(B, L, H, scale=0.05)) + 0.01
    A = -jnp.abs(arr(H))
    Bm = arr(B, L, 1, N, scale=0.3)
    Cm = arr(B, L, 1, N, scale=0.3)
    y_rec, s_rec = ssd_reference(x, dt, A, Bm, Cm)
    Bh = jnp.repeat(Bm, H, axis=2).reshape(B, L // Q, Q, H, N)
    Ch = jnp.repeat(Cm, H, axis=2).reshape(B, L // Q, Q, H, N)
    y_k, S_k = ops.ssd_chunk(x.reshape(B, L // Q, Q, H, P),
                             dt.reshape(B, L // Q, Q, H), A, Bh, Ch,
                             interpret=True)
    # combine across chunks like models.ssm does
    import jax as _jax
    a_tot = jnp.exp(jnp.sum(dt.reshape(B, L // Q, Q, H)
                            * A[None, None, None, :], axis=2))

    def comb(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, s1 * a2[..., None, None] + s2

    _, S_run = _jax.lax.associative_scan(comb, (a_tot, S_k), axis=1)
    np.testing.assert_allclose(np.asarray(S_run[:, -1]), np.asarray(s_rec),
                               atol=1e-3)
