"""Serving telemetry: tracer, metrics, decision audit, Chrome-trace export.

Covers the tentpole acceptance bar (ISSUE 8): a 2-span heterogeneous-switch
run exports a *valid* Chrome trace-event JSON with one track per replica,
per-request flow arrows across migrations, and switch-phase begin/end
spans; the fake-clock engine test proves timestamps come from the
injectable clock (deterministic TTFT); the frozen ``load_stats`` schema is
pinned; and the chaos-marked completeness test asserts that under a seeded
fault plan (replica crash + a switch that fails mid-migration) every
submitted request's event stream still ends in exactly one terminal event
and every migration pairs a source with a destination replica.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.costmodel import CostModel
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.types import (ClusterSpec, Deployment, H100_SPEC,
                              ReplicaConfig, WorkloadType)
from repro.models import init_params
from repro.serving.cluster import ClusterRuntime
from repro.serving.engine import LOAD_STATS_KEYS, ServingEngine
from repro.serving.faults import FaultPlan
from repro.serving.router import FlowRouter
from repro.serving.telemetry import (ORCH_TID, TERMINAL_KINDS, DecisionAudit,
                                     Histogram, Telemetry, Tracer,
                                     export_chrome_trace,
                                     validate_chrome_trace)

ARCH = [WorkloadType(1275, 287), WorkloadType(139, 133),
        WorkloadType(1181, 1824), WorkloadType(282, 1121)]


def ws(rates):
    return [a.with_rate(float(r)) for a, r in zip(ARCH, rates)]


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.125            # deterministic, strictly increasing
        return self.t


# ---------------------------------------------------------------------------
# Primitives: tracer ring buffer, disabled no-op, histogram quantiles.
# ---------------------------------------------------------------------------


def test_tracer_ring_bound_and_disabled_noop():
    tr = Tracer(clock=FakeClock(), capacity=4)
    for i in range(10):
        tr.emit("submit", rid=i)
    assert len(tr.events) == 4
    assert tr.dropped == 6
    assert [e.rid for e in tr.events] == [6, 7, 8, 9]   # oldest evicted

    off = Telemetry(enabled=False)
    off.emit("submit", rid=0)
    off.metrics.count("x")
    off.metrics.observe("h", 1.0)
    off.audit.record_realized(None)      # must not even touch the report
    assert not off.tracer.events and not off.metrics.counters
    assert not off.metrics.histograms and not off.audit.records


def test_histogram_log_bucket_percentiles():
    h = Histogram()
    vals = [0.001 * (i + 1) for i in range(1000)]      # 1ms .. 1s uniform
    for v in vals:
        h.record(v)
    assert h.count == 1000
    assert h.min == pytest.approx(0.001) and h.max == pytest.approx(1.0)
    assert h.mean == pytest.approx(np.mean(vals))
    # log-bucketed: ~5% relative resolution at base 1.1
    for p in (50, 95, 99):
        exact = float(np.percentile(vals, p))
        assert h.percentile(p) == pytest.approx(exact, rel=0.11)
    # clamped to observed range
    assert h.percentile(0) >= h.min and h.percentile(100) <= h.max
    h2 = Histogram()
    h2.record(-1.0)                      # underflow bucket
    assert h2.percentile(50) == 0.0


def test_validator_rejects_malformed_traces():
    ok = {"traceEvents": [
        {"ph": "B", "name": "sw", "pid": 0, "tid": 1, "ts": 0},
        {"ph": "E", "name": "sw", "pid": 0, "tid": 1, "ts": 5}]}
    assert validate_chrome_trace(ok)["be_pairs"] == 1
    bad_pairs = {"traceEvents": [
        {"ph": "B", "name": "sw", "pid": 0, "tid": 1, "ts": 0}]}
    with pytest.raises(ValueError, match="unclosed"):
        validate_chrome_trace(bad_pairs)
    bad_flow = {"traceEvents": [
        {"ph": "s", "name": "m", "pid": 0, "tid": 1, "ts": 0, "id": "a"}]}
    with pytest.raises(ValueError, match="unpaired"):
        validate_chrome_trace(bad_flow)
    bad_dur = {"traceEvents": [
        {"ph": "X", "name": "r", "pid": 0, "tid": 1, "ts": 0, "dur": -1}]}
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(bad_dur)


# ---------------------------------------------------------------------------
# Decision audit: FIFO join, calibration error, replica-count mismatch.
# ---------------------------------------------------------------------------


class _Plan:
    def __init__(self, rcs, fractions, throughput=10.0):
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions
        self.throughput = throughput
        self.kv_migration_seconds = 0.0


class _Report:
    def __init__(self, tokens, completed=0):
        self.tokens = tokens
        self.completed = completed


def test_audit_fifo_join_and_calibration():
    audit = DecisionAudit()
    # two replicas, all traffic to replica 0 for type 0, etc.
    plan = _Plan([ReplicaConfig(1, 1)] * 2, [[1.0, 0.0], [0.0, 1.0]])
    w = [WorkloadType(10, 10, rate=3.0), WorkloadType(10, 10, rate=1.0)]
    audit.record_plan(plan, w, hysteresis_margin=0.1, switched=True)
    assert audit.records[0].predicted_share == pytest.approx([0.75, 0.25])
    assert not audit.records[0].joined
    # realized exactly the predicted split -> zero error
    audit.record_realized(_Report([75, 25], completed=4))
    assert audit.records[0].joined
    assert audit.calibration_error() == pytest.approx(0.0)
    # second decision realized fully inverted -> L1 = 1.0, mean 0.5
    audit.record_plan(plan, w)
    audit.record_realized(_Report([25, 75]))
    assert audit.calibration_error() == pytest.approx(0.5)
    # replica-count mismatch (death mid-span) scores the 2.0 sentinel
    audit.record_plan(plan, w)
    audit.record_realized(_Report([100]))
    assert audit.records[2].share_l1 == 2.0


# ---------------------------------------------------------------------------
# Frozen load_stats schema (engine + cluster adds "dead").
# ---------------------------------------------------------------------------


def test_load_stats_schema_frozen(cfg_params):
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=32, block_size=8, max_seqs=2)
    assert set(eng.load_stats()) == set(LOAD_STATS_KEYS), \
        "engine load_stats keys drifted from the frozen schema"
    rt = ClusterRuntime(cfg, params, total_chips=2, blocks_per_chip=16,
                        seqs_per_chip=2, block_size=8,
                        router=FlowRouter([[1.0]]))
    rt.apply_plan(_Plan([ReplicaConfig(1, 1)], [[1.0]]))
    (d,) = rt.load_stats()
    assert set(d) == set(LOAD_STATS_KEYS) | {"dead"}, \
        "cluster load_stats keys drifted from the frozen schema"


# ---------------------------------------------------------------------------
# Engine lifecycle with an injected clock: deterministic trace + TTFT.
# ---------------------------------------------------------------------------


def test_engine_trace_deterministic_with_fake_clock(cfg_params):
    cfg, params = cfg_params

    def run():
        tm = Telemetry(clock=FakeClock())
        eng = ServingEngine(cfg, params, num_blocks=64, block_size=8,
                            max_seqs=2, telemetry=tm)
        assert eng.clock is tm.clock     # unified timekeeping
        rng = np.random.RandomState(3)
        for i in range(2):
            eng.submit(i, rng.randint(0, cfg.vocab_size, 8)
                       .astype(np.int32), 4)
        eng.run_to_completion()
        return tm

    a, b = run(), run()
    assert [(e.kind, e.ts, e.rid) for e in a.tracer.events] == \
           [(e.kind, e.ts, e.rid) for e in b.tracer.events]
    kinds = {e.kind for e in a.tracer.events}
    assert {"submit", "admit", "first_token", "dispatch", "sync",
            "retire"} <= kinds
    # TTFT is measured on the fake clock, hence identical across runs
    ttft = a.metrics.histograms["ttft_s"].summary()
    assert ttft["count"] == 2
    assert ttft == b.metrics.histograms["ttft_s"].summary()
    # each request: one submit, one first_token, one terminal
    for rid, evs in a.tracer.by_request().items():
        ks = [e.kind for e in evs]
        assert ks.count("submit") == 1 and ks.count("first_token") == 1
        assert sum(1 for k in ks if k in TERMINAL_KINDS) == 1


# ---------------------------------------------------------------------------
# Acceptance: 2-span orchestrated heterogeneous switch -> valid Chrome
# trace with per-replica tracks, migration flows, and switch-phase spans.
# ---------------------------------------------------------------------------


def test_orchestrated_switch_exports_valid_trace(cfg_params, tmp_path):
    cfg, params = cfg_params
    cm = CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)
    orch = Orchestrator(cm, ClusterSpec(6, hw=H100_SPEC),
                        OrchestratorConfig(search_patience=10))
    tm = Telemetry()
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=16,
                        seqs_per_chip=1, block_size=8, drain_steps=0,
                        telemetry=tm)
    rng = np.random.RandomState(0)
    rid = 0
    for rates in ([5, 300, 2, 3], [40, 10, 60, 40]):
        plan = orch.plan_span(ws(rates))
        rt.apply_plan(plan)
        for _ in range(6):
            t = int(rng.randint(0, 4))
            prompt = rng.randint(0, cfg.vocab_size,
                                 6 + 2 * t).astype(np.int32)
            rt.submit(rid, prompt, 8 + t, type_id=t)
            rid += 1
        for _ in range(4):
            rt.step()
        rt.finish_span()
    rt.run_until_idle()
    assert len(rt.results) == rid

    kinds = {e.kind for e in tm.tracer.events}
    assert "migrate" in kinds, "the heterogeneous switch migrated nothing"
    assert "switch_prepare" in kinds and "switch_commit" in kinds

    # the export round-trips through JSON and validates
    out = tmp_path / "trace.json"
    export_chrome_trace(tm, path=str(out))
    obj = json.loads(out.read_text())
    counts = validate_chrome_trace(obj)
    tids = {e["tid"] for e in obj["traceEvents"] if e["ph"] != "M"}
    assert len(tids - {ORCH_TID}) >= 2, "need one track per replica"
    assert ORCH_TID in tids
    assert counts["flows"] >= 1, "migrations must draw flow arrows"
    assert counts["be_pairs"] >= 2, "switch phases must pair begin/end"
    assert counts["slices"] >= rid, "every request needs residency slices"
    # every track carries a thread_name metadata record
    named = {e["tid"] for e in obj["traceEvents"] if e["ph"] == "M"}
    assert tids <= named

    # decision audit joined both spans
    assert sum(1 for r in tm.audit.records if r.joined) == 2
    assert np.isfinite(tm.audit.calibration_error())

    # latency histograms populated: exactly one TTFT/TPOT per request
    # (migrated re-prefills must not re-enter), >= one queue delay (a
    # re-prefill migration re-admits and is counted again)
    assert tm.metrics.histograms["ttft_s"].count == rid
    assert tm.metrics.histograms["tpot_s"].count == rid
    assert tm.metrics.histograms["queue_delay_s"].count >= rid


# ---------------------------------------------------------------------------
# Chaos: trace completeness under a seeded crash + mid-switch failure.
# ---------------------------------------------------------------------------


@pytest.mark.chaos
@pytest.mark.parametrize("case,kw", [
    ("crash", dict(crashes=1, stalls=0)),
    ("failed-switch", dict(crashes=0, stalls=0,
                           switch_failure="switch_migrate")),
    ("crash+failed-switch", dict(crashes=1, stalls=0,
                                 switch_failure="switch_migrate")),
])
def test_trace_complete_under_chaos(cfg_params, case, kw):
    cfg, params = cfg_params
    faults = FaultPlan.seeded(11, n_replicas=2, horizon_ticks=6, **kw)
    tm = Telemetry()
    rt = ClusterRuntime(cfg, params, total_chips=4, blocks_per_chip=32,
                        seqs_per_chip=4, block_size=8, drain_steps=1,
                        router=FlowRouter([[0.5], [0.5]]), faults=faults,
                        telemetry=tm)
    rt.apply_plan(_Plan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                        [[0.5], [0.5]]))
    rng = np.random.RandomState(7)
    for rid in range(8):
        prompt = rng.randint(0, cfg.vocab_size,
                             6 + (rid % 3) * 2).astype(np.int32)
        rt.submit(rid, prompt, 6 + (rid % 4))
    for _ in range(6):
        rt.step()
    # switch ordinal 2: the target of the switch_migrate fault
    rt.apply_plan(_Plan([ReplicaConfig(2, 1), ReplicaConfig(1, 1)],
                        [[0.6], [0.4]]))
    rt.run_until_idle()
    rt.finish_span()

    # exactly one terminal event per submitted request, no extras
    submitted = {e.rid for e in tm.tracer.events if e.kind == "submit"}
    assert submitted == set(range(8))
    terminals: dict[int, int] = {}
    for e in tm.tracer.events:
        if e.kind in TERMINAL_KINDS:
            terminals[e.rid] = terminals.get(e.rid, 0) + 1
    assert terminals.keys() == submitted, \
        f"{case}: requests without a terminal event"
    assert all(c == 1 for c in terminals.values()), \
        f"{case}: duplicated terminal events {terminals}"

    # every migration names a real source and destination replica
    n_rep = len(rt.replicas)
    for e in tm.tracer.events:
        if e.kind == "migrate":
            assert 0 <= e.data["src"] < n_rep
            assert 0 <= e.data["dst"] < n_rep
            assert e.data["path"] in ("handoff", "copy", "reprefill",
                                      "requeue")

    # crash events balance recovery events, and the export stays valid
    n_crash = sum(1 for e in tm.tracer.events if e.kind == "crash")
    n_recov = sum(1 for e in tm.tracer.events if e.kind == "recovered")
    assert n_crash == n_recov
    if kw.get("crashes"):
        assert n_crash >= 1
    counts = validate_chrome_trace(export_chrome_trace(tm))
    assert counts["events"] > 0


# ---------------------------------------------------------------------------
# Live rebalancing (ISSUE 9): rebalance events render as flow arrows, the
# trace still validates, and a preempted-then-resumed request keeps the
# one-terminal-per-rid invariant.
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_rebalance_trace_flows_valid(cfg_params, tmp_path):
    from repro.serving.cluster import RebalanceConfig
    from repro.serving.faults import FaultSpec

    faults = FaultPlan([FaultSpec("stall", 2, replica=0, steps=10_000)])
    tm = Telemetry()
    rt = ClusterRuntime(cfg_params[0], cfg_params[1], total_chips=4,
                        blocks_per_chip=32, seqs_per_chip=4, block_size=8,
                        drain_steps=1, router=FlowRouter([[0.5], [0.5]]),
                        faults=faults, telemetry=tm,
                        rebalance=RebalanceConfig(max_moves_per_tick=4))
    rt.apply_plan(_Plan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                        [[0.5], [0.5]]))
    rng = np.random.RandomState(7)
    for rid in range(8):
        prompt = rng.randint(0, cfg_params[0].vocab_size,
                             6 + (rid % 3) * 2).astype(np.int32)
        rt.submit(rid, prompt, 6 + (rid % 4))
    rt.run_until_idle()
    rt.finish_span()

    kinds = {e.kind for e in tm.tracer.events}
    assert "rebalance" in kinds, "watchdog drains must emit rebalance events"
    assert "degraded" in kinds, "the watchdog must announce degradation"
    for e in tm.tracer.events:
        if e.kind == "rebalance":
            assert 0 <= e.data["src"] < 2 and 0 <= e.data["dst"] < 2
            assert e.data["path"] in ("handoff", "copy", "reprefill",
                                      "requeue")
    out = tmp_path / "trace.json"
    export_chrome_trace(tm, path=str(out))
    counts = validate_chrome_trace(json.loads(out.read_text()))
    assert counts["flows"] >= 1, "rebalances must draw flow arrows"


def test_preempt_evict_resume_one_terminal(cfg_params):
    """Eviction closes the victim's residency but is NOT terminal: the
    resumed request retires exactly once, and the preempt event carries
    the action and the waiter it made room for."""
    cfg, params = cfg_params
    tm = Telemetry()
    rt = ClusterRuntime(cfg, params, total_chips=4, blocks_per_chip=32,
                        seqs_per_chip=4, block_size=8, drain_steps=1,
                        router=FlowRouter([[0.5], [0.5]]), telemetry=tm,
                        rebalance=True)
    rt.apply_plan(_Plan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                        [[0.5], [0.5]]))
    rng = np.random.RandomState(3)
    for rid in range(10):
        prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
        rt.submit(rid, prompt, 8)
    for _ in range(3):
        rt.step()                    # both replicas saturated
    rt.submit(10, np.arange(8, dtype=np.int32), 6, priority=2)
    rt.step()
    rt.run_until_idle()
    rt.finish_span()

    evs = [e for e in tm.tracer.events if e.kind == "preempt"]
    assert evs, "saturated replicas must preempt for the high-pri waiter"
    assert all(e.data["action"] in ("relocate", "evict") for e in evs)
    assert all(e.data["for_rid"] == 10 for e in evs)
    terminals: dict[int, int] = {}
    for e in tm.tracer.events:
        if e.kind in TERMINAL_KINDS:
            terminals[e.rid] = terminals.get(e.rid, 0) + 1
    assert terminals.keys() == set(range(11))
    assert all(c == 1 for c in terminals.values()), \
        f"preempted-then-resumed requests duplicated terminals: {terminals}"
