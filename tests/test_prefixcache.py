"""Prefix-cache correctness (ISSUE 7).

Greedy token parity cache-on vs cache-off across every prefill/decode mode
(one-shot, chunked prefill resuming mid-prompt, partial-page COW
divergence, horizon decode over shared pages), evict -> restore roundtrip
parity through the host tier, the counted-once / decref accounting
contract on the shared allocator, and the planner-side hit-rate discount.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.costmodel import CostModel
from repro.core.types import H100_SPEC, ReplicaConfig, WorkloadType
from repro.models import init_params
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import BlockPool, gather_tokens, scatter_tokens
from repro.serving.prefixcache import PrefixCache
from repro.serving.request import shared_prefix_prompts

BS = 8


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _jobs(cfg, n=6, prefix=24, tail=6, seed=3, n_templates=1):
    prompts = shared_prefix_prompts(n, prefix, tail, vocab=cfg.vocab_size,
                                    seed=seed, n_templates=n_templates)
    return [(p, 4 + (i % 3)) for i, p in enumerate(prompts)]


def _run(cfg, params, jobs, *, cache, num_blocks=64, max_seqs=2, **kw):
    """Run jobs to completion; small ``max_seqs`` staggers admissions so
    later requests admit after earlier ones published their pages."""
    eng = ServingEngine(cfg, params, num_blocks=num_blocks, block_size=BS,
                        max_seqs=max_seqs, prefix_cache=cache, **kw)
    for rid, (p, n) in enumerate(jobs):
        eng.submit(rid, p, n)
    out = {r.rid: list(r.generated) for r in eng.run_to_completion()}
    return out, eng


# ---------------------------------------------------------------------------
# greedy parity cache-on vs cache-off, per prefill/decode mode
# ---------------------------------------------------------------------------


def test_one_shot_parity_and_prefill_savings(cfg_params):
    cfg, params = cfg_params
    jobs = _jobs(cfg)
    ref, eng_off = _run(cfg, params, jobs, cache=False)
    got, eng_on = _run(cfg, params, jobs, cache=True)
    assert got == ref
    pc = eng_on.prefix_cache
    assert pc is not None and pc.hits > 0
    assert eng_on.prefill_tokens < eng_off.prefill_tokens, \
        "cache hits did not reduce prefill-forward tokens"


def test_chunked_prefill_resumes_mid_prompt(cfg_params):
    cfg, params = cfg_params
    jobs = _jobs(cfg)
    ref, eng_off = _run(cfg, params, jobs, cache=False,
                        prefill_chunk_tokens=BS)
    got, eng_on = _run(cfg, params, jobs, cache=True,
                       prefill_chunk_tokens=BS)
    assert got == ref
    assert eng_on.prefix_cache.hits > 0
    assert eng_on.prefill_tokens < eng_off.prefill_tokens


def test_partial_page_cow_divergence(cfg_params):
    """Identical prompts: the match is capped at prompt_len - 1, which lands
    mid-page, so the last matched page attaches by copy (COW).  The copy
    must not perturb the shared original — every repeat stays at parity."""
    cfg, params = cfg_params
    rng = np.random.RandomState(11)
    prompt = rng.randint(0, cfg.vocab_size, 3 * BS).astype(np.int32)
    jobs = [(prompt, 4 + i) for i in range(3)]     # divergent decode lengths
    ref, _ = _run(cfg, params, jobs, cache=False, max_seqs=1)
    got, eng = _run(cfg, params, jobs, cache=True, max_seqs=1)
    assert got == ref
    # repeats prefilled only the final prompt token (the COW page carries
    # the rest): 3*BS + 1 + 1 forward tokens total
    assert eng.prefill_tokens == 3 * BS + 2
    assert eng.prefix_cache.hits == 2


def test_horizon_decode_over_shared_pages(cfg_params):
    cfg, params = cfg_params
    jobs = _jobs(cfg)
    ref, _ = _run(cfg, params, jobs, cache=False, decode_horizon=4)
    got, eng = _run(cfg, params, jobs, cache=True, decode_horizon=4)
    assert got == ref
    assert eng.prefix_cache.hits > 0


# ---------------------------------------------------------------------------
# host tier: evict -> restore roundtrip
# ---------------------------------------------------------------------------


def test_evict_restore_roundtrip_parity(cfg_params):
    """A pool too small to keep two templates' cached pages resident forces
    the LRU evict -> host tier -> restore roundtrip (the off-duty template's
    pages get pushed out while the other runs, then restored on its next
    request); token output must still match the cache-off run exactly.

    Pure-template prompts (no unique tail): unique tail pages would absorb
    all the eviction pressure and never be re-matched."""
    cfg, params = cfg_params
    jobs = _jobs(cfg, n=8, prefix=32, tail=0, n_templates=2)
    ref, _ = _run(cfg, params, jobs, cache=False, num_blocks=64, max_seqs=1)
    got, eng = _run(cfg, params, jobs, cache=True, num_blocks=9, max_seqs=1)
    assert got == ref
    pc = eng.prefix_cache
    assert pc.evicted_bytes > 0, "tiny pool never evicted to the host tier"
    assert pc.restored_bytes > 0, "no cache hit restored a host-tier page"


def test_evict_restore_preserves_bytes(cfg_params):
    """Pool-level fidelity: evicting a page to host and restoring it yields
    bit-identical K/V, independent of any model forward."""
    cfg, _ = cfg_params
    pool = BlockPool(cfg, 4, BS, jnp.float32, 1)
    pc = PrefixCache(pool)
    (b,) = pool.allocator.alloc(1)
    rng = np.random.RandomState(5)
    L, H, D = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jnp.asarray(rng.randn(L, BS, H, D), jnp.float32)
    v = jnp.asarray(rng.randn(L, BS, H, D), jnp.float32)
    scatter_tokens(pool, [b], k, v)
    tokens = rng.randint(0, 100, BS).astype(np.int32)
    pc.publish(tokens, [b])
    pool.allocator.release([b])                 # index ref remains: cold
    (e,) = pc.index.values()
    pc._evict(e)
    assert e.block is None and pool.allocator.n_free == 4
    m = pc.match(tokens, BS)                    # full page may match here
    cached, shared, cow = pc.attach(m)
    assert cached == BS and e.block is not None
    k2, v2 = gather_tokens(pool, [e.block], BS)
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v))


# ---------------------------------------------------------------------------
# index / refcount contract
# ---------------------------------------------------------------------------


def test_match_requires_identical_prefix(cfg_params):
    cfg, _ = cfg_params
    pool = BlockPool(cfg, 8, BS, jnp.float32, 1)
    pc = PrefixCache(pool)
    rng = np.random.RandomState(9)
    stream = rng.randint(0, 100, 3 * BS).astype(np.int32)
    blocks = pool.allocator.alloc(3)
    pc.publish(stream, blocks)
    # identical stream: all 3 pages match, capped at prompt_len - 1 — the
    # cap lands mid-page, so the last page attaches copy-on-write
    m = pc.match(stream, 3 * BS - 1)
    assert m.cached_tokens == 3 * BS - 1 and m.cow
    # divergence inside page 1 kills pages 1 and 2 (chained keys)
    other = stream.copy()
    other[BS + 2] += 1
    m = pc.match(other, 3 * BS - 1)
    assert m.cached_tokens == BS and not m.cow
    # divergence at token 0: nothing matches
    other2 = stream.copy()
    other2[0] += 1
    assert pc.match(other2, 3 * BS - 1).cached_tokens == 0


def test_shared_pages_counted_once_and_decref(cfg_params):
    """After a cached run drains: every sequence reservation is returned,
    no block is double-freed, and the only remaining refs are the index's
    own (cold pages) — dropping them returns the pool to fully free."""
    cfg, params = cfg_params
    _, eng = _run(cfg, params, _jobs(cfg), cache=True, num_blocks=64)
    pool = eng.cache.pool
    alloc = pool.allocator
    assert pool.reserved == 0
    assert alloc.pinned == 0, "a drained pool still counts pinned pages"
    held = sum(1 for r in alloc.refs if r > 0)
    assert held + alloc.n_free == pool.num_blocks
    pc = eng.prefix_cache
    assert pc.cold_blocks() == sum(1 for e in pc.index.values()
                                   if e.block is not None)
    pc.drop_cold()
    assert alloc.n_free == pool.num_blocks


# ---------------------------------------------------------------------------
# planner-side: hit rate discounts prefill cost
# ---------------------------------------------------------------------------


def test_cached_frac_discounts_prefill_cost():
    cm = CostModel(
        __import__("repro.configs", fromlist=["get_config"])
        .get_config("opt-30b").profile(), hw=H100_SPEC)
    rc = ReplicaConfig(2, 1)
    cold = WorkloadType(1024, 256, 10.0)
    warm = cold.with_cached_frac(0.9)
    p_cold = cm.replica_perf(rc, cold)
    p_warm = cm.replica_perf(rc, warm)
    assert p_warm.prefill_time < 0.25 * p_cold.prefill_time
    assert p_warm.throughput > p_cold.throughput
    # memory term unchanged: shared pages still occupy HBM
    assert p_warm.b_eff == p_cold.b_eff
    assert warm.with_cached_frac(1.5).cached_frac == 1.0
