"""Device-resident paged decode: kernel parity + fused engine behavior.

Three layers of checks:
  * ``flash_decode_paged`` (interpret) vs dense ``flash_decode`` vs the jnp
    oracle across GQA group sizes, ragged lens, and softcaps;
  * the fused bucketed engine step vs the seed dense-gather engine,
    token-for-token under greedy sampling;
  * scheduling/compilation invariants: prefill no longer starves decode,
    and the fused step compiles O(log) distinct variants, not one per
    active-set size.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import ops, ref
from repro.models import init_params
from repro.serving.engine import ServingEngine

R = np.random.RandomState(7)


def arr(*shape, scale=0.5):
    return jnp.asarray(R.randn(*shape).astype(np.float32) * scale)


def _paged_case(B=3, pages=16, page=8, Hkv=2, group=4, D=64, maxp=4):
    Hq = Hkv * group
    q = arr(B, Hq, D)
    kp = arr(pages, page, Hkv, D)
    vp = arr(pages, page, Hkv, D)
    tbl = jnp.asarray(R.randint(0, pages, (B, maxp)), jnp.int32)
    lens = jnp.asarray(R.randint(1, maxp * page + 1, B), jnp.int32)
    return q, kp, vp, tbl, lens


def _gather(k_pages, tbl):
    k = k_pages[tbl]                      # [B, maxp, page, Hkv, D]
    B, n, p, H, D = k.shape
    return k.reshape(B, n * p, H, D)


@pytest.mark.parametrize("group", [1, 4, 8])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_vs_dense_vs_ref(group, softcap):
    q, kp, vp, tbl, lens = _paged_case(group=group)
    o_paged = ops.flash_decode_paged(q, kp, vp, tbl, lens, softcap=softcap,
                                     interpret=True)
    o_dense = ops.flash_decode(q, _gather(kp, tbl), _gather(vp, tbl), lens,
                               softcap=softcap, interpret=True)
    o_ref = ref.flash_decode_paged_ref(q, kp, vp, tbl, lens, softcap=softcap)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_ref),
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(o_dense), np.asarray(o_ref),
                               atol=3e-5)


def test_paged_start_window_masks_head():
    """`start` lower bound (local/sliding-window layers) matches the oracle."""
    q, kp, vp, tbl, lens = _paged_case(B=4, maxp=4)
    start = jnp.asarray([0, 5, 17, 30], jnp.int32)
    start = jnp.minimum(start, jnp.maximum(lens - 1, 0))
    o = ops.flash_decode_paged(q, kp, vp, tbl, lens, start=start,
                               softcap=30.0, interpret=True)
    r = ref.flash_decode_paged_ref(q, kp, vp, tbl, lens, start=start,
                                   softcap=30.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


def test_paged_batch_entry_matches_per_layer():
    """Multi-layer entry (one pallas_call per layer, hoisted reshapes)."""
    L = 3
    q, kp, vp, tbl, lens = _paged_case()
    qL = jnp.stack([q * (0.5 + i) for i in range(L)])
    kn = jnp.swapaxes(kp, 1, 2)          # kernel-native [P, Hkv, page, D]
    vn = jnp.swapaxes(vp, 1, 2)
    kL = jnp.stack([kn * (1.0 + 0.1 * i) for i in range(L)])
    vL = jnp.stack([vn * (1.0 - 0.1 * i) for i in range(L)])
    out = ops.flash_decode_paged_batch(qL, kL, vL, tbl, lens, interpret=True)
    for i in range(L):
        r = ref.flash_decode_paged_ref(
            qL[i], jnp.swapaxes(kL[i], 1, 2), jnp.swapaxes(vL[i], 1, 2),
            tbl, lens)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(r),
                                   atol=3e-5)


def _run_engine(cfg, params, jobs, mode, max_seqs=2):
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8,
                        max_seqs=max_seqs, decode_mode=mode)
    for i, (p, n) in enumerate(jobs):
        eng.submit(i, p, n)
    return {r.rid: r.generated for r in eng.run_to_completion()}, eng


def test_fused_engine_matches_dense_engine_tokens():
    """Bucketed fused paged step == seed dense-gather engine, greedy."""
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.RandomState(1)
    jobs = [(rng.randint(0, cfg.vocab_size, n).astype(np.int32), new)
            for n, new in ((8, 4), (8, 6), (12, 3))]
    got_paged, _ = _run_engine(cfg, params, jobs, "paged")
    got_dense, _ = _run_engine(cfg, params, jobs, "dense")
    assert got_paged == got_dense


def test_fused_engine_matches_dense_engine_local_window():
    """gemma2-style local/global alternation through the paged start bound."""
    cfg = get_smoke_config("gemma2-2b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.RandomState(2)
    jobs = [(rng.randint(0, cfg.vocab_size, n).astype(np.int32), new)
            for n, new in ((8, 4), (8, 5))]
    got_paged, _ = _run_engine(cfg, params, jobs, "paged")
    got_dense, _ = _run_engine(cfg, params, jobs, "dense")
    assert got_paged == got_dense


def test_kernel_impl_engine_with_head_padded_pool():
    """attn_impl="kernel" pads head_dim once at pool allocation (TPU layout);
    the Pallas path (interpret on CPU) must match the jnp path token-for-
    token over the padded pool."""
    cfg = get_smoke_config("yi-9b")            # head_dim 32 -> pool padded
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.RandomState(5)
    jobs = [(rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 4)
            for _ in range(2)]

    def run(impl):
        eng = ServingEngine(cfg, params, num_blocks=64, block_size=8,
                            max_seqs=2, attn_impl=impl)
        for i, (p, n) in enumerate(jobs):
            eng.submit(i, p, n)
        out = {r.rid: r.generated for r in eng.run_to_completion()}
        return out, eng

    got_kernel, eng = run("kernel")
    assert eng.cache.k.shape[-1] == 128        # pool allocated pre-padded
    got_jnp, _ = run("jnp")
    assert got_kernel == got_jnp


def test_mixed_prefill_decode_no_starvation():
    """Admitting prompts must not stall running decodes: every sequence that
    was active before a step gains exactly one token on that step."""
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ServingEngine(cfg, params, num_blocks=128, block_size=8, max_seqs=4)
    rng = np.random.RandomState(3)
    eng.submit(0, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 10)
    eng.step()                               # prefill request 0
    saw_mixed_step = False
    for i in range(1, 4):                    # staggered arrivals
        eng.submit(i, rng.randint(0, cfg.vocab_size, 8).astype(np.int32), 6)
        active_before = list(eng.active.values())
        counts = {r.rid: len(r.generated) for r in active_before}
        eng.step()
        if counts:
            saw_mixed_step = True            # prefill + decode in one step
        for r in active_before:
            assert len(r.generated) == counts[r.rid] + 1, (
                f"request {r.rid} starved during a prefill step")
    assert saw_mixed_step
    eng.run_to_completion()
    assert eng.cache.allocator.n_free == 128


def test_fused_step_compilations_bucketed():
    """Distinct fused-step compilations stay O(log max_seqs * log max_pages)
    — the active-set size must not leak into the jit cache key unbucketed."""
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
    rng = np.random.RandomState(4)
    for i in range(11):                      # active set sweeps 1..8 and back
        n = 6 + (i % 5) * 2
        eng.submit(i, rng.randint(0, cfg.vocab_size, n).astype(np.int32),
                   3 + (i % 6))
    eng.run_to_completion()
    n_compiles = eng._fused._cache_size()
    decode_steps = eng.steps
    # batch buckets {1,2,4,8} x page buckets {1,2,4}: well under one-per-step
    assert n_compiles <= 12, n_compiles
    assert decode_steps > n_compiles


def test_run_decode_is_gather_free():
    src = inspect.getsource(ServingEngine._run_decode)
    assert "gather_dense" not in src
