"""Workload predictor tests (k-means typing + LSTM forecasting)."""
import numpy as np
import pytest

from repro.core.predictor import (LSTMWorkloadPredictor, MovingAveragePredictor,
                                  WorkloadClusterer, count_series, kmeans,
                                  rrmse)


def test_kmeans_separates_clear_clusters(rng):
    a = rng.randn(100, 2) + [0, 0]
    b = rng.randn(100, 2) + [10, 10]
    C, labels = kmeans(np.vstack([a, b]), 2, seed=0)
    assert len(set(labels[:100])) == 1
    assert len(set(labels[100:])) == 1
    assert labels[0] != labels[150]


def test_clusterer_roundtrip(rng):
    in_l = np.concatenate([rng.lognormal(5, 0.3, 200),
                           rng.lognormal(7.5, 0.3, 200)]).astype(int)
    out_l = np.concatenate([rng.lognormal(4, 0.3, 200),
                            rng.lognormal(7, 0.3, 200)]).astype(int)
    cl, labels = WorkloadClusterer.fit(in_l, out_l, k=2, seed=0)
    again = cl.assign(in_l, out_l)
    assert (again == labels).mean() > 0.95


def test_count_series_shape():
    labels = np.array([0, 1, 1, 0])
    spans = np.array([0, 0, 1, 2])
    c = count_series(labels, spans, 2, 3)
    assert c.shape == (3, 2)
    assert c[0].tolist() == [1, 1]
    assert c[2].tolist() == [1, 0]


@pytest.fixture(scope="module")
def sin_series():
    t = np.arange(220)
    base = np.stack([50 + 30 * np.sin(2 * np.pi * t / 60),
                     25 + 10 * np.sin(2 * np.pi * t / 60 + 1.5)], axis=1)
    return np.random.RandomState(0).poisson(base).astype(float)


def test_lstm_learns_and_beats_ma(sin_series):
    lstm = LSTMWorkloadPredictor(2, window=50, hidden=24, seed=0)
    lstm.fit(sin_series[:200], epochs=150)
    preds = lstm.predict_series(sin_series)
    true = sin_series[50:]
    r_lstm = rrmse(preds[-20:], true[-20:])
    ma = MovingAveragePredictor(2, window=5)
    r_ma = rrmse(ma.predict_series(sin_series, start=50)[-20:], true[-20:])
    assert np.isfinite(r_lstm)
    assert r_lstm < r_ma            # LSTM captures the cycle, MA lags it


def test_predict_shape_and_positivity(sin_series):
    lstm = LSTMWorkloadPredictor(2, window=50, hidden=16, seed=0)
    lstm.fit(sin_series[:200], epochs=30)
    p = lstm.predict(sin_series[:120])
    assert p.shape == (2,)
    assert (p >= 0).all()


def test_aggregate_mode_returns_per_type(sin_series):
    agg = LSTMWorkloadPredictor(2, window=50, hidden=16, per_type=False,
                                seed=0)
    agg.fit(sin_series[:200], epochs=30)
    p = agg.predict(sin_series[:120])
    assert p.shape == (2,)


def test_rrmse_basics():
    assert rrmse([1, 2, 3], [1, 2, 3]) == 0.0
    assert rrmse([2, 4, 6], [1, 2, 3]) > 0.5
