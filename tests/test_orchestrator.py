"""Orchestrator control loop: span planning, switching, fault tolerance."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.costmodel import CostModel
from repro.core.orchestrator import Orchestrator
from repro.core.types import ClusterSpec, H100_SPEC, WorkloadType

ARCH = [WorkloadType(1275, 287), WorkloadType(139, 133),
        WorkloadType(1181, 1824), WorkloadType(282, 1121)]


@pytest.fixture()
def orch():
    cm = CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)
    return Orchestrator(cm, ClusterSpec(16, hw=H100_SPEC))


def ws(rates):
    return [a.with_rate(float(r)) for a, r in zip(ARCH, rates)]


def test_first_span_plans_deployment(orch):
    plan = orch.plan_span(ws([50, 600, 30, 60]))
    assert plan.deployment.total_chips == 16
    assert plan.switch_seconds == 0.0       # nothing to transfer yet
    f = np.array(plan.fractions)
    assert (f >= -1e-9).all() and (f.sum(0) <= 1.0 + 1e-6).all()


def test_stable_workload_no_switch(orch):
    p1 = orch.plan_span(ws([50, 600, 30, 60]))
    p2 = orch.plan_span(ws([52, 590, 31, 62]))
    assert p2.deployment == p1.deployment
    assert p2.changed_replicas == []


def test_switch_cost_less_than_reload(orch):
    orch.plan_span(ws([50, 2000, 30, 60]))
    # drastic regime change at saturating rates to force a re-deployment
    plan = orch.plan_span(ws([40, 60, 1500, 900]))
    assert plan.reload_seconds > 10.0
    if plan.changed_replicas:
        assert plan.switch_seconds < plan.reload_seconds / 3


def test_failure_replans_on_survivors(orch):
    orch.plan_span(ws([50, 600, 30, 60]))
    plan = orch.on_cluster_change(12, ws([50, 600, 30, 60]))
    assert plan.deployment.total_chips == 12
    assert max(c for rep in plan.placed.replicas for c in rep.chips) < 12


def test_elastic_grow(orch):
    orch.plan_span(ws([50, 600, 30, 60]))
    plan = orch.on_cluster_change(24, ws([50, 600, 30, 60]))
    assert plan.deployment.total_chips == 24


def test_straggler_health_shifts_flow(orch):
    p1 = orch.plan_span(ws([100, 3000, 200, 300]))
    if p1.deployment.dp < 2:
        pytest.skip("single-replica deployment; nothing to shift")
    orch.observe_health([0.2] + [1.0] * (p1.deployment.dp - 1))
    p2 = orch.plan_span(ws([100, 3000, 200, 300]))
    if p2.deployment == p1.deployment:
        f1 = np.array(p1.fractions)
        f2 = np.array(p2.fractions)
        assert f2[0].sum() <= f1[0].sum() + 1e-6
