"""Chaos tests: injected faults, replica-failure recovery, transactional
switches with rollback, TPOT shedding, and hang surfacing.

The acceptance bar (ISSUE 6): under a seeded fault plan injecting a replica
crash mid-decode, a stall, and a failed switch (rollback path), every
non-shed request completes with greedy token parity vs a fault-free run,
zero emitted tokens are lost, and the Switch/Span reports account the
recoveries.  Everything here runs on the CPU smoke model; the `chaos`
marker lets CI run the matrix as its own job.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.costmodel import CostModel
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.types import (ClusterSpec, Deployment, H100_SPEC,
                              ReplicaConfig, WorkloadType)
from repro.models import init_params
from repro.serving.cluster import (ClusterHangError, ClusterRuntime,
                                   RebalanceConfig)
from repro.serving.engine import ServingEngine
from repro.serving.faults import (FaultPlan, FaultSpec, InjectedOOM,
                                  ReplicaCrash, TransientDispatchError)
from repro.serving.router import FlowRouter
from repro.serving.telemetry import TERMINAL_KINDS, Telemetry

pytestmark = pytest.mark.chaos

ARCH = [WorkloadType(1275, 287), WorkloadType(139, 133),
        WorkloadType(1181, 1824), WorkloadType(282, 1121)]


def ws(rates):
    return [a.with_rate(float(r)) for a, r in zip(ARCH, rates)]


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _orchestrator(chips: int) -> Orchestrator:
    cm = CostModel(get_config("opt-30b").profile(), hw=H100_SPEC)
    return Orchestrator(cm, ClusterSpec(chips, hw=H100_SPEC),
                        OrchestratorConfig(search_patience=10))


class _Plan:
    """Minimal stand-in for SpanPlan in manual (orchestrator-less) tests."""

    def __init__(self, rcs, fractions):
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions


def _jobs(cfg, n=8, seed=7):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, 6 + (i % 3) * 2).astype(np.int32),
             6 + (i % 4)) for i in range(n)]


@pytest.fixture(scope="module")
def reference(cfg_params):
    """Fault-free greedy reference for the shared job set."""
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=8)
    for rid, (p, n) in enumerate(_jobs(cfg)):
        eng.submit(rid, p, n)
    return {r.rid: list(r.generated) for r in eng.run_to_completion()}


def _two_replica_runtime(cfg, params, faults, **kw):
    rt = ClusterRuntime(cfg, params, total_chips=4, blocks_per_chip=32,
                        seqs_per_chip=4, block_size=8, drain_steps=1,
                        router=FlowRouter([[0.5], [0.5]]), faults=faults,
                        **kw)
    rt.apply_plan(_Plan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                        [[0.5], [0.5]]))
    return rt


def _assert_all_complete_with_parity(rt, reference, n=8):
    """Every non-shed request finished with exactly the fault-free tokens
    (which also proves zero emitted tokens were lost)."""
    shed = set(rt.all_shed_rids)
    for rid in range(n):
        if rid in shed:
            continue
        assert rid in rt.results, f"rid {rid} neither finished nor shed"
        assert rt.results[rid].generated == reference[rid], \
            f"rid {rid} diverged from the fault-free run"


# ---------------------------------------------------------------------------
# Acceptance: seeded plan with a mid-decode crash + a stall + a failed
# switch (rollback), through the full Orchestrator -> ClusterRuntime loop.
# ---------------------------------------------------------------------------


def test_chaos_parity_crash_stall_failed_switch(cfg_params):
    cfg, params = cfg_params
    orch = _orchestrator(6)
    plan0 = orch.plan_span(ws([5, 300, 2, 3]))
    dp = plan0.deployment.dp
    assert dp >= 2, "need >= 2 replicas for crash-with-survivors"
    rt = ClusterRuntime(cfg, params, orch, blocks_per_chip=16,
                        seqs_per_chip=2, block_size=8, drain_steps=0)
    rt.apply_plan(plan0)
    rng = np.random.RandomState(0)
    jobs = {}
    rid = 0
    span_reports = []
    switch_reports = [rt.switch_reports[-1]]
    faults = None
    for span, rates in enumerate(([5, 300, 2, 3], [40, 10, 60, 40])):
        if span > 0:
            plan = orch.plan_span(ws(rates))
            switch_reports.append(rt.apply_plan(plan))
        for i in range(6):
            t = int(rng.randint(0, 4))
            prompt = rng.randint(0, cfg.vocab_size, 6 + 2 * t).astype(np.int32)
            jobs[rid] = (prompt, 8 + t)
            k = rt.submit(rid, prompt, 8 + t, type_id=t)
            if faults is None:
                # target the replica that actually got traffic: stall it
                # for 3 ticks, crash it mid-decode right after, and arm
                # the span-1 switch to fail mid-migration
                faults = FaultPlan([
                    FaultSpec("stall", 3, replica=k, steps=3),
                    FaultSpec("crash", 6, replica=k),
                    FaultSpec("switch_migrate", 2),
                ])
                rt.faults = faults
            rid += 1
            rt.step(); rt.step()
        if span == 1:
            rt.run_until_idle()
        span_reports.append(rt.finish_span())

    # the crash fired mid-decode and its requests were recovered
    assert faults.fired("crash") == 1
    dead_spans = [r for r in span_reports if r.dead_replicas]
    assert dead_spans, "no span accounted the dead replica"
    rec = dead_spans[0].recovery
    assert rec.migrated + rec.requeued + rec.dropped >= 1, \
        "the crashed replica's requests were not recovered"
    # the span-1 switch failed mid-migration and rolled back
    rolled = [s for s in switch_reports if s.rolled_back]
    assert rolled and "injected migration failure" in rolled[0].failure
    # every non-shed request completed with fault-free greedy parity:
    # zero emitted tokens lost through crash recovery AND rollback
    shed = set(rt.all_shed_rids)
    done = set(rt.results)
    assert shed | done == set(range(rid)), "requests lost without a trace"
    ref = ServingEngine(cfg, params, num_blocks=256, block_size=8, max_seqs=12)
    for r, (prompt, n) in jobs.items():
        ref.submit(r, prompt, n)
    expected = {r.rid: r.generated for r in ref.run_to_completion()}
    for r in sorted(done):
        assert rt.results[r].generated == expected[r], f"rid {r} diverged"
        assert len(rt.results[r].generated) == jobs[r][1]
    # degraded-mode replanning: the dead replica's chips left the budget
    assert orch.cluster.chips == rt.surviving_chips < rt.total_chips


# ---------------------------------------------------------------------------
# Seeded fault matrix (the CI chaos job): crash-during-decode (pages kept
# and lost), crash-during-switch, stall, OOM — all complete with parity.
# ---------------------------------------------------------------------------


MATRIX = {
    "crash-decode": dict(crashes=1, stalls=0),
    "crash-decode-lose-pages": dict(crashes=1, stalls=0, lose_pages=True),
    "crash-during-switch": dict(crashes=0, stalls=0,
                                switch_failure="switch_migrate"),
    "build-failure": dict(crashes=0, stalls=0,
                          switch_failure="switch_build"),
    "stall": dict(crashes=0, stalls=1),
    "oom": dict(crashes=0, stalls=0, ooms=1),
    "slow": dict(crashes=0, stalls=0, slows=1),
    "hotspot": dict(crashes=0, stalls=0, hotspots=1),
}


@pytest.mark.parametrize("case", sorted(MATRIX))
@pytest.mark.parametrize("seed", [11, 23])
def test_chaos_matrix_seeded(cfg_params, reference, case, seed):
    cfg, params = cfg_params
    faults = FaultPlan.seeded(seed, n_replicas=2, horizon_ticks=6,
                              **MATRIX[case])
    rt = _two_replica_runtime(cfg, params, faults)
    for rid, (p, n) in enumerate(_jobs(cfg)):
        rt.submit(rid, p, n)
    for _ in range(6):
        rt.step()
    # switch ordinal 2: the target of the switch_* faults
    sw = rt.apply_plan(_Plan([ReplicaConfig(2, 1), ReplicaConfig(1, 1)],
                             [[0.6], [0.4]]))
    rt.run_until_idle()
    rep = rt.finish_span()
    _assert_all_complete_with_parity(rt, reference)
    if case.startswith("crash-decode"):
        assert rep.dead_replicas, "crash did not register a death"
        if "lose-pages" in case:
            assert rep.recovery.reprefilled + rep.recovery.requeued >= 1
            assert rep.recovery.handoff == 0, \
                "untrusted pages must not be handed off"
    if case in ("crash-during-switch", "build-failure"):
        assert sw.rolled_back and sw.failure
        # the rollback restored the old configuration
        assert [h.rc for h in rt.replicas] == [ReplicaConfig(1, 1)] * 2
    if case == "oom":
        assert rep.retries >= 1, "injected OOM was not retried"
        assert not rep.dead_replicas, "a transient OOM must not kill"


def test_seeded_plans_are_deterministic():
    a = FaultPlan.seeded(42, n_replicas=3, transients=2, ooms=1,
                         switch_failure="switch_build")
    b = FaultPlan.seeded(42, n_replicas=3, transients=2, ooms=1,
                         switch_failure="switch_build")
    assert a.faults == b.faults
    c = FaultPlan.seeded(43, n_replicas=3, transients=2, ooms=1,
                         switch_failure="switch_build")
    assert a.faults != c.faults


# ---------------------------------------------------------------------------
# Retry / escalation semantics.
# ---------------------------------------------------------------------------


def test_transient_fault_retries_then_recovers(cfg_params, reference):
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("transient", 3, replica=0, steps=2)])
    rt = _two_replica_runtime(cfg, params, faults)
    for rid, (p, n) in enumerate(_jobs(cfg)):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.retries == 2
    assert not rep.dead_replicas, "bounded transients must not kill"
    assert not rt.all_shed_rids
    _assert_all_complete_with_parity(rt, reference)


def test_repeated_failures_escalate_to_death(cfg_params, reference):
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("transient", 3, replica=0, steps=50)])
    rt = _two_replica_runtime(cfg, params, faults, max_retries=3)
    for rid, (p, n) in enumerate(_jobs(cfg)):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.dead_replicas == [0]
    assert rep.retries == 4          # 3 tolerated + the escalating failure
    assert rep.recovery.migrated + rep.recovery.requeued >= 1
    _assert_all_complete_with_parity(rt, reference)


def test_crash_with_pages_kept_rides_handoff(cfg_params, reference):
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("crash", 5, replica=0)])
    rt = _two_replica_runtime(cfg, params, faults)
    # 6 jobs over 2 replicas of max_seqs=4: the survivor has slot headroom,
    # so at least one recovered sequence must ride the free handoff path
    for rid, (p, n) in enumerate(_jobs(cfg, n=6)):
        rt.submit(rid, p, n)
    for _ in range(4):
        rt.step()
    assert not rt.dead_replicas
    rt.step()                       # tick 5: the armed crash fires
    assert rt.dead_replicas == [0]
    assert rt._span_recovery.handoff >= 1, \
        "shared-pool crash recovery should hand off at least one sequence"
    assert rt._span_recovery.pages_handoff >= 1
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.dead_replicas == [0]
    _assert_all_complete_with_parity(rt, reference, n=6)


def test_crash_lose_pages_recovers_from_request_log(cfg_params, reference):
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("crash", 5, replica=0, lose_pages=True)])
    rt = _two_replica_runtime(cfg, params, faults)
    for rid, (p, n) in enumerate(_jobs(cfg)):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.dead_replicas == [0]
    assert rep.recovery.handoff == 0 and rep.recovery.copied == 0
    assert rep.recovery.reprefilled + rep.recovery.requeued >= 1
    # zero emitted tokens lost despite the device state being "gone"
    _assert_all_complete_with_parity(rt, reference)


def _shared_prefix_jobs(cfg, n=8, seed=5):
    from repro.serving.request import shared_prefix_prompts
    prompts = shared_prefix_prompts(n, 24, 4, vocab=cfg.vocab_size, seed=seed)
    return [(p, 4 + (i % 3)) for i, p in enumerate(prompts)]


def test_shared_prefix_pages_survive_replica_death(cfg_params):
    """A dead replica's sequences hold refs on prefix-cache pages also used
    by the survivor; recovery must decref, never double-free or recycle a
    shared page out from under the survivor (greedy parity proves it)."""
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("crash", 5, replica=0, lose_pages=True)])
    rt = _two_replica_runtime(cfg, params, faults, prefix_cache=True)
    jobs = _shared_prefix_jobs(cfg)
    for rid, (p, n) in enumerate(jobs):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.dead_replicas == [0]
    assert rep.prefix_hits >= 1
    # reference: fault-free cache-OFF engine — parity also proves the
    # cache+crash combination changed no tokens
    ref = ServingEngine(cfg, params, num_blocks=256, block_size=8,
                        max_seqs=8)
    for rid, (p, n) in enumerate(jobs):
        ref.submit(rid, p, n)
    expected = {r.rid: list(r.generated) for r in ref.run_to_completion()}
    shed = set(rt.all_shed_rids)
    for rid in range(len(jobs)):
        if rid in shed:
            continue
        assert rt.results[rid].generated == expected[rid], \
            f"rid {rid} diverged (shared page corrupted or double-freed)"
    # allocator sanity after the dust settles: nothing double-freed — every
    # block is either free or referenced, and the books balance
    pool = rt.pool
    held = sum(1 for r in pool.allocator.refs if r > 0)
    assert held + pool.allocator.n_free == pool.num_blocks
    assert pool.allocator.n_free >= 0


def test_log_recovery_rehits_prefix_cache(cfg_params):
    """Re-prefill-from-log recovery admits requests with prefill_pos=0, so
    they re-match the pool-scoped index (which outlives the dead engine):
    recovery itself becomes cheaper on shared-prefix traffic."""
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("crash", 6, replica=0, lose_pages=True)])
    rt = _two_replica_runtime(cfg, params, faults, prefix_cache=True)
    jobs = _shared_prefix_jobs(cfg)
    for rid, (p, n) in enumerate(jobs):
        rt.submit(rid, p, n)
    for _ in range(5):
        rt.step()
    pc = rt.pool.prefix_cache
    assert pc is not None
    hits_before = pc.hits
    rt.step()                       # tick 6: crash fires, log recovery runs
    assert rt.dead_replicas == [0]
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.recovery.reprefilled + rep.recovery.requeued >= 1
    assert pc.hits > hits_before, \
        "recovered requests re-prefilled from token 0 without re-hitting " \
        "the surviving prefix index"


def test_all_replicas_dead_sheds_instead_of_wedging(cfg_params):
    cfg, params = cfg_params
    rt = _two_replica_runtime(cfg, params, None)
    for rid, (p, n) in enumerate(_jobs(cfg, n=4)):
        rt.submit(rid, p, n)
    for _ in range(2):
        rt.step()
    rt.fail_replica(0)
    rt.fail_replica(1)
    # nothing pending (recovered-then-shed), nothing wedged
    assert rt.pending == 0
    rt.run_until_idle()            # returns immediately, no hang
    shed = set(rt.all_shed_rids)
    assert shed | set(rt.results) == set(range(4))
    with pytest.raises(ValueError):
        rt.submit(99, np.arange(4, dtype=np.int32), 4)


# ---------------------------------------------------------------------------
# run_until_idle hang surfacing (satellite).
# ---------------------------------------------------------------------------


def test_run_until_idle_raises_on_exhaustion(cfg_params):
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("stall", 1, replica=0, steps=10_000),
                        FaultSpec("stall", 1, replica=1, steps=10_000)])
    rt = _two_replica_runtime(cfg, params, faults)
    rt.submit(0, np.arange(6, dtype=np.int32), 4)
    with pytest.raises(ClusterHangError, match="still pending"):
        rt.run_until_idle(max_ticks=15)
    # strict=False restores the old poll-style behavior
    assert rt.run_until_idle(max_ticks=5, strict=False) == []


# ---------------------------------------------------------------------------
# TPOT-aware admission (satellite): mid-flight shedding + health feedback.
# ---------------------------------------------------------------------------


def test_engine_tpot_shed_mid_flight(cfg_params):
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=4)
    now = [0.0]
    eng.clock = lambda: now[0]
    eng.submit(0, np.arange(8, dtype=np.int32), 12, tpot_deadline=0.5)
    eng.submit(1, np.arange(8, dtype=np.int32), 12)   # no budget: untouched
    eng.step()                      # prefill, first tokens, t_first = 0
    eng.step()                      # second token: pace still 0 -> kept
    assert len(eng.active) == 2
    now[0] = 100.0                  # pace blows the 0.5 s/token budget
    eng.step()
    assert eng.shed_rids == [0]
    assert eng.load_stats()["shed"] == 1
    assert [r.rid for r in eng.active.values()] == [1]
    done = eng.run_to_completion()
    assert [r.rid for r in done] == [1]   # the unbudgeted request completes


def test_cluster_tpot_shed_counted_and_scales_health(cfg_params):
    cfg, params = cfg_params
    rt = _two_replica_runtime(cfg, params, None)
    now = [0.0]
    for h in rt.replicas:
        h.engine.clock = lambda: now[0]
    k0 = rt.submit(0, np.arange(8, dtype=np.int32), 12, tpot_deadline=0.5)
    k1 = rt.submit(1, np.arange(8, dtype=np.int32), 12, tpot_deadline=0.5)
    rt.step(); rt.step()
    now[0] = 100.0
    rt.step()
    assert sorted(rt.all_shed_rids) == [0, 1]
    rep = rt.finish_span()
    assert rep.shed == 2
    # every request those replicas held was shed, none served: achieved
    # collapses to 0 -> the orchestrator's capacity scaling sees the miss
    assert rep.achieved_fraction[k0] == 0.0
    assert rep.achieved_fraction[k1] == 0.0
    assert rt.pending == 0


def test_tpot_budget_survives_migration(cfg_params):
    cfg, params = cfg_params
    rt = _two_replica_runtime(cfg, params, None)
    rt.submit(0, np.arange(8, dtype=np.int32), 16, tpot_deadline=123.0)
    for _ in range(3):
        rt.step()
    rt.apply_plan(_Plan([ReplicaConfig(2, 1), ReplicaConfig(1, 1)],
                        [[0.6], [0.4]]))
    carried = [r.tpot_budget
               for h in rt.replicas
               for r in (list(h.engine.active.values()) + h.engine.waiting)]
    assert carried == [123.0]


# ---------------------------------------------------------------------------
# Live rebalancing (ISSUE 9): watchdog straggler escape, hot-spot relief,
# priority preemption, and the rebalance-on-vs-off shed acceptance bar.
# ---------------------------------------------------------------------------


def _one_terminal_per_rid(tm, rids):
    """Every submitted rid got exactly one terminal telemetry event."""
    terminals: dict[int, int] = {}
    for e in tm.tracer.events:
        if e.kind in TERMINAL_KINDS:
            terminals[e.rid] = terminals.get(e.rid, 0) + 1
    assert terminals.keys() == set(rids), "requests without a terminal event"
    assert all(c == 1 for c in terminals.values()), \
        f"duplicated terminal events: {terminals}"


def test_priority_admission_order(cfg_params):
    cfg, params = cfg_params
    eng = ServingEngine(cfg, params, num_blocks=32, block_size=8, max_seqs=1)
    eng.submit(0, np.arange(6, dtype=np.int32), 4)
    eng.submit(1, np.arange(6, dtype=np.int32), 4, priority=1)
    eng.step()
    assert [r.rid for r in eng.active.values()] == [1], \
        "the high-priority request must claim the slot first"
    done = eng.run_to_completion()
    assert {r.rid for r in done} == {0, 1}


def test_watchdog_drains_and_escapes_permanent_stall(cfg_params, reference):
    """A frozen replica used to be survivable only as a health signal; the
    watchdog now drains it (free same-pool handoffs) and escalates it to a
    real failure, so run_until_idle terminates with zero requests shed."""
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("stall", 2, replica=0, steps=10_000)])
    rt = _two_replica_runtime(cfg, params, faults,
                              rebalance=RebalanceConfig(max_moves_per_tick=4))
    for rid, (p, n) in enumerate(_jobs(cfg)):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.rebalanced >= 1, "the stalled replica was never drained"
    assert rep.rebalance.recompute_tokens == 0, \
        "same-pool watchdog drains must not recompute any tokens"
    assert rep.dead_replicas == [0], "a sustained stall must escalate"
    assert not rt.all_shed_rids
    _assert_all_complete_with_parity(rt, reference)


def test_hotspot_relief_spreads_load(cfg_params, reference):
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("hotspot", 0, replica=1, steps=4)])
    rt = _two_replica_runtime(cfg, params, faults, rebalance=True)
    for rid, (p, n) in enumerate(_jobs(cfg)):
        rt.submit(rid, p, n)          # all biased onto replica 1
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.rebalanced >= 1, "the hot spot was never relieved"
    stats = rt.load_stats()
    assert stats[0]["rebalanced_in"] >= 1, \
        "the cold replica should have received load"
    assert stats[1]["rebalanced_out"] >= 1
    assert not rt.all_shed_rids
    _assert_all_complete_with_parity(rt, reference)


def _straggler_runtime(cfg, params, faults, **kw):
    """Two wide replicas (8 slots each) so the whole job set fits on one —
    the shape the straggler acceptance run needs."""
    rt = ClusterRuntime(cfg, params, total_chips=4, blocks_per_chip=32,
                        seqs_per_chip=8, block_size=8, drain_steps=1,
                        router=FlowRouter([[0.5], [0.5]]), faults=faults,
                        **kw)
    rt.apply_plan(_Plan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                        [[0.5], [0.5]]))
    return rt


def _rebalance_acceptance_run(cfg, params, on):
    """Seeded straggler + hot-spot + priority-mix trace on a virtual clock:
    every request lands on replica 0, which freezes for 6 ticks — long
    enough to blow the per-token pace budget of anything left in place."""
    faults = FaultPlan([FaultSpec("hotspot", 0, replica=0, steps=2),
                        FaultSpec("stall", 2, replica=0, steps=6)])
    rt = _straggler_runtime(
        cfg, params, faults,
        rebalance=RebalanceConfig(max_moves_per_tick=4) if on else None)
    now = [0.0]
    for h in rt.replicas:
        h.engine.clock = lambda: now[0]
    for rid, (p, n) in enumerate(_jobs(cfg)):
        rt.submit(rid, p, n, tpot_deadline=3.0,
                  priority=1 if rid % 4 == 0 else 0)
    ticks = 0
    while rt.pending and ticks < 80:
        rt.step()
        now[0] += 1.0
        ticks += 1
    assert rt.pending == 0, "acceptance trace did not drain"
    return rt, rt.finish_span()


def test_rebalance_acceptance_fewer_shed_than_off(cfg_params, reference):
    """The ISSUE 9 bar: same seeded straggler + hot-spot + priority mix,
    rebalancing on vs off; on must shed strictly less, every completed
    request keeps greedy parity, and mid-span drains ride the free
    handoff path (zero tokens recomputed)."""
    cfg, params = cfg_params
    rt_off, rep_off = _rebalance_acceptance_run(cfg, params, on=False)
    rt_on, rep_on = _rebalance_acceptance_run(cfg, params, on=True)
    assert rep_off.shed >= 1, \
        "the straggler mix must shed without rebalancing (bar is vacuous)"
    assert rep_on.shed < rep_off.shed, \
        "rebalancing-on must shed strictly fewer requests"
    assert rep_on.rebalanced >= 1
    assert rep_on.rebalance.handoff >= 1, \
        "draining residents must ride the same-pool handoff path"
    assert rep_on.rebalance.recompute_tokens == 0, \
        "escape from the straggler must not recompute any tokens"
    _assert_all_complete_with_parity(rt_off, reference)
    _assert_all_complete_with_parity(rt_on, reference)


def test_rebalance_destination_crash_recovers(cfg_params):
    """Requests drained off a straggler land on a destination that then
    crashes: recovery must move them again (shared prefix pages decref'd,
    never double-freed), with one terminal telemetry event per rid."""
    cfg, params = cfg_params
    faults = FaultPlan([FaultSpec("hotspot", 0, replica=0, steps=2),
                        FaultSpec("stall", 2, replica=0, steps=10_000),
                        FaultSpec("crash", 7, replica=1)])
    tm = Telemetry()
    third = [[1.0 / 3], [1.0 / 3], [1.0 / 3]]
    rt = ClusterRuntime(cfg, params, total_chips=6, blocks_per_chip=32,
                        seqs_per_chip=4, block_size=8, drain_steps=1,
                        router=FlowRouter(third), faults=faults,
                        telemetry=tm, prefix_cache=True,
                        rebalance=RebalanceConfig(max_moves_per_tick=4))
    rt.apply_plan(_Plan([ReplicaConfig(1, 1)] * 3, third))
    jobs = _shared_prefix_jobs(cfg)
    for rid, (p, n) in enumerate(jobs):
        rt.submit(rid, p, n)
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.rebalanced >= 1, "the straggler was never drained"
    assert 1 in rep.dead_replicas, "the destination crash did not register"
    _one_terminal_per_rid(tm, range(len(jobs)))
    # completed requests match a fault-free cache-off reference: no tokens
    # lost and no shared page corrupted across the double move
    ref = ServingEngine(cfg, params, num_blocks=256, block_size=8,
                        max_seqs=8)
    for rid, (p, n) in enumerate(jobs):
        ref.submit(rid, p, n)
    expected = {r.rid: list(r.generated) for r in ref.run_to_completion()}
    shed = set(rt.all_shed_rids)
    for rid in range(len(jobs)):
        if rid not in shed:
            assert rt.results[rid].generated == expected[rid], \
                f"rid {rid} diverged across rebalance + crash recovery"
    # allocator books balance: nothing double-freed, nothing leaked
    pool = rt.pool
    held = sum(1 for r in pool.allocator.refs if r > 0)
    assert held + pool.allocator.n_free == pool.num_blocks


def test_preempt_evict_source_dies_before_resume(cfg_params):
    """A preemption-evicted request is parked in the host log; its source
    replica then dies before the re-prefill.  The log (not the replica) is
    the restore source, so the victim must still complete with parity."""
    cfg, params = cfg_params
    tm = Telemetry()
    rt = _two_replica_runtime(cfg, params, None, telemetry=tm,
                              rebalance=True)
    jobs = _jobs(cfg, n=10)
    for rid, (p, n) in enumerate(jobs):
        rt.submit(rid, p, n)
    for _ in range(3):
        rt.step()                     # both replicas saturated (4 slots)
    hi_prompt = np.arange(8, dtype=np.int32)
    jobs.append((hi_prompt, 6))
    rt.submit(10, hi_prompt, 6, priority=2)
    rt.step()                         # preemption ladder: relocate impossible
    assert rt._evicted, "no victim was evicted for the high-pri waiter"
    victim, src = next(iter(rt._evicted.items()))
    rt.fail_replica(src)              # source dies before the resume
    rt.run_until_idle()
    rep = rt.finish_span()
    assert rep.preempted >= 1
    assert not rt.all_shed_rids, "eviction must not become shedding here"
    assert victim in rt.results, "the evicted victim never resumed"
    _one_terminal_per_rid(tm, range(11))
    evs = [e for e in tm.tracer.events if e.kind == "preempt"]
    assert any(e.data["action"] == "evict" for e in evs)
    ref = ServingEngine(cfg, params, num_blocks=256, block_size=8,
                        max_seqs=11)
    for rid, (p, n) in enumerate(jobs):
        ref.submit(rid, p, n)
    expected = {r.rid: list(r.generated) for r in ref.run_to_completion()}
    for rid in range(11):
        assert rt.results[rid].generated == expected[rid], \
            f"rid {rid} diverged through evict + source death + resume"


# ---------------------------------------------------------------------------
# Fault-plan / injection unit checks.
# ---------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor", 1)


def test_dispatch_fault_one_shot_and_budgeted():
    plan = FaultPlan([FaultSpec("crash", 5, replica=1),
                      FaultSpec("transient", 2, replica=0, steps=2)])
    assert plan.dispatch_fault(4, 1) is None          # not armed yet
    assert plan.dispatch_fault(6, 0).kind == "transient"
    assert plan.dispatch_fault(6, 0).kind == "transient"
    assert plan.dispatch_fault(6, 0) is None          # budget exhausted
    crash = plan.dispatch_fault(7, 1)                 # fires late, once
    assert crash.kind == "crash"
    assert plan.dispatch_fault(8, 1) is None
    assert plan.fired("crash") == 1 and plan.fired("transient") == 2


def test_error_mapping():
    from repro.serving.faults import error_for
    e = error_for(FaultSpec("crash", 1, lose_pages=True))
    assert isinstance(e, ReplicaCrash) and e.lose_pages
    assert isinstance(error_for(FaultSpec("transient", 1)),
                      TransientDispatchError)
    assert issubclass(InjectedOOM, MemoryError)
