"""Serving substrate: paged KV, engine exactness, router, simulator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.costmodel import CostModel
from repro.core.types import ClusterSpec, H100_SPEC, WorkloadType
from repro.models import decode_step, init_cache, init_params, prefill
from repro.serving.baselines import OServePolicy, VLLMStaticPolicy
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import BlockAllocator, PagedKVCache
from repro.serving.request import synthesize_trace
from repro.serving.router import FlowRouter, RoundRobinRouter
from repro.serving.simulator import simulate


def test_block_allocator_lifecycle():
    a = BlockAllocator(8)
    blocks = a.alloc(5)
    assert len(set(blocks)) == 5
    assert a.n_free == 3
    a.release(blocks[:2])
    assert a.n_free == 5
    with pytest.raises(MemoryError):
        a.alloc(6)


def test_paged_cache_roundtrip():
    cfg = get_smoke_config("yi-9b")
    cache = PagedKVCache.create(cfg, num_blocks=32, block_size=4, max_seqs=4)
    cache.admit(0, prompt_len=10)
    k = jnp.arange(cfg.n_layers * 10 * cfg.n_kv_heads * cfg.head_dim,
                   dtype=jnp.float32).reshape(cfg.n_layers, 10,
                                              cfg.n_kv_heads, cfg.head_dim)
    cache.write_prefill(0, k, k * 2)
    kd, vd, lens = cache.gather_dense(np.array([0]), 12)
    np.testing.assert_allclose(np.asarray(kd[:, 0, :10]), np.asarray(k))
    np.testing.assert_allclose(np.asarray(vd[:, 0, :10]), np.asarray(k * 2))
    assert int(lens[0]) == 10
    cache.release_slot(0)
    assert cache.allocator.n_free == 32


def test_engine_token_exact_vs_reference():
    cfg = get_smoke_config("olmoe-1b-7b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (10, 10, 14)]
    news = [5, 3, 6]

    def ref_gen(prompt, n_new):
        lp, cache = prefill(params, cfg, jnp.asarray(prompt)[None])
        big = init_cache(cfg, 1, len(prompt) + n_new + 2, jnp.float32)
        if cache.k is not None:
            big.k = big.k.at[:, :, :len(prompt)].set(cache.k)
            big.v = big.v.at[:, :, :len(prompt)].set(cache.v)
        if cache.ssm is not None:
            big.ssm, big.conv = cache.ssm, cache.conv
        big.pos = cache.pos
        toks = [int(jnp.argmax(lp[0, :cfg.vocab_size]))]
        for _ in range(n_new - 1):
            lg, big = decode_step(params, cfg,
                                  jnp.asarray([toks[-1]], jnp.int32), big)
            toks.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
        return toks

    refs = [ref_gen(p, n) for p, n in zip(prompts, news)]
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2)
    for i, (p, n) in enumerate(zip(prompts, news)):
        eng.submit(i, p, n)
    done = {r.rid: r.generated for r in eng.run_to_completion()}
    for i in range(3):
        assert done[i] == refs[i]


def test_engine_continuous_batching_admits_as_slots_free():
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ServingEngine(cfg, params, num_blocks=64, block_size=8, max_seqs=2)
    for i in range(5):
        eng.submit(i, np.arange(8, dtype=np.int32) + i, 4)
    finished = eng.run_to_completion()
    assert len(finished) == 5
    assert eng.cache.allocator.n_free == 64   # all pages reclaimed


def test_flow_router_tracks_fractions():
    r = FlowRouter([[0.75, 0.0], [0.25, 1.0]])
    picks = [r.route(0) for _ in range(100)]
    frac0 = picks.count(0) / 100
    assert 0.7 <= frac0 <= 0.8
    assert all(r.route(1) == 1 for _ in range(10))


def test_round_robin_router_skips_down():
    r = RoundRobinRouter(3)
    up = np.array([True, False, True])
    picks = {r.route(0, up) for _ in range(6)}
    assert picks == {0, 2}


@pytest.fixture(scope="module")
def sim_setup():
    cfg = get_config("opt-30b")
    cm = CostModel(cfg.profile(), hw=H100_SPEC)
    cluster = ClusterSpec(16, hw=H100_SPEC)
    arch = [WorkloadType(1275, 287), WorkloadType(139, 133),
            WorkloadType(1181, 1824), WorkloadType(282, 1121)]
    reqs = synthesize_trace(6, 120, trace_id=2, seed=0)
    for r in reqs:
        # crude typing for the test
        r.type_id = int(r.out_len > 500) * 2 + int(r.in_len > 600)
    return cm, cluster, arch, reqs


def test_simulator_conservation(sim_setup):
    cm, cluster, arch, reqs = sim_setup
    avg = np.array([30.0, 30.0, 30.0, 30.0])
    pol = VLLMStaticPolicy(cm, cluster, arch, avg)
    res = simulate([r for r in reqs], pol, cm, arch, 6)
    done = sum(1 for r in res.requests if r.finish >= 0)
    assert done + res.dropped == len(reqs)
    for r in res.requests:
        if r.finish >= 0:
            assert r.finish >= r.start >= r.arrival - 1e-9
            assert r.first_token >= r.start


def test_simulator_oserve_runs(sim_setup):
    cm, cluster, arch, reqs = sim_setup
    pol = OServePolicy(cm, cluster, arch)
    res = simulate([r for r in reqs], pol, cm, arch, 6)
    m = res.metrics()
    assert m["completed"] > 0
    assert np.isfinite(m.get("p99", np.inf))
