"""AdamW + gradient clipping + accumulation + int8 gradient compression.

Self-contained (no optax dependency).  The int8 compression hook wraps the
data-parallel all-reduce: gradients are blockwise-quantized to int8 before
``psum`` and dequantized after, cutting DP collective bytes 2x (bf16) / 4x
(fp32) — one of the distributed-optimization tricks the large-scale posture
requires (used under ``shard_map``; under plain GSPMD jit it applies a
quantize/dequantize roundtrip so the numerics are representative).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params: Any, grads: Any, state: dict, cfg: AdamWConfig
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------------
# int8 gradient compression (for the DP all-reduce).
# --------------------------------------------------------------------------


def quantize_int8(x: jax.Array, block: int = 256) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization along the last axis."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, size: int
                    ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_roundtrip(grads: Any) -> Any:
    """Quantize->dequantize every gradient leaf (the numerics of int8
    compressed all-reduce; the collective itself is inserted by GSPMD/shard_map
    on the int8 representation when enabled in the train step)."""
    def roundtrip(g):
        q, s = quantize_int8(g)
        return dequantize_int8(q, s, g.shape, g.size).astype(g.dtype)
    return jax.tree.map(roundtrip, grads)


def psum_compressed(grads: Any, axis_name: str) -> Any:
    """int8-compressed all-reduce under shard_map: quantize locally, psum the
    int8 payloads (and scales), dequantize.  Bytes on the wire: 1/4 of fp32."""
    def reduce_leaf(g):
        q, s = quantize_int8(g)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)   # int32 accum
        ssum = jax.lax.psum(s, axis_name)                     # scales add
        n = jax.lax.psum(1, axis_name)
        # average of per-shard dequantized values (scale ~ mean of scales)
        flat = (qsum.astype(jnp.float32) * (ssum / n)).reshape(-1)[:g.size]
        return (flat.reshape(g.shape) / n).astype(g.dtype)
    return jax.tree.map(reduce_leaf, grads)
