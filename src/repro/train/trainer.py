"""Training step factory + loop: remat, grad accumulation, compression.

``make_train_step`` builds the jit-able pure function the dry-run lowers on
the production mesh; ``Trainer`` is the host-side loop with checkpointing and
restart used by ``examples/train_100m.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import forward, init_params
from repro.models.config import ModelConfig
from repro.train.losses import cross_entropy
from repro.train.optimizer import (AdamWConfig, adamw_update,
                                   compress_roundtrip, init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    remat: bool = True
    microbatches: int = 1           # gradient accumulation
    moe_aux_weight: float = 0.01
    grad_compression: bool = False  # int8 roundtrip around the DP all-reduce
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16


def cast_for_compute(params, dtype):
    """fp32 master params -> bf16 compute copies (2D+ leaves only; 1D gains,
    SSM dt/A/D stay fp32 for numerics)."""
    return jax.tree.map(
        lambda p: p.astype(dtype)
        if (p.ndim >= 2 and p.dtype == jnp.float32) else p, params)


def loss_fn(params, cfg: ModelConfig, batch, tcfg: TrainConfig):
    params = cast_for_compute(params, tcfg.compute_dtype)
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    labels = batch["labels"]
    if cfg.is_moe:
        logits, aux = forward(params, cfg, tokens, embeds,
                              remat=tcfg.remat, with_aux=True)
    else:
        logits = forward(params, cfg, tokens, embeds, remat=tcfg.remat)
        aux = jnp.zeros((), jnp.float32)
    loss, metrics = cross_entropy(logits, labels, cfg)
    total = loss + tcfg.moe_aux_weight * aux
    metrics["aux_loss"] = aux
    return total, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig | None = None
                    ) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ...}; batch leaves have leading
    [global_batch, seq] (sharded by the caller's in_shardings).
    """
    tcfg = tcfg or TrainConfig()

    def single_grads(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, tcfg)
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                g_acc, m_acc = carry
                g, m = single_grads(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            split = jax.tree.map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches)
                                    + x.shape[1:]), batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            g0, m0 = single_grads(jax.tree.map(lambda x: x, params),
                                  jax.tree.map(lambda x: x[0], split))
            rest = jax.tree.map(lambda x: x[1:], split)
            (grads, metrics), _ = jax.lax.scan(micro, (g0, m0), rest)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree.map(lambda m: m / tcfg.microbatches, metrics)
        else:
            grads, metrics = single_grads(params, batch)
        if tcfg.grad_compression:
            grads = compress_roundtrip(grads)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], tcfg.opt)
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key: jax.Array,
                     tcfg: TrainConfig | None = None) -> dict:
    tcfg = tcfg or TrainConfig()
    params = init_params(cfg, key, tcfg.param_dtype)
    return {"params": params, "opt": init_opt_state(params)}


class Trainer:
    """Host loop: data -> step -> metrics -> periodic checkpoint, with
    resume-from-latest restart (fault tolerance)."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 data_iter, checkpoint_dir: str | None = None,
                 checkpoint_every: int = 50, seed: int = 0):
        from repro.train.checkpoint import CheckpointManager
        self.cfg = cfg
        self.tcfg = tcfg
        self.data_iter = data_iter
        self.step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
        self.ckpt = (CheckpointManager(checkpoint_dir)
                     if checkpoint_dir else None)
        self.checkpoint_every = checkpoint_every
        self.state = init_train_state(cfg, jax.random.PRNGKey(seed), tcfg)
        self.step = 0
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest(self.state)
            if restored is not None:
                self.state, self.step = restored

    def run(self, steps: int, log_every: int = 10) -> list[dict]:
        history = []
        t0 = time.time()
        for _ in range(steps):
            batch = next(self.data_iter)
            self.state, metrics = self.step_fn(self.state, batch)
            self.step += 1
            if self.step % log_every == 0 or self.step == 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step
                m["wall"] = time.time() - t0
                history.append(m)
            if self.ckpt is not None and self.step % self.checkpoint_every == 0:
                self.ckpt.save(self.state, self.step)
        if self.ckpt is not None:
            self.ckpt.save(self.state, self.step)
            self.ckpt.wait()
        return history
