"""Sharded checkpointing with async save, manifests, and crash-safe restore.

Layout:
  <dir>/step_000123/
      manifest.json        # leaf paths, shapes, dtypes
      leaf_00000.npy ...   # one file per pytree leaf
      COMMITTED            # written last; restores ignore uncommitted dirs

On a real multi-host cluster each host writes only the leaves it owns
(``host_shard_filter``); on this single-process container that's all leaves.
Async saves run on a worker thread so the train loop never blocks on I/O.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._worker = threading.Thread(target=self._drain, daemon=True)
        self._worker.start()

    # -- save ---------------------------------------------------------------

    def save(self, state, step: int, blocking: bool = False) -> None:
        paths, leaves, _ = _flatten_with_paths(state)
        host_leaves = [np.asarray(leaf) for leaf in leaves]
        if blocking:
            self._write(paths, host_leaves, step)
        else:
            self._q.put((paths, host_leaves, step))

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            self._write(*item)
            self._q.task_done()

    def _write(self, paths, leaves, step: int) -> None:
        out = os.path.join(self.dir, f"step_{step:09d}")
        tmp = out + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(zip(paths, leaves)):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"].append(
                {"path": path, "file": fname,
                 "shape": list(leaf.shape), "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(out, ignore_errors=True)
        os.replace(tmp, out)
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        self._q.join()

    # -- restore -------------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, "COMMITTED"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, template, step: int):
        """Restore into the structure of `template` (shapes must match)."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _flatten_with_paths(template)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        new_leaves = []
        for path, leaf in zip(paths, leaves):
            entry = by_path[path]
            arr = np.load(os.path.join(d, entry["file"]))
            assert list(arr.shape) == list(leaf.shape), \
                f"shape mismatch at {path}: {arr.shape} vs {leaf.shape}"
            new_leaves.append(
                jax.device_put(arr.astype(leaf.dtype))
                if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    def restore_latest(self, template):
        steps = self.list_steps()
        if not steps:
            return None
        step = steps[-1]
        return self.restore(template, step), step
