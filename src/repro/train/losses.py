"""Training losses: masked cross-entropy + MoE load-balancing auxiliary."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def cross_entropy(logits: jax.Array, labels: jax.Array, cfg: ModelConfig,
                  mask: jax.Array | None = None) -> tuple[jax.Array, dict]:
    """logits [B,S,Vpad] fp32, labels [B,S] int32 (-1 = ignore).

    Padded vocab ids are excluded from the partition function.
    """
    Vpad = logits.shape[-1]
    vmask = jnp.arange(Vpad) < cfg.vocab_size
    logits = jnp.where(vmask[None, None, :], logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    valid = valid.astype(jnp.float32)
    denom = jnp.maximum(valid.sum(), 1.0)
    loss = (nll * valid).sum() / denom
    acc = ((jnp.argmax(logits, -1) == labels) * valid).sum() / denom
    return loss, {"nll": loss, "accuracy": acc, "tokens": denom}
