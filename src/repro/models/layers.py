"""Shared building blocks: norms, MLPs, rotary/sinusoidal positions."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.pshard import logical


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


# -- positions ---------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (or [S])."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sincos_embedding(positions: jax.Array, d_model: int) -> jax.Array:
    """Absolute sinusoidal embeddings (musicgen). positions: [B, S]."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# -- MLPs ---------------------------------------------------------------------


def init_mlp(key: jax.Array, d_model: int, d_ff: int, variant: str,
             bias: bool, dtype) -> dict:
    ks = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(d_ff)
    p = {}
    if variant in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[0], (d_model, d_ff)) * scale_in).astype(dtype)
        p["w_up"] = (jax.random.normal(ks[1], (d_model, d_ff)) * scale_in).astype(dtype)
    else:  # gelu: single up projection
        p["w_up"] = (jax.random.normal(ks[1], (d_model, d_ff)) * scale_in).astype(dtype)
    p["w_down"] = (jax.random.normal(ks[2], (d_ff, d_model)) * scale_out).astype(dtype)
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def mlp(x: jax.Array, p: dict, variant: str) -> jax.Array:
    """x: [B, S, d_model] -> [B, S, d_model].  TP: d_ff sharded column->row."""
    up = x @ p["w_up"]
    if "b_up" in p:
        up = up + p["b_up"]
    if variant == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif variant == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    h = logical(h, "batch", "seq", "d_ff")
    out = h @ p["w_down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out
