"""Grouped-query attention: full-sequence (train/prefill) and cached decode.

Sharding strategy (resolved per-arch by ``repro.launch.sharding``):
  * train/prefill: heads sharded over `model` when divisible, optionally
    padded to the next multiple of TP ("pad"), else replicated.
  * decode: the KV cache is sharded along the *sequence* axis over `model`
    ("kv_seq" logical axis) — flash-decoding semantics; GSPMD inserts the
    partial-softmax combine collectives.  This removes head-divisibility
    constraints and spreads KV memory evenly at 32k-500k contexts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rms_norm, softcap
from repro.pshard import logical

NEG_INF = -2.0 ** 30


def init_attention(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    d, q_dim, kv_dim = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, q_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (q_dim, d)) / np.sqrt(q_dim)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), dtype)
        p["bk"] = jnp.zeros((kv_dim,), dtype)
        p["bv"] = jnp.zeros((kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def _project_qkv(x, p, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_q_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jax.Array, n_q_heads: int) -> jax.Array:
    """[B, S, Hkv, D] -> [B, S, Hq, D] by group broadcast."""
    B, S, Hkv, D = k.shape
    rep = n_q_heads // Hkv
    if rep == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hkv, rep, D))
    return k.reshape(B, S, Hkv * rep, D)


def causal_mask(S: int, window: int = 0) -> jax.Array:
    """[S, S] additive mask; window > 0 limits lookback (local attention)."""
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = j <= i
    if window > 0:
        ok = ok & (j > i - window)
    return jnp.where(ok, 0.0, NEG_INF)


CHUNK_THRESHOLD = 2048   # sequences longer than this use the chunked path
Q_CHUNK = 1024


def _attend(q, k, v, cfg: ModelConfig, q_pos, k_pos, is_local):
    """softmax((q k^T) * scale + mask) v with explicit position masks.

    q: [B, Sq, H, D]; k/v: [B, Sk, H, D]; q_pos: [Sq]; k_pos: [Sk].
    """
    scale = 1.0 / np.sqrt(cfg.head_dim)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_logit_softcap)
    ok = k_pos[None, :] <= q_pos[:, None]
    if cfg.local_window > 0:
        ok_local = ok & (k_pos[None, :] > q_pos[:, None] - cfg.local_window)
        ok = jnp.where(jnp.asarray(is_local), ok_local, ok)
    scores = jnp.where(ok[None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def full_attention(x: jax.Array, p: dict, cfg: ModelConfig,
                   positions: jax.Array, is_local: jax.Array | bool = False
                   ) -> jax.Array:
    """Train/prefill self-attention over the whole sequence.

    For S > CHUNK_THRESHOLD the query dimension is processed in rematted
    chunks (flash-style memory behavior: the [S, S] score matrix is never
    materialized; the chunk body is recomputed in the backward pass).

    is_local: python bool or traced scalar selecting the gemma2 local mask.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    q = logical(q, "batch", "seq", "heads", None)
    k = logical(k, "batch", "seq", "kv_heads", None)
    v = logical(v, "batch", "seq", "kv_heads", None)
    k = _expand_kv(k, cfg.n_q_heads)
    v = _expand_kv(v, cfg.n_q_heads)
    pos1d = jnp.arange(S)

    if S <= CHUNK_THRESHOLD:
        out = _attend(q, k, v, cfg, pos1d, pos1d, is_local)
    else:
        C = Q_CHUNK
        n_chunks = (S + C - 1) // C
        assert S % C == 0, f"seq {S} must be a multiple of chunk {C}"
        qc = q.reshape(B, n_chunks, C, cfg.n_q_heads, cfg.head_dim)
        qc = jnp.moveaxis(qc, 1, 0)

        def body(_, args):
            q_i, i = args
            q_pos = i * C + jnp.arange(C)
            o = _attend(q_i, k, v, cfg, q_pos, pos1d, is_local)
            return None, o

        body = jax.checkpoint(body, prevent_cse=False)
        # unroll with the layer scan so dry-run cost_analysis counts every trip
        _, oc = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)),
                             unroll=(cfg.scan_unroll > 1))
        out = jnp.moveaxis(oc, 0, 1).reshape(B, S, cfg.n_q_heads, cfg.head_dim)

    out = logical(out, "batch", "seq", "heads", None)
    out = out.reshape(B, S, cfg.q_dim)
    return out @ p["wo"]


def _gather_pages_dense(k_pages: jax.Array, v_pages: jax.Array,
                        block_table: jax.Array, head_dim: int
                        ) -> tuple[jax.Array, jax.Array]:
    """Gather a block table's pages as dense [B, n_pages * page, Hkv, D]
    caches (kernel-native pool layout in, head_pad columns dropped).  The
    ONE page->dense layout transform — every jnp attention path shares it,
    so a pool-layout change cannot silently desynchronize them.
    """
    B = block_table.shape[0]
    k = k_pages[block_table]                # [B, n, Hkv, page, D]
    v = v_pages[block_table]
    _, n, Hkv, page, D = k.shape
    k = jnp.moveaxis(k, 3, 2).reshape(B, n * page, Hkv, D)
    v = jnp.moveaxis(v, 3, 2).reshape(B, n * page, Hkv, D)
    return k[..., :head_dim], v[..., :head_dim]


def paged_attention_jnp(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_table: jax.Array, lens: jax.Array,
                        start: jax.Array, cfg: ModelConfig) -> jax.Array:
    """jnp reference paged attention (CPU / interpret fallback).

    q: [B, Hq, D]; k_pages/v_pages: [P, Hkv, page, D] (kernel-native layout);
    block_table: [B, n_pages]; lens: [B] #positions attended (incl. current
    token); start: [B] lower position bound.  Returns [B, Hq, D].

    The gather stays in native layout; the score/mask/softmax math is the
    shared oracle (``ref.flash_decode_ref``), so dense, paged, and kernel
    paths all agree token-for-token under greedy decode.
    """
    from repro.kernels.ref import flash_decode_ref
    k, v = _gather_pages_dense(k_pages, v_pages, block_table, cfg.head_dim)
    return flash_decode_ref(q, k, v, lens, start=start,
                            softcap=float(cfg.attn_logit_softcap))


def paged_decode_attention(x: jax.Array, p: dict, cfg: ModelConfig,
                           k_pages: jax.Array, v_pages: jax.Array,
                           block_table: jax.Array, lens: jax.Array,
                           is_local: jax.Array | bool = False, *,
                           impl: str = "jnp", interpret: bool = False
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step directly against the paged KV pool (gather-free).

    Also the body of the fused multi-step horizon loop
    (``models.decode_loop_paged``): the pool scatter + table read are pure
    functional updates on the scan carry, so H consecutive steps run
    device-resident with the caller's block table pre-extended for all H
    tokens — nothing here may touch the host.

    Args:
      x: [B, 1, d_model] current token embedding.
      k_pages / v_pages: [P, Hkv, page, D] one layer's pool, kernel-native
        layout; the new K/V token is scattered into its page in place.
      block_table: [B, n_pages] physical page ids (padded rows may point at
        a trash page — the scatter then lands there harmlessly).
      lens: [B] number of tokens already cached; the new token is written at
        position ``lens`` and attention covers [start, lens+1).
      impl: "kernel" routes through the Pallas paged kernel, "jnp" uses the
        gathered reference path (exact vs the dense decode path).
    Returns: (attn_out [B, 1, d_model], new k_pages, new v_pages)
    """
    B = x.shape[0]
    pos = lens
    q, k_new, v_new = _project_qkv(x, p, cfg, pos[:, None])
    # sharded replicas: q by (tp) heads, the new K/V token by KV heads, so
    # the page scatter below stays local to the head shard that owns it
    q = logical(q, "batch", None, "heads", None)
    page = k_pages.shape[2]
    Hkv = k_pages.shape[1]
    dpad = k_pages.shape[-1] - cfg.head_dim   # pool head_pad (kernel path)
    kn, vn = k_new[:, 0], v_new[:, 0]
    kn = logical(kn, "batch", "kv_heads", None)
    vn = logical(vn, "batch", "kv_heads", None)
    if dpad:
        kn = jnp.pad(kn, ((0, 0), (0, 0), (0, dpad)))
        vn = jnp.pad(vn, ((0, 0), (0, 0), (0, dpad)))
    pid = block_table[jnp.arange(B), pos // page]         # [B]
    off = pos % page
    hidx = jnp.arange(Hkv)[None, :]
    k_pages = k_pages.at[pid[:, None], hidx, off[:, None]].set(
        kn.astype(k_pages.dtype))
    v_pages = v_pages.at[pid[:, None], hidx, off[:, None]].set(
        vn.astype(v_pages.dtype))

    len_att = pos + 1
    if cfg.local_window > 0:
        lo = jnp.maximum(len_att - cfg.local_window, 0)
        start = jnp.where(jnp.asarray(is_local), lo, 0)
    else:
        start = jnp.zeros_like(len_att)
    if impl == "kernel":
        from repro.kernels import flash_decode as _fd
        qk = q[:, 0]
        if dpad:                      # pool is pre-padded; pad q alone
            qk = jnp.pad(qk, ((0, 0), (0, 0), (0, dpad)))
        out = _fd.flash_decode_paged_native(
            qk, k_pages, v_pages, block_table, len_att, start=start,
            softcap=float(cfg.attn_logit_softcap),
            scale=1.0 / np.sqrt(cfg.head_dim),
            interpret=interpret)[..., :cfg.head_dim]
    else:
        out = paged_attention_jnp(q[:, 0], k_pages, v_pages, block_table,
                                  len_att, start, cfg)
    out = out.reshape(B, 1, cfg.q_dim)
    return out @ p["wo"], k_pages, v_pages


def paged_decode_attention_buffered(x: jax.Array, p: dict, cfg: ModelConfig,
                                    k_pages: jax.Array, v_pages: jax.Array,
                                    block_table: jax.Array,
                                    pool_lens: jax.Array,
                                    kh: jax.Array, vh: jax.Array,
                                    step_idx: jax.Array,
                                    is_local: jax.Array | bool = False
                                    ) -> tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """One decode step of the fused horizon loop: pools stay READ-ONLY.

    Inside a ``lax.scan`` over H decode steps, writing the per-step K/V
    token into the paged pool would force the whole pool through the scan
    carry (an O(pool) copy per token on backends without aliasing).
    Instead the horizon's new K/V lives in a small side buffer ``kh``/``vh``
    ([B, H, Hkv, head_dim], scan-carried), and attention overlays the
    buffer onto the gathered pages at its absolute positions — producing
    the *bit-identical* dense cache the scatter-first path would have
    gathered (overwritten lanes past the valid length are masked to exact
    zeros either way), so tokens match the per-step path exactly.  The
    caller scatters the buffer into the pool once per horizon
    (``models.decode_loop_paged``).

    Args:
      x: [B, 1, d_model] current token embedding.
      k_pages / v_pages: [P, Hkv, page, D] one layer's pool (not written).
      block_table: [B, n_pages] physical page ids covering the horizon.
      pool_lens: [B] tokens resident in pages BEFORE the horizon started.
      kh / vh: [B, H, Hkv, head_dim] this horizon's K/V so far; position
        ``step_idx`` is written here.
      step_idx: scalar int32 — loop iteration (absolute position is
        ``pool_lens + step_idx``).
    Returns: (attn_out [B, 1, d_model], new kh, new vh)
    """
    from repro.kernels.ref import flash_decode_ref
    B = x.shape[0]
    H = kh.shape[1]
    pos = pool_lens + step_idx
    q, k_new, v_new = _project_qkv(x, p, cfg, pos[:, None])
    q = logical(q, "batch", None, "heads", None)
    kh = kh.at[:, step_idx].set(k_new[:, 0].astype(kh.dtype))
    vh = vh.at[:, step_idx].set(v_new[:, 0].astype(vh.dtype))
    kh = logical(kh, "batch", None, "kv_heads", None)
    vh = logical(vh, "batch", None, "kv_heads", None)

    # gather the paged prefix, then overlay the horizon buffer at its
    # absolute positions (entries past ``lens`` are masked out below, so
    # the not-yet-generated tail of the buffer is harmless)
    k, v = _gather_pages_dense(k_pages, v_pages, block_table, cfg.head_dim)
    bidx = jnp.arange(B)[:, None]
    tpos = pool_lens[:, None] + jnp.arange(H)[None, :]    # [B, H]
    k = k.at[bidx, tpos].set(kh)
    v = v.at[bidx, tpos].set(vh)

    len_att = pos + 1
    if cfg.local_window > 0:
        lo = jnp.maximum(len_att - cfg.local_window, 0)
        start = jnp.where(jnp.asarray(is_local), lo, 0)
    else:
        start = jnp.zeros_like(len_att)
    out = flash_decode_ref(q[:, 0], k, v, len_att, start=start,
                           softcap=float(cfg.attn_logit_softcap))
    out = out.reshape(B, 1, cfg.q_dim)
    return out @ p["wo"], kh, vh


def prefill_chunk_attention(x: jax.Array, p: dict, cfg: ModelConfig,
                            k_pages: jax.Array, v_pages: jax.Array,
                            block_table: jax.Array, start: jax.Array,
                            n_valid: jax.Array, trash_page: int,
                            is_local: jax.Array | bool = False
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One prefill *chunk* attending the paged prefix + itself (chunked
    prefill: the prefill->page scatter is fused into the forward).

    Args:
      x: [B, C, d_model] chunk embeddings (positions ``start + [0, C)``;
        every sequence in the batch shares the same ``start``).
      k_pages / v_pages: [P, Hkv, page, D] one layer's pool, kernel-native
        layout; the chunk's K/V is scattered into its pages in place, then
        attention reads the table's pages (prefix chunks included) — no
        dense per-sequence cache is ever materialized outside the pool.
      block_table: [B, n_pages] physical page ids covering start + C tokens.
      start: scalar int32 — tokens already resident (earlier chunks).
      n_valid: scalar int32 — real tokens in this chunk (the tail of a
        bucketed chunk scatters to ``trash_page`` and is masked out).
    Returns: (attn_out [B, C, d_model], new k_pages, new v_pages)
    """
    B, C = x.shape[0], x.shape[1]
    pos = start + jnp.arange(C, dtype=jnp.int32)           # [C]
    q, k_new, v_new = _project_qkv(x, p, cfg, pos[None, :])
    q = logical(q, "batch", "seq", "heads", None)
    k_new = logical(k_new, "batch", "seq", "kv_heads", None)
    v_new = logical(v_new, "batch", "seq", "kv_heads", None)
    page = k_pages.shape[2]
    Hkv = k_pages.shape[1]
    n_pages = block_table.shape[1]
    dpad = k_pages.shape[-1] - cfg.head_dim
    if dpad:
        k_new = jnp.pad(k_new, ((0, 0),) * 3 + ((0, dpad),))
        v_new = jnp.pad(v_new, ((0, 0),) * 3 + ((0, dpad),))
    valid = jnp.arange(C) < n_valid                        # [C]
    tidx = jnp.minimum(pos // page, n_pages - 1)           # [C]
    pid = jnp.where(valid[None, :], block_table[:, tidx], trash_page)  # [B, C]
    off = (pos % page)[None, :]                            # [1, C]
    hidx = jnp.arange(Hkv)[None, None, :]
    k_pages = k_pages.at[pid[:, :, None], hidx, off[:, :, None]].set(
        k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[pid[:, :, None], hidx, off[:, :, None]].set(
        v_new.astype(v_pages.dtype))

    # gather prefix + chunk through the table (pages past the live length
    # hold trash and are position-masked below)
    k, v = _gather_pages_dense(k_pages, v_pages, block_table, cfg.head_dim)
    k = _expand_kv(k, cfg.n_q_heads).astype(q.dtype)
    v = _expand_kv(v, cfg.n_q_heads).astype(q.dtype)
    out = _attend(q, k, v, cfg, pos, jnp.arange(n_pages * page), is_local)
    out = out.reshape(B, C, cfg.q_dim)
    return out @ p["wo"], k_pages, v_pages


def decode_attention(x: jax.Array, p: dict, cfg: ModelConfig,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, is_local: jax.Array | bool = False
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step with a dense KV cache.

    Args:
      x: [B, 1, d_model] current token embedding.
      k_cache / v_cache: [B, Smax, Hkv, D]; the new K/V is written at `pos`.
      pos: [B] int32 write/attend position per sequence.
    Returns:
      (attn_out [B, 1, d_model], new k_cache, new v_cache)
    """
    B, Smax = k_cache.shape[0], k_cache.shape[1]
    q, k_new, v_new = _project_qkv(x, p, cfg, pos[:, None])
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, pos].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, pos].set(v_new[:, 0].astype(v_cache.dtype))
    # Sequence-sharded cache: flash-decoding combine happens inside the
    # softmax/contraction that GSPMD partitions along `kv_seq`.
    k_cache = logical(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = logical(v_cache, "batch", "kv_seq", "kv_heads", None)

    kk = _expand_kv(k_cache, cfg.n_q_heads)
    vv = _expand_kv(v_cache, cfg.n_q_heads)
    if kk.dtype != x.dtype:      # fp8/quantized caches upcast for compute
        kk = kk.astype(x.dtype)
        vv = vv.astype(x.dtype)
    scale = 1.0 / np.sqrt(cfg.head_dim)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    scores = softcap(scores, cfg.attn_logit_softcap)
    j = jnp.arange(Smax)[None, None, None, :]
    ok = j <= pos[:, None, None, None]
    if cfg.local_window > 0:
        lo = pos[:, None, None, None] - cfg.local_window
        ok_local = ok & (j > lo)
        sel = jnp.asarray(is_local)
        ok = jnp.where(sel, ok_local, ok)
    scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    out = out.reshape(B, 1, cfg.q_dim)
    return out @ p["wo"], k_cache, v_cache
