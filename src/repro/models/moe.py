"""Top-k mixture-of-experts with group-local capacity dispatch.

Design (TPU-native, GSPMD-friendly):
  * tokens are grouped along the batch dimension (groups align with the data
    sharding), capacity is per (group, expert) = ceil(topk * tokens_per_group
    * capacity_factor / E);
  * dispatch positions come from a one-hot cumulative sum *within the group*
    (no global sort, no giant [N, E, C] dispatch einsum tensors);
  * expert buffers [G, E, C, d] are scattered/gathered with per-group indices;
    expert weights [E, d, ff] shard over `model` as expert-parallelism when
    E % TP == 0 ("ep"), otherwise over the ff dim ("tp", expert-tensor-
    parallel: granite's 40 experts on TP=16).

Overflowing tokens are dropped (standard capacity-based MoE); the router uses
softmax-then-topk with renormalized combine weights (OLMoE-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.pshard import logical


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    return {
        "router": (jax.random.normal(ks[0], (d, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, ff, d)) * s_out).astype(dtype),
    }


def _pick_groups(n_tokens_per_seq: int, batch: int, n_experts: int,
                 top_k: int) -> int:
    """Groups divide the batch; keep tokens/group >= ~4*E/topk so the
    per-expert capacity ceil() stays cheap, but cap group size for memory."""
    target_tokens = max(4 * n_experts // max(top_k, 1), 64)
    g = batch
    while g > 1 and (batch // g) * n_tokens_per_seq < target_tokens:
        # halve groups (g must divide batch; walk divisors downward)
        for cand in range(g - 1, 0, -1):
            if batch % cand == 0:
                g = cand
                break
        else:
            g = 1
    return max(1, g)


def moe_dense(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Decode-path MoE: every expert on every token, gate-combined.

    Exact (no capacity drops).  For single-token decode the step is
    HBM-bound on the expert weights, which are read once regardless of the
    routing — so the E/topk FLOPs overhead is hidden and this beats
    per-token weight gathers for batch >= E/topk.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * S, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topi = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs)
    gates = jax.vmap(lambda g, i, v: g.at[i].set(v))(gates, topi, gate_vals)
    gate = jnp.einsum("nd,edf->nef", xt, p["w_gate"])
    up = jnp.einsum("nd,edf->nef", xt, p["w_up"])
    h = jax.nn.silu(gate) * up
    h = logical(h, None, "experts", "expert_ff")
    y_all = jnp.einsum("nef,efd->ned", h, p["w_down"])
    y = jnp.einsum("ned,ne->nd", y_all, gates.astype(x.dtype))
    return y.reshape(B, S, d)


def moe_block(x: jax.Array, p: dict, cfg: ModelConfig,
              capacity_factor: float | None = None) -> jax.Array:
    """x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    # Small token counts (decode steps, CPU-scale smoke/serving): the dense
    # path is exact and HBM-bound anyway.  Large scale uses capacity-based
    # dispatch (drops bounded by the load-balancing loss during training).
    if B * S <= 2048:
        return moe_dense(x, p, cfg)
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    G = _pick_groups(S, B, E, K)
    N = (B // G) * S                      # tokens per group
    C = max(1, int(np.ceil(K * N * cf / E)))

    xg = x.reshape(G, N, d)
    logits = (xg.astype(jnp.float32) @ p["router"])          # [G, N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topi = jax.lax.top_k(probs, K)                # [G, N, K]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert's capacity buffer:
    # cumulative count of earlier assignments to the same expert in the group.
    oh = jax.nn.one_hot(topi, E, dtype=jnp.int32)            # [G, N, K, E]
    flat_oh = oh.reshape(G, N * K, E)
    pos = jnp.cumsum(flat_oh, axis=1) - flat_oh              # exclusive cumsum
    pos = (pos * flat_oh).sum(-1).reshape(G, N, K)           # [G, N, K]
    keep = pos < C
    slot = jnp.where(keep, topi * C + pos, E * C)            # overflow -> dump slot

    # Scatter tokens into expert buffers [G, E*C (+1 dump), d].
    def scatter_group(buf_idx, xs):
        buf = jnp.zeros((E * C + 1, d), xs.dtype)
        idx = buf_idx.reshape(N * K)
        vals = jnp.repeat(xs, K, axis=0)
        return buf.at[idx].add(vals)

    buffers = jax.vmap(scatter_group)(slot, xg)[:, : E * C, :]
    buffers = buffers.reshape(G, E, C, d)
    buffers = logical(buffers, "moe_groups", "experts", None, None)

    # Expert FFN (SwiGLU), batched over experts.
    gate = jnp.einsum("gecd,edf->gecf", buffers, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buffers, p["w_up"])
    h = jax.nn.silu(gate) * up
    h = logical(h, "moe_groups", "experts", None, "expert_ff")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    out_buf = logical(out_buf, "moe_groups", "experts", None, None)
    out_buf = out_buf.reshape(G, E * C, d)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((G, 1, d), out_buf.dtype)], axis=1)

    # Gather back and combine with renormalized gates.
    def gather_group(buf, idx):
        return buf[idx]                                      # [N*K, d]

    slots_out = jax.vmap(gather_group)(out_buf, slot.reshape(G, N * K))
    slots_out = slots_out.reshape(G, N, K, d)
    w = (gate_vals * keep).astype(x.dtype)[..., None]
    yg = (slots_out * w).sum(axis=2)                         # [G, N, d]
    return yg.reshape(B, S, d)


def load_balance_loss(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e.

    f_e = fraction of tokens whose top-k set contains e; P_e = mean router
    probability.  Keeps routing balanced so the capacity path's drop rate
    stays negligible at scale.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = x.reshape(-1, d).astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, topi = jax.lax.top_k(probs, K)
    chosen = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1)  # [N, E]
    f = chosen.mean(0)
    P = probs.mean(0)
    return E * jnp.sum(f * P) / K


def moe_ref(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Oracle: dense per-token expert evaluation (no capacity drops).

    Used in tests; agreement holds whenever nothing overflows capacity.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32).reshape(-1, d) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topi = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    xt = x.reshape(-1, d)
    gates_full = jnp.zeros_like(probs)
    gates_full = jax.vmap(lambda g, i, v: g.at[i].set(v))(gates_full, topi, gate_vals)
    # every expert on every token (tiny shapes only)
    gate = jnp.einsum("nd,edf->enf", xt, p["w_gate"])
    up = jnp.einsum("nd,edf->enf", xt, p["w_up"])
    h = jax.nn.silu(gate) * up
    y_all = jnp.einsum("enf,efd->end", h, p["w_down"])
    y = jnp.einsum("end,ne->nd", y_all, gates_full.astype(x.dtype))
    return y.reshape(B, S, d)
