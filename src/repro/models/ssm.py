"""Mamba2 / SSD (state-space duality) mixer, plus the hybrid (hymba) path.

Chunked SSD algorithm (Dao & Gu, 2024) for train/prefill:
  within-chunk: masked (C_t . B_s) * exp(cs_t - cs_s) "attention" matmuls;
  across chunks: an associative scan over per-chunk states [B, H, P, N].
Decode keeps a constant-size recurrent state (the reason mamba2/hymba are the
only archs assigned the long_500k shape).

All scan math runs in fp32; projections stay in the compute dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.pshard import logical


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_ssm(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    """Per-stream projection weights (z, x, B, C, dt kept separate so the
    head-aligned streams shard over the model axis without mixed layouts)."""
    ks = jax.random.split(key, 8)
    d, H, P, N, G = (cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim,
                     cfg.ssm_state, cfg.ssm_groups)
    d_inner = H * P
    s = 1.0 / np.sqrt(d)
    dt_init = jnp.log(jnp.exp(jnp.linspace(1e-3, 1e-1, H)) - 1.0)  # softplus^-1
    W = cfg.ssm_conv_width
    return {
        "w_z": (jax.random.normal(ks[0], (d, d_inner)) * s).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d, d_inner)) * s).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (d, G * N)) * s).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (d, G * N)) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d, H)) * s).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (W, d_inner)) * 0.2).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (W, G * N)) * 0.2).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (W, G * N)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_channels(cfg),), dtype),
        "dt_bias": dt_init.astype(jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.zeros((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[4], (d_inner, d)) /
                     np.sqrt(d_inner)).astype(dtype),
    }


def _project(x: jax.Array, p: dict):
    """x [..., d] -> (z, xs, B, C, dt) per-stream projections."""
    return (x @ p["w_z"], x @ p["w_x"], x @ p["w_B"], x @ p["w_C"],
            x @ p["w_dt"])


def _conv_weight(p: dict) -> jax.Array:
    return jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)


def _gated_norm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))


def ssd_chunked(xs, dt, A, B_, C_, chunk: int, init_state=None):
    """Chunked SSD scan.

    Args:
      xs: [B, L, H, P] inputs (post-conv, activated), fp32.
      dt: [B, L, H] softplus'd step sizes, fp32.
      A:  [H] negative decay rates, fp32.
      B_, C_: [B, L, G, N] input/output projections, fp32.
      chunk: chunk length Q (L % Q == 0).
      init_state: optional [B, H, P, N] initial state.
    Returns:
      (y [B, L, H, P], final_state [B, H, P, N])
    """
    Bsz, L, H, P = xs.shape
    G, N = B_.shape[2], B_.shape[3]
    Q = min(chunk, max(L, 1))
    orig_L = L
    pad = (-L) % Q
    if pad:
        # Zero-pad to a chunk multiple: dt=0 => decay exp(0)=1 keeps state,
        # x=0 contributes nothing, so the final state is unaffected.
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xs, dt, B_, C_ = zf(xs), zf(dt), zf(B_), zf(C_)
        L = L + pad
    Nc = L // Q
    rep = H // G

    xs_c = xs.reshape(Bsz, Nc, Q, H, P)
    dt_c = dt.reshape(Bsz, Nc, Q, H)
    B_c = B_.reshape(Bsz, Nc, Q, G, N)
    C_c = C_.reshape(Bsz, Nc, Q, G, N)
    # broadcast groups to heads
    B_h = jnp.repeat(B_c, rep, axis=3)  # [B, Nc, Q, H, N]
    C_h = jnp.repeat(C_c, rep, axis=3)

    dtA = dt_c * A[None, None, None, :]                 # [B, Nc, Q, H] (<=0)
    cs = jnp.cumsum(dtA, axis=2)                        # inclusive cumsum
    total = cs[:, :, -1, :]                             # [B, Nc, H]

    # Intra-chunk (the "duality" quadratic form).
    # M[t, s] = exp(cs_t - cs_s) for t >= s.
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # [B,Nc,Q(t),Q(s),H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcthn,bcshn->bchts", C_h, B_h)     # [B,Nc,H,Q,Q]
    scores = cb * jnp.moveaxis(M, -1, 2)                # [B,Nc,H,Q,Q]
    xdt = xs_c * dt_c[..., None]                        # [B,Nc,Q,H,P]
    y_intra = jnp.einsum("bchts,bcshp->bcthp", scores, xdt)

    # Per-chunk end states.
    decay_to_end = jnp.exp(total[:, :, None, :] - cs)   # [B,Nc,Q,H]
    S_chunk = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                         decay_to_end * dt_c, B_h, xs_c)

    # Associative scan across chunks: state' = state * a + s.
    a_tot = jnp.exp(total)                              # [B, Nc, H]
    if init_state is not None:
        # fold the initial state in as a virtual chunk 0
        a_tot = jnp.concatenate([jnp.ones_like(a_tot[:, :1]), a_tot], axis=1)
        S_chunk = jnp.concatenate([init_state[:, None].astype(S_chunk.dtype),
                                   S_chunk], axis=1)

    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_run, S_run = jax.lax.associative_scan(combine, (a_tot, S_chunk), axis=1)
    if init_state is not None:
        S_prev = S_run[:, :-1]                          # state entering chunk c
        final_state = S_run[:, -1]
    else:
        S_prev = jnp.concatenate(
            [jnp.zeros_like(S_run[:, :1]), S_run[:, :-1]], axis=1)
        final_state = S_run[:, -1]

    # Inter-chunk contribution: y_t += C_t . (S_prev * exp(cs_t)).
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", C_h * jnp.exp(cs)[..., None],
                         S_prev)
    y = (y_intra + y_inter).reshape(Bsz, L, H, P)
    return y[:, :orig_L], final_state


def ssm_forward(x: jax.Array, p: dict, cfg: ModelConfig,
                init_state: jax.Array | None = None,
                conv_init: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence SSD mixer.

    Args:
      x: [B, L, d_model].
    Returns: (out [B, L, d_model], final_ssm_state [B,H,P,N],
              final_conv_window [B, width-1, conv_channels])
    """
    Bsz, L, _ = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    W = cfg.ssm_conv_width

    z, xs, B_, C_, dt = _project(x, p)
    d_inner = H * P
    if conv_init is None:
        conv_init = jnp.zeros((Bsz, W - 1, conv_channels(cfg)), xs.dtype)
    init_x, init_B, init_C = jnp.split(
        conv_init.astype(xs.dtype), [d_inner, d_inner + G * N], axis=-1)
    b_x, b_B, b_C = jnp.split(p["conv_b"], [d_inner, d_inner + G * N])

    def causal_conv(stream, w, b, init):
        padded = jnp.concatenate([init, stream], axis=1)
        out = sum(padded[:, i:i + L] * w[i] for i in range(W))
        return jax.nn.silu(out + b), padded[:, L:]

    xs_c, win_x = causal_conv(xs, p["conv_x"], b_x, init_x)
    B_c, win_B = causal_conv(B_, p["conv_B"], b_B, init_B)
    C_c, win_C = causal_conv(C_, p["conv_C"], b_C, init_C)
    new_conv_window = jnp.concatenate([win_x, win_B, win_C], axis=-1)

    xs_f = xs_c.reshape(Bsz, L, H, P).astype(jnp.float32)
    xs_f = logical(xs_f, "batch", "seq", "ssm_heads", None)
    B_f = B_c.reshape(Bsz, L, G, N).astype(jnp.float32)
    C_f = C_c.reshape(Bsz, L, G, N).astype(jnp.float32)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, state = ssd_chunked(xs_f, dt_f, A, B_f, C_f, cfg.ssm_chunk,
                           init_state)
    y = y + xs_f * p["D"][None, None, :, None]
    y = y.reshape(Bsz, L, H * P)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, state, new_conv_window


def ssm_decode_step(x: jax.Array, p: dict, cfg: ModelConfig,
                    state: jax.Array, conv_window: jax.Array
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One recurrent step.

    Args:
      x: [B, 1, d_model]; state: [B, H, P, N] fp32;
      conv_window: [B, W-1, conv_channels] (previous conv inputs).
    """
    Bsz = x.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state

    z, xs, B_, C_, dt = _project(x[:, 0], p)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)     # [B, conv_ch]
    window = jnp.concatenate([conv_window.astype(conv_in.dtype),
                              conv_in[:, None]], axis=1)  # [B, W, ch]
    conv = jnp.einsum("bwc,wc->bc", window, _conv_weight(p)) + p["conv_b"]
    conv = jax.nn.silu(conv)
    new_window = window[:, 1:]
    xs_c, B_c, C_c = jnp.split(conv, [H * P, H * P + G * N], axis=-1)

    xs_f = xs_c.reshape(Bsz, H, P).astype(jnp.float32)
    B_f = jnp.repeat(B_c.reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
    C_f = jnp.repeat(C_c.reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, H]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt_f * A)                                # [B, H]

    state = state * a[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt_f, xs_f, B_f)
    y = jnp.einsum("bhn,bhpn->bhp", C_f, state)
    y = y + xs_f * p["D"][None, :, None]
    y = y.reshape(Bsz, H * P)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = (y.astype(x.dtype) @ p["out_proj"])[:, None]
    return out, state, new_window


def ssd_reference(xs, dt, A, B_, C_, init_state=None):
    """Token-by-token recurrent oracle for the chunked/kernel paths."""
    Bsz, L, H, P = xs.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    B_h = jnp.repeat(B_, rep, axis=2)
    C_h = jnp.repeat(C_, rep, axis=2)
    state = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
             else init_state)

    def step(state, t):
        a = jnp.exp(dt[:, t] * A[None, :])
        state = state * a[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], xs[:, t], B_h[:, t])
        y = jnp.einsum("bhn,bhpn->bhp", C_h[:, t], state)
        return state, y

    state, ys = jax.lax.scan(step, state, jnp.arange(L))
    return jnp.moveaxis(ys, 0, 1), state
