"""Model configuration for the architecture zoo.

One frozen dataclass describes every assigned architecture family:
dense GQA transformers, MoE, pure SSM (mamba2/SSD), hybrid attention+SSM
(hymba), and the VLM/audio backbones (whose modality frontends are stubs
providing precomputed embeddings/token ids per the assignment).
"""
from __future__ import annotations

import dataclasses

from repro.core.costmodel import ModelProfile


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention details
    pos_embedding: str = "rope"    # rope | sincos | none
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0    # gemma2
    final_logit_softcap: float = 0.0   # gemma2
    local_window: int = 0              # >0: alternate local/global (gemma2)
    sandwich_norm: bool = False        # gemma2 pre+post block norms
    scale_embedding: bool = False      # gemma2 sqrt(d) embedding scale
    norm_eps: float = 1e-6
    mlp_variant: str = "swiglu"        # swiglu | geglu | gelu (2-matmul)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_groups: int = 1
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    hybrid: bool = False               # parallel attn + SSM heads (hymba)

    # embeddings / io
    tie_embeddings: bool = True
    modality: str = "text"             # text | image_stub | audio_stub
    max_seq_len: int = 32_768

    # sharding preferences (resolved by repro.launch.sharding)
    attn_sharding: str = "auto"        # auto | heads | pad | replicate
    expert_sharding: str = "auto"      # auto | ep | tp
    seq_parallel: bool = True          # SP residual in training plans
    # scan unrolling: 1 = rolled loop (fast compile); n_layers = fully
    # unrolled (dry-run cost accounting: XLA cost_analysis counts a while
    # body once, so rolled-loop FLOPs undercount by ~n_layers)
    scan_unroll: int = 1

    # ------------------------------------------------------------------

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def has_attn(self) -> bool:
        return not self.attn_free

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    def padded_vocab(self, multiple: int = 256) -> int:
        return _round_up(self.vocab_size, multiple)

    def padded_heads(self, tp: int) -> int:
        return _round_up(self.n_q_heads, tp)

    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid archs only)."""
        return self.family in ("ssm", "hybrid")

    def local_is_local(self, layer: int) -> bool:
        """gemma2 alternation: even layers local, odd layers global."""
        return self.local_window > 0 and layer % 2 == 0

    # -- cost-model bridge ------------------------------------------------

    def profile(self) -> ModelProfile:
        return ModelProfile(
            name=self.name,
            n_layers=self.n_layers,
            d_model=self.d_model,
            n_q_heads=self.n_q_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            d_ff=self.d_ff,
            vocab=self.vocab_size,
            n_experts=self.n_experts,
            top_k=self.top_k,
            ssm_state=self.ssm_state,
            ssm_heads=self.ssm_heads,
            ssm_head_dim=self.ssm_head_dim,
            hybrid_attn=self.hybrid,
            attn_free=self.attn_free,
        )

    def param_count(self) -> int:
        return self.profile().param_count

    # -- smoke-scale reduction ---------------------------------------------

    def reduced(self, n_layers: int = 2, d_model: int = 64, n_q_heads: int = 4,
                n_kv_heads: int | None = None, d_ff: int = 128,
                vocab: int = 256, n_experts: int | None = None,
                top_k: int | None = None) -> "ModelConfig":
        """Same-family tiny config for CPU smoke tests."""
        kv = n_kv_heads if n_kv_heads is not None else max(1, n_q_heads // 2)
        kv = min(kv, n_q_heads)
        changes: dict = dict(
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_q_heads=n_q_heads,
            n_kv_heads=kv if self.n_kv_heads != self.n_q_heads else n_q_heads,
            head_dim=d_model // n_q_heads * 2,
            d_ff=0 if self.d_ff == 0 else d_ff,
            vocab_size=vocab,
            max_seq_len=512,
        )
        if self.is_moe:
            changes["n_experts"] = n_experts if n_experts is not None else 8
            changes["top_k"] = top_k if top_k is not None else 2
            changes["moe_capacity_factor"] = 2.0  # drop-free smoke tests
        if self.has_ssm:
            changes["ssm_state"] = 16
            changes["ssm_heads"] = 4
            changes["ssm_head_dim"] = 16
            changes["ssm_chunk"] = 64
        if self.local_window:
            changes["local_window"] = 64
        return dataclasses.replace(self, **changes)


def flops_per_token_train(cfg: ModelConfig) -> float:
    """6*N_active*D convention (MODEL_FLOPS numerator for the roofline)."""
    return 6.0 * cfg.profile().active_param_count


def flops_per_token_fwd(cfg: ModelConfig) -> float:
    return 2.0 * cfg.profile().active_param_count
