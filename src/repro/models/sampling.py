"""Token sampling utilities (greedy / temperature / top-k) with vocab masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def mask_padded_vocab(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Disallow the padded vocab tail (ids >= cfg.vocab_size)."""
    V = logits.shape[-1]
    if V == cfg.vocab_size:
        return logits
    idx = jnp.arange(V)
    return jnp.where(idx[None, :] < cfg.vocab_size, logits, -jnp.inf)


def step_key(key: jax.Array, step: jax.Array | int) -> jax.Array:
    """Per-decode-step PRNG key: fold the global decode-step index into the
    stream key.

    Both the step-at-a-time decode path and the fused multi-step horizon
    loop (``models.decode_loop_paged``) derive step ``t``'s key as
    ``step_key(base, t)``, so the two paths draw the *identical* key
    sequence and sampled decoding is token-for-token reproducible across
    horizon sizes.  ``step`` may be a traced scalar (in-loop folding).
    """
    return jax.random.fold_in(key, step)


def sample(logits: jax.Array, cfg: ModelConfig, key: jax.Array,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits: [B, Vpad] -> token ids [B]."""
    logits = mask_padded_vocab(logits, cfg)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        cutoff = vals[:, -1:]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
