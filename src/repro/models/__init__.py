from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    DecodeCache,
    PagedDecodeState,
    decode_loop_paged,
    decode_step,
    decode_step_paged,
    forward,
    init_cache,
    init_params,
    param_count,
    prefill,
    prefill_chunk,
)
