"""The unified decoder-only model covering all assigned architecture families.

One scan-over-layers body handles: dense GQA attention (with gemma2's
local/global alternation + softcaps + sandwich norms), MoE MLPs, SSD mixers
(mamba2), and hybrid parallel attention+SSM heads (hymba).  VLM/audio archs
use the same backbone; their modality frontends are stubs that feed
precomputed token ids / frame embeddings (see ``repro.launch.dryrun
.input_specs``).

Entry points:
  init_params(cfg, key)                  -> parameter pytree (layers stacked)
  forward(params, cfg, tokens|embeds)    -> logits           (train)
  prefill(params, cfg, tokens)           -> (logits, DecodeCache)
  decode_step(params, cfg, token, cache) -> (logits, DecodeCache)
  decode_loop_paged(params, cfg, tokens, state, key, step0, horizon)
      -> ([B, horizon] tokens, PagedDecodeState)   (fused multi-step decode)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import init_mlp, mlp, rms_norm, sincos_embedding, softcap
from repro.pshard import logical


# --------------------------------------------------------------------------
# Decode cache.
# --------------------------------------------------------------------------


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k", "v", "ssm", "conv", "pos"],
    meta_fields=[],
)
@dataclasses.dataclass
class DecodeCache:
    k: Any      # [L, B, Smax, Hkv, D] or None
    v: Any
    ssm: Any    # [L, B, H, P, N] fp32 or None
    conv: Any   # [L, B, W-1, conv_ch] or None
    pos: Any    # [B] int32: number of tokens already in the cache


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k", "v", "block_table", "lens", "ssm", "conv"],
    meta_fields=[],
)
@dataclasses.dataclass
class PagedDecodeState:
    """Device-resident paged decode state (one jitted step's working set).

    The K/V pools stay in kernel-native layout so the Pallas paged kernel
    (and the jnp fallback) read pages without per-step transposes.
    """
    k: Any            # [L, P, Hkv, page, D] paged pool or None
    v: Any
    block_table: Any  # [B, n_pages] int32 physical page ids
    lens: Any         # [B] int32 tokens already cached per sequence
    ssm: Any          # [L, B, H, P, N] fp32 or None
    conv: Any         # [L, B, W-1, conv_ch] or None


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> DecodeCache:
    L = cfg.n_layers
    k = v = ssm = conv = None
    if cfg.has_attn:
        shape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
    if cfg.has_ssm:
        ssm = jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32)
        conv = jnp.zeros((L, batch, cfg.ssm_conv_width - 1,
                          ssm_lib.conv_channels(cfg)), dtype)
    return DecodeCache(k, v, ssm, conv, jnp.zeros((batch,), jnp.int32))


# --------------------------------------------------------------------------
# Parameter init.
# --------------------------------------------------------------------------


def _init_block(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {"ln1": jnp.zeros((d,), dtype)}
    if cfg.has_attn:
        p["attn"] = attn_lib.init_attention(ks[0], cfg, dtype)
    if cfg.has_ssm:
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg, dtype)
    if cfg.hybrid:
        p["attn_out_norm"] = jnp.zeros((d,), dtype)
        p["ssm_out_norm"] = jnp.zeros((d,), dtype)
    if cfg.sandwich_norm:
        p["post_ln1"] = jnp.zeros((d,), dtype)
    if cfg.is_moe:
        p["ln2"] = jnp.zeros((d,), dtype)
        p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
    elif cfg.d_ff > 0:
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = init_mlp(ks[3], d, cfg.d_ff, cfg.mlp_variant,
                            cfg.mlp_bias, dtype)
    if cfg.sandwich_norm and (cfg.is_moe or cfg.d_ff > 0):
        p["post_ln2"] = jnp.zeros((d,), dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.bfloat16) -> dict:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    V = cfg.padded_vocab()
    embed = (jax.random.normal(k_embed, (V, cfg.d_model)) * 0.02).astype(dtype)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed,
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (cfg.d_model, V)) * 0.02).astype(dtype)
    return params


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# Block application (shared by all modes).
# --------------------------------------------------------------------------


def _mixer(x, bp, cfg: ModelConfig, layer_idx, positions, mode,
           kv=None, ssm_state=None, conv=None, pos=None, paged=None):
    """Token mixing: attention and/or SSM.  Returns (out, new_kv, new_ssm_pair).

    ``paged`` (decode only): dict with ``table`` [B, n_pages] plus static
    ``impl``/``interpret`` — routes attention through the paged KV pool
    (kv holds the layer's page pools) instead of a dense cache.
    """
    is_local = (layer_idx % 2 == 0) if cfg.local_window > 0 else False
    attn_out = None
    new_k = new_v = None
    if cfg.has_attn:
        if mode == "chunk":
            attn_out, new_k, new_v = attn_lib.prefill_chunk_attention(
                x, bp["attn"], cfg, kv[0], kv[1], paged["table"], pos,
                paged["n_valid"], paged["trash"], is_local)
        elif mode == "decode" and paged is not None \
                and paged["impl"] == "buffered":
            # horizon loop: pools read-only, new K/V rides the side buffer
            # (new_k/new_v are the updated buffer rows, not pools)
            attn_out, new_k, new_v = attn_lib.paged_decode_attention_buffered(
                x, bp["attn"], cfg, kv[0], kv[1], paged["table"],
                paged["pool_lens"], paged["kh"], paged["vh"], paged["step"],
                is_local)
        elif mode == "decode" and paged is not None:
            attn_out, new_k, new_v = attn_lib.paged_decode_attention(
                x, bp["attn"], cfg, kv[0], kv[1], paged["table"], pos,
                is_local, impl=paged["impl"], interpret=paged["interpret"])
        elif mode == "decode":
            attn_out, new_k, new_v = attn_lib.decode_attention(
                x, bp["attn"], cfg, kv[0], kv[1], pos, is_local)
        else:
            attn_out = attn_lib.full_attention(
                x, bp["attn"], cfg, positions, is_local)
    ssm_out = None
    new_state = new_conv = None
    if cfg.has_ssm:
        if mode == "decode":
            ssm_out, new_state, new_conv = ssm_lib.ssm_decode_step(
                x, bp["ssm"], cfg, ssm_state, conv)
        else:
            ssm_out, new_state, new_conv = ssm_lib.ssm_forward(
                x, bp["ssm"], cfg, ssm_state, conv)
    if cfg.hybrid:
        out = 0.5 * (rms_norm(attn_out, bp["attn_out_norm"], cfg.norm_eps)
                     + rms_norm(ssm_out, bp["ssm_out_norm"], cfg.norm_eps))
    elif cfg.has_attn:
        out = attn_out
    else:
        out = ssm_out
    return out, (new_k, new_v), (new_state, new_conv)


def _block(x, bp, cfg: ModelConfig, layer_idx, positions, mode,
           kv=None, ssm_state=None, conv=None, pos=None, with_aux=False,
           paged=None):
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    mix, new_kv, new_ssm = _mixer(h, bp, cfg, layer_idx, positions, mode,
                                  kv, ssm_state, conv, pos, paged)
    if cfg.sandwich_norm:
        mix = rms_norm(mix, bp["post_ln1"], cfg.norm_eps)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe or cfg.d_ff > 0:
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            m = moe_lib.moe_block(h2, bp["moe"], cfg)
            if with_aux:
                aux = moe_lib.load_balance_loss(h2, bp["moe"], cfg)
        else:
            m = mlp(h2, bp["mlp"], cfg.mlp_variant)
        if cfg.sandwich_norm:
            m = rms_norm(m, bp["post_ln2"], cfg.norm_eps)
        x = x + m
    # Residual-stream boundary: "act_seq" is sequence-parallel (sharded
    # over `model`) in training plans to cut layer-boundary activation memory.
    x = logical(x, "batch", "act_seq", "d_model")
    return x, new_kv, new_ssm, aux


# --------------------------------------------------------------------------
# Embedding & head.
# --------------------------------------------------------------------------


def embed_inputs(params, cfg: ModelConfig, tokens=None, embeds=None,
                 positions=None):
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds.astype(params["embed"].dtype)
    if cfg.scale_embedding:
        x = x * np.sqrt(cfg.d_model)
    if cfg.pos_embedding == "sincos":
        x = x + sincos_embedding(positions, cfg.d_model).astype(x.dtype)
    return x


def lm_logits(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logical(logits, "batch", "seq", "vocab")


# --------------------------------------------------------------------------
# Full-sequence forward (training).
# --------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, tokens=None, embeds=None,
            pos_offset: int = 0, remat: bool = False, with_aux: bool = False):
    """Returns logits [B, S, padded_vocab] (fp32); (logits, aux) if with_aux."""
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    positions = pos_offset + jnp.arange(S)[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (B, S))
    x = embed_inputs(params, cfg, tokens, embeds, positions)
    x = logical(x, "batch", "act_seq", "d_model")

    def body(carry, scanned):
        x, aux_sum = carry
        bp, layer_idx = scanned
        x, _, _, aux = _block(x, bp, cfg, layer_idx, positions, "full",
                              with_aux=with_aux)
        return (x, aux_sum + aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux_sum), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], jnp.arange(cfg.n_layers)),
        unroll=cfg.scan_unroll)
    logits = lm_logits(params, cfg, x)
    if with_aux:
        return logits, aux_sum / cfg.n_layers
    return logits


# --------------------------------------------------------------------------
# Prefill: full-sequence forward that materializes the decode cache.
# --------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None):
    """Returns (last-token logits [B, Vpad], DecodeCache at length S)."""
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S)).astype(jnp.int32)
    x = embed_inputs(params, cfg, tokens, embeds, positions)

    def body(x, scanned):
        bp, layer_idx = scanned
        # full-mode block, capturing per-layer K/V and SSM terminal state
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        caches = {}
        is_local = (layer_idx % 2 == 0) if cfg.local_window > 0 else False
        attn_out = None
        if cfg.has_attn:
            q, k, v = attn_lib._project_qkv(h, bp["attn"], cfg, positions)
            caches["k"], caches["v"] = k, v
            attn_out = attn_lib.full_attention(h, bp["attn"], cfg, positions,
                                               is_local)
        ssm_out = None
        if cfg.has_ssm:
            ssm_out, state, conv_w = ssm_lib.ssm_forward(h, bp["ssm"], cfg)
            caches["ssm"], caches["conv"] = state, conv_w
        if cfg.hybrid:
            mix = 0.5 * (rms_norm(attn_out, bp["attn_out_norm"], cfg.norm_eps)
                         + rms_norm(ssm_out, bp["ssm_out_norm"], cfg.norm_eps))
        elif cfg.has_attn:
            mix = attn_out
        else:
            mix = ssm_out
        if cfg.sandwich_norm:
            mix = rms_norm(mix, bp["post_ln1"], cfg.norm_eps)
        x = x + mix
        if cfg.is_moe or cfg.d_ff > 0:
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            m = (moe_lib.moe_block(h2, bp["moe"], cfg) if cfg.is_moe
                 else mlp(h2, bp["mlp"], cfg.mlp_variant))
            if cfg.sandwich_norm:
                m = rms_norm(m, bp["post_ln2"], cfg.norm_eps)
            x = x + m
        x = logical(x, "batch", "seq", "d_model")
        return x, caches

    x, caches = jax.lax.scan(
        body, x, (params["blocks"], jnp.arange(cfg.n_layers)),
        unroll=cfg.scan_unroll)
    logits = lm_logits(params, cfg, x[:, -1:, :])[:, 0]
    pos = jnp.full((B,), S, jnp.int32)
    cache = DecodeCache(
        k=caches.get("k"), v=caches.get("v"),
        ssm=caches.get("ssm"), conv=caches.get("conv"), pos=pos)
    return logits, cache


def prefill_chunk(params, cfg: ModelConfig, tokens, k, v, block_table,
                  start, n_valid, trash_page: int):
    """One *chunk* of a paged prefill: positions ``start + [0, C)``.

    Chunked prefill (Sarathi-style) splits a long prompt into fixed-size
    chunks interleaved with decode steps; each chunk's K/V is scattered into
    the paged pool *inside* this forward (fused prefill->page scatter) and
    its attention reads the earlier chunks back through the block table, so
    no dense whole-prompt cache is ever materialized.

    Args:
      tokens: [B, C] int32; every sequence shares ``start``.  The chunk may
        be bucketed: only the first ``n_valid`` positions are real — the
        tail scatters to ``trash_page`` and is excluded from the logits.
      k / v: [L, P, Hkv, page, D] pools (kernel-native layout).
      block_table: [B, n_pages] page ids covering ``start + C`` tokens.
      start: scalar int32 tokens already resident; n_valid: scalar int32.
    Returns: (logits at position ``start + n_valid - 1`` [B, Vpad] fp32,
      new k, new v).

    SSM/hybrid architectures are not supported (the SSD scan has no
    per-position state checkpoint to resume a bucketed chunk from); callers
    fall back to one-shot prefill for them.
    """
    if cfg.has_ssm:
        raise NotImplementedError(
            "chunked prefill supports attention-only models")
    B, C = tokens.shape
    pos = start + jnp.arange(C, dtype=jnp.int32)
    positions = jnp.broadcast_to(pos[None, :], (B, C))
    x = embed_inputs(params, cfg, tokens, None, positions)
    x = logical(x, "batch", "seq", "d_model")
    paged = {"table": block_table, "n_valid": n_valid, "trash": trash_page}

    def body(x, scanned):
        bp, layer_idx, k_l, v_l = scanned
        x, new_kv, _, _ = _block(x, bp, cfg, layer_idx, positions, "chunk",
                                 kv=(k_l, v_l), pos=start, paged=paged)
        return x, {"k": new_kv[0], "v": new_kv[1]}

    x, ys = jax.lax.scan(
        body, x, (params["blocks"], jnp.arange(cfg.n_layers), k, v),
        unroll=cfg.scan_unroll)
    x_last = jnp.take(x, n_valid - 1, axis=1)[:, None]   # [B, 1, d]
    logits = lm_logits(params, cfg, x_last)[:, 0]
    return logits, ys["k"], ys["v"]


# --------------------------------------------------------------------------
# Decode step.
# --------------------------------------------------------------------------


def _decode_core(params, cfg: ModelConfig, tokens, embeds, pos,
                 k, v, ssm, conv, paged):
    """Shared decode-step body: embed, layer scan, logits.

    ``paged=None`` runs dense cached attention over k/v [L, B, Smax, Hkv, D];
    a paged dict (see ``_mixer``) runs paged attention over pools.
    Returns (logits [B, Vpad] fp32, per-layer ys dict of new state).
    """
    positions = pos[:, None]
    x = embed_inputs(params, cfg, None if tokens is None else tokens[:, None],
                     embeds, positions)
    x = logical(x, "batch", "seq", "d_model")

    def body(x, scanned):
        bp, layer_idx, k_l, v_l, ssm_l, conv_l = scanned
        x, new_kv, new_ssm, _ = _block(
            x, bp, cfg, layer_idx, positions, "decode",
            kv=(k_l, v_l), ssm_state=ssm_l, conv=conv_l, pos=pos,
            paged=paged)
        ys = {}
        if cfg.has_attn:
            ys["k"], ys["v"] = new_kv
        if cfg.has_ssm:
            ys["ssm"], ys["conv"] = new_ssm
        return x, ys

    L = cfg.n_layers
    dummy = jnp.zeros((L,), jnp.int32)
    xs = (params["blocks"], jnp.arange(L),
          k if k is not None else dummy,
          v if v is not None else dummy,
          ssm if ssm is not None else dummy,
          conv if conv is not None else dummy)
    x, ys = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    return lm_logits(params, cfg, x)[:, 0], ys


def decode_step(params, cfg: ModelConfig, tokens, cache: DecodeCache,
                embeds=None):
    """One token for every sequence in the batch.

    Args:
      tokens: [B] int32 (or embeds [B, 1, d] for stub-frontend archs).
      cache: DecodeCache whose attention K/V buffers have a fixed max length.
    Returns: (logits [B, Vpad] fp32, updated DecodeCache)
    """
    logits, ys = _decode_core(params, cfg, tokens, embeds, cache.pos,
                              cache.k, cache.v, cache.ssm, cache.conv, None)
    new_cache = DecodeCache(
        k=ys.get("k", cache.k), v=ys.get("v", cache.v),
        ssm=ys.get("ssm", cache.ssm), conv=ys.get("conv", cache.conv),
        pos=cache.pos + 1)
    return logits, new_cache


def decode_step_paged(params, cfg: ModelConfig, tokens,
                      state: PagedDecodeState, embeds=None, *,
                      attn_impl: str = "jnp", interpret: bool = False):
    """One token for every sequence, attending the paged KV pool directly.

    The per-layer new K/V token is scattered into its page and attention
    reads pages through the block table — no dense [B, S, Hkv, D] cache is
    ever materialized (the device-resident serving decode path).

    Args:
      tokens: [B] int32 (or embeds [B, 1, d] for stub-frontend archs).
      state: PagedDecodeState; ``state.lens`` is the write/attend position.
      attn_impl: "kernel" (Pallas paged kernel) or "jnp" (exact fallback).
    Returns: (logits [B, Vpad] fp32, updated PagedDecodeState with lens+1)
    """
    paged = {"table": state.block_table, "impl": attn_impl,
             "interpret": interpret}
    logits, ys = _decode_core(params, cfg, tokens, embeds, state.lens,
                              state.k, state.v, state.ssm, state.conv, paged)
    new_state = PagedDecodeState(
        k=ys.get("k", state.k), v=ys.get("v", state.v),
        block_table=state.block_table, lens=state.lens + 1,
        ssm=ys.get("ssm", state.ssm), conv=ys.get("conv", state.conv))
    return logits, new_state


def _decode_core_buffered(params, cfg: ModelConfig, tokens, pos, k, v,
                          ssm, conv, table, kh, vh, step_idx, pool_lens):
    """One buffered decode step: like ``_decode_core`` in paged mode, but
    the pools are consumed READ-ONLY (scan xs of the layer scan — never
    copied) and each layer's new K/V token is written to its row of the
    horizon buffer ``kh``/``vh`` [L, B, H, Hkv, head_dim], which the layer
    scan re-stacks into ``ys["k"]``/``ys["v"]``.
    """
    positions = pos[:, None]
    x = embed_inputs(params, cfg, tokens[:, None], None, positions)
    x = logical(x, "batch", "seq", "d_model")

    def body(x, scanned):
        bp, layer_idx, k_l, v_l, kh_l, vh_l, ssm_l, conv_l = scanned
        paged = {"impl": "buffered", "table": table, "kh": kh_l, "vh": vh_l,
                 "step": step_idx, "pool_lens": pool_lens}
        x, new_kv, new_ssm, _ = _block(
            x, bp, cfg, layer_idx, positions, "decode",
            kv=(k_l, v_l), ssm_state=ssm_l, conv=conv_l, pos=pos,
            paged=paged)
        ys = {}
        if cfg.has_attn:
            ys["k"], ys["v"] = new_kv          # updated buffer rows
        if cfg.has_ssm:
            ys["ssm"], ys["conv"] = new_ssm
        return x, ys

    L = cfg.n_layers
    dummy = jnp.zeros((L,), jnp.int32)
    xs = (params["blocks"], jnp.arange(L),
          k if k is not None else dummy,
          v if v is not None else dummy,
          kh if kh is not None else dummy,
          vh if vh is not None else dummy,
          ssm if ssm is not None else dummy,
          conv if conv is not None else dummy)
    x, ys = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    return lm_logits(params, cfg, x)[:, 0], ys


def decode_loop_paged(params, cfg: ModelConfig, tokens,
                      state: PagedDecodeState, key, step0, horizon: int, *,
                      attn_impl: str = "jnp", interpret: bool = False,
                      temperature: float = 0.0):
    """``horizon`` fused decode steps, entirely on device (``lax.scan``).

    Each scan iteration runs one full paged decode step — attention over
    the block table, SSM state update — then samples the next token with
    the per-step folded key (``sampling.step_key(key, step0 + i)``) and
    feeds it straight back as the next step's input, so generating
    ``horizon`` tokens costs one jit dispatch and (at the caller) one
    device→host transfer instead of ``horizon`` of each.

    K/V pool traffic is O(pool) per HORIZON, not per token: for the jnp
    attention path the pools are scan *constants* — each step writes its
    K/V token into a [L, B, H, Hkv, D] side buffer that attention overlays
    onto the gathered pages (bit-identical result, see
    ``attention.paged_decode_attention_buffered``) — and the buffer is
    scattered through the block table once after the loop.  The Pallas
    kernel path keeps the scatter-first loop (the kernel reads pages in
    place, and on TPU buffer donation makes the in-loop pool updates
    in-place).

    The caller must have pre-extended page capacity for ``horizon`` more
    tokens per sequence: positions ``lens .. lens + horizon - 1`` are
    written through the block table with no host allocation in the loop.
    ``step0`` is the global decode-step counter (a traced scalar is fine);
    with ``temperature == 0`` the keys are ignored and the loop is exactly
    ``horizon`` greedy decode steps.

    Args:
      tokens: [B] int32 — each sequence's last generated token.
      state: PagedDecodeState at the pre-loop lengths.
      horizon: static step count (callers bucket it to keep compilations
        O(log max_horizon)).
    Returns: (tokens [B, horizon] int32, PagedDecodeState with lens +
      horizon)
    """
    from repro.models.sampling import sample, step_key

    if not cfg.has_attn or attn_impl == "kernel":
        # scatter-first loop: pools (if any) ride the scan carry
        def body(carry, i):
            toks, st = carry
            logits, st = decode_step_paged(params, cfg, toks, st,
                                           attn_impl=attn_impl,
                                           interpret=interpret)
            toks = sample(logits, cfg, step_key(key, step0 + i),
                          temperature=temperature)
            return (toks, st), toks

        (_, state), toks_h = jax.lax.scan(
            body, (tokens, state), jnp.arange(horizon, dtype=jnp.int32))
        return jnp.moveaxis(toks_h, 0, 1), state

    # buffered loop (jnp path): pools stay out of the carry
    B = tokens.shape[0]
    L = cfg.n_layers
    pool_lens = state.lens
    kh = jnp.zeros((L, B, horizon, cfg.n_kv_heads, cfg.head_dim),
                   state.k.dtype)
    # horizon side buffer shards like the pool: layers over pp, KV heads
    # over tp (identity when no sharding rules are installed)
    kh = logical(kh, "layers", "batch", None, "kv_heads", None)
    vh = jnp.zeros_like(kh)

    def body(carry, i):
        toks, lens, kh, vh, ssm, conv = carry
        logits, ys = _decode_core_buffered(
            params, cfg, toks, lens, state.k, state.v, ssm, conv,
            state.block_table, kh, vh, i, pool_lens)
        toks = sample(logits, cfg, step_key(key, step0 + i),
                      temperature=temperature)
        return (toks, lens + 1, ys["k"], ys["v"],
                ys.get("ssm", ssm), ys.get("conv", conv)), toks

    init = (tokens, state.lens, kh, vh, state.ssm, state.conv)
    (_, lens, kh, vh, ssm, conv), toks_h = jax.lax.scan(
        body, init, jnp.arange(horizon, dtype=jnp.int32))

    # the horizon's ONE pool scatter: buffer -> pages via the block table
    table = state.block_table
    page = state.k.shape[3]
    tpos = pool_lens[:, None] + jnp.arange(horizon)[None, :]      # [B, H]
    pid = jnp.take_along_axis(table, tpos // page, axis=1)        # [B, H]
    off = tpos % page
    dpad = state.k.shape[-1] - kh.shape[-1]
    if dpad:
        kh = jnp.pad(kh, ((0, 0),) * 4 + ((0, dpad),))
        vh = jnp.pad(vh, ((0, 0),) * 4 + ((0, dpad),))
    hidx = jnp.arange(cfg.n_kv_heads)[None, None, :]
    k_pages = state.k.at[:, pid[:, :, None], hidx, off[:, :, None]].set(
        kh.astype(state.k.dtype))
    v_pages = state.v.at[:, pid[:, :, None], hidx, off[:, :, None]].set(
        vh.astype(state.v.dtype))
    new_state = PagedDecodeState(k=k_pages, v=v_pages, block_table=table,
                                 lens=lens, ssm=ssm, conv=conv)
    return jnp.moveaxis(toks_h, 0, 1), new_state
