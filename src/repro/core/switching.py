"""Ad hoc model switching (paper S4.2 + Appendix G, Algorithm 2).

A deployment switch changes the set of replicas and their (TP, PP) strategies.
Because every replica holds the same parameters, each *target* shard can be
fetched from any *source* device whose holdings overlap it, over fast
chip-to-chip links — instead of reloading the model from host storage.

TPU adaptation: "intra-machine NVLink vs inter-machine IB" becomes
"intra-pod ICI vs inter-pod DCN"; the greedy planner prefers intra-pod sources
and balances per-pair communication load exactly as in Algorithm 2.

Parameter geometry: a parameter element is identified by a point in the unit
square (layer fraction l, tensor-parallel fraction f).  A device of a replica
with strategy (tp, pp) at coordinates (stage s, rank r) holds the rectangle
[s/pp, (s+1)/pp) x [r/tp, (r+1)/tp).  Exact ``fractions.Fraction`` cuts keep
the grain decomposition lossless for any tp/pp mix (incl. TP=3, PP=2).
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction

from repro.core.costmodel import CostModel
from repro.core.types import ClusterSpec, Deployment, HardwareSpec, ReplicaConfig


# --------------------------------------------------------------------------
# Placement: deployments -> concrete chip ids.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlacedReplica:
    config: ReplicaConfig
    chips: tuple[int, ...]  # length == config.chips; index = stage * tp + rank

    def holding(self, device_pos: int) -> tuple[Fraction, Fraction, Fraction, Fraction]:
        """Rectangle (l0, l1, f0, f1) held by the device at local position."""
        tp, pp = self.config.tp, self.config.pp
        stage, rank = divmod(device_pos, tp)
        return (Fraction(stage, pp), Fraction(stage + 1, pp),
                Fraction(rank, tp), Fraction(rank + 1, tp))


@dataclasses.dataclass(frozen=True)
class PlacedDeployment:
    replicas: tuple[PlacedReplica, ...]

    @property
    def all_chips(self) -> tuple[int, ...]:
        return tuple(c for r in self.replicas for c in r.chips)


def place_deployment(dep: Deployment, cluster: ClusterSpec,
                     chip_pool: list[int] | None = None) -> PlacedDeployment:
    """Assign chips contiguously (TP ranks adjacent -> same ICI neighborhood).

    Replicas are placed largest-first so big TP groups stay within one pod.
    """
    pool = list(range(cluster.chips)) if chip_pool is None else sorted(chip_pool)
    order = sorted(range(len(dep.replicas)),
                   key=lambda i: -dep.replicas[i].chips)
    placed: dict[int, PlacedReplica] = {}
    cursor = 0
    for i in order:
        cfg = dep.replicas[i]
        chips = tuple(pool[cursor:cursor + cfg.chips])
        if len(chips) < cfg.chips:
            raise ValueError("not enough chips in pool for deployment")
        cursor += cfg.chips
        placed[i] = PlacedReplica(cfg, chips)
    return PlacedDeployment(tuple(placed[i] for i in range(len(dep.replicas))))


# --------------------------------------------------------------------------
# Algorithm 2: greedy switch plan.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Transfer:
    src: int            # global chip id (or -1 for host reload)
    dst: int
    bytes: float
    intra_pod: bool
    grain: tuple        # (l0, l1, f0, f1) fractions, for audit


@dataclasses.dataclass
class SwitchPlan:
    transfers: list[Transfer]
    local_bytes: float          # satisfied from the device's own HBM (free)
    host_bytes: float           # no chip source existed -> host reload path
    total_param_bytes: float

    def moved_bytes(self) -> float:
        return sum(t.bytes for t in self.transfers)

    def estimate_seconds(self, hw: HardwareSpec) -> float:
        """Bottleneck-link estimate: per-chip ICI send/recv + per-host DCN."""
        sent_ici: dict[int, float] = {}
        recv_ici: dict[int, float] = {}
        dcn_host: dict[int, float] = {}
        for t in self.transfers:
            if t.intra_pod:
                sent_ici[t.src] = sent_ici.get(t.src, 0.0) + t.bytes
                recv_ici[t.dst] = recv_ici.get(t.dst, 0.0) + t.bytes
            else:
                for host in (hw.host_of(t.src), hw.host_of(t.dst)):
                    dcn_host[host] = dcn_host.get(host, 0.0) + t.bytes
        t_ici = max(list(sent_ici.values()) + list(recv_ici.values()) + [0.0]) / hw.ici_bw
        t_dcn = max(list(dcn_host.values()) + [0.0]) / hw.dcn_bw
        t_host = self.host_bytes / hw.host_load_bw if self.host_bytes else 0.0
        return max(t_ici, t_dcn) + t_host


def _cuts(values: list[int]) -> list[Fraction]:
    pts = {Fraction(0), Fraction(1)}
    for v in values:
        for i in range(1, v):
            pts.add(Fraction(i, v))
    return sorted(pts)


def plan_switch(
    source: PlacedDeployment,
    target: PlacedDeployment,
    cm: CostModel,
    hw: HardwareSpec | None = None,
) -> SwitchPlan:
    """Algorithm 2 with the intra-machine(-pod)-first heuristic."""
    hw = hw or HardwareSpec()
    param_bytes = cm.p.param_bytes

    # Source holdings: chip -> list of rectangles (a chip may appear once).
    src_holdings: list[tuple[int, tuple[Fraction, Fraction, Fraction, Fraction]]] = []
    for rep in source.replicas:
        for pos, chip in enumerate(rep.chips):
            src_holdings.append((chip, rep.holding(pos)))

    # Atomic grain grid from every tp/pp boundary in either deployment.
    l_cuts = _cuts([r.config.pp for r in source.replicas]
                   + [r.config.pp for r in target.replicas])
    f_cuts = _cuts([r.config.tp for r in source.replicas]
                   + [r.config.tp for r in target.replicas])

    def covers(rect, l0, l1, f0, f1) -> bool:
        return rect[0] <= l0 and rect[1] >= l1 and rect[2] <= f0 and rect[3] >= f1

    # Pre-index: grain -> source chips holding it.
    grain_sources: dict[tuple, list[int]] = {}
    grains: list[tuple] = []
    for li in range(len(l_cuts) - 1):
        for fi in range(len(f_cuts) - 1):
            g = (l_cuts[li], l_cuts[li + 1], f_cuts[fi], f_cuts[fi + 1])
            holders = [chip for chip, rect in src_holdings
                       if covers(rect, *g)]
            grains.append(g)
            grain_sources[g] = holders

    pair_load: dict[tuple[int, int], float] = {}
    src_total: dict[int, float] = {}
    transfers: list[Transfer] = []
    local_bytes = 0.0
    host_bytes = 0.0

    for rep in target.replicas:
        for pos, chip in enumerate(rep.chips):
            need = rep.holding(pos)
            for g in grains:
                if not covers(need, *g):
                    continue
                vol = float((g[1] - g[0]) * (g[3] - g[2])) * param_bytes
                holders = grain_sources[g]
                if chip in holders:
                    local_bytes += vol        # already resident -> free
                    continue
                if not holders:
                    host_bytes += vol         # cold start: host reload path
                    continue
                intra = [s for s in holders if hw.pod_of(s) == hw.pod_of(chip)]
                pool = intra if intra else holders
                # Greedy: min per-pair load, tie-break min per-source total
                # (pseudocode uses C_{s->t}; the text's "least data sent so
                # far" is the tie-break).
                s_star = min(pool, key=lambda s: (pair_load.get((s, chip), 0.0),
                                                  src_total.get(s, 0.0), s))
                pair_load[(s_star, chip)] = pair_load.get((s_star, chip), 0.0) + vol
                src_total[s_star] = src_total.get(s_star, 0.0) + vol
                transfers.append(Transfer(s_star, chip, vol, bool(intra), g))
    return SwitchPlan(transfers, local_bytes, host_bytes, param_bytes)


# --------------------------------------------------------------------------
# KV-cache migration (paper S4.2 "KV cache transmission").
# --------------------------------------------------------------------------


@dataclasses.dataclass
class KVMigrationPlan:
    drained: list[int]          # request ids left to finish on the source
    migrated: list[tuple[int, float]]  # (request id, bytes moved)
    handoff: list[int] = dataclasses.field(default_factory=list)
    # destination-side pre-allocated KV buffers: page-rounded moved bytes
    # inflated by the fragmentation headroom (paper: fixed-size buffers)
    reserved_bytes: float = 0.0

    def moved_bytes(self) -> float:
        return sum(b for _, b in self.migrated)

    def estimate_seconds(self, hw: HardwareSpec, intra_pod: bool = True) -> float:
        """Transfer stall: moved bytes over the fast (intra-pod ICI) or slow
        (inter-pod DCN) link.  Page handoffs are accounting-only — free."""
        bw = hw.ici_bw if intra_pod else hw.dcn_bw
        return self.moved_bytes() / bw if self.moved_bytes() else 0.0


def plan_kv_migration(
    cm: CostModel,
    request_lens: dict[int, int],
    drain_threshold: int = 2048,
    headroom: float = 0.15,
    *,
    shared_pool: bool = False,
    page_tokens: int = 16,
) -> KVMigrationPlan:
    """Short-sequence requests drain on the source; long ones migrate.

    ``shared_pool=True`` models the runtime's page-handoff path (source and
    destination replicas are views of one device ``BlockPool``): migrated
    sequences transfer by ownership re-registration, moving zero bytes.
    Otherwise bytes move page-granular — a sequence of context ``ctx``
    occupies ``ceil(ctx / page_tokens)`` full pages, and the whole page
    transfers, not just its live tokens.

    ``headroom`` reproduces the paper's pre-allocated fixed-size KV buffers
    (+10-20% for fragmentation) — it inflates the destination's reserved
    bytes, not the moved bytes.
    """
    drained: list[int] = []
    migrated: list[tuple[int, float]] = []
    handoff: list[int] = []
    reserved = 0.0
    for rid, ctx in request_lens.items():
        if ctx < drain_threshold:
            drained.append(rid)
            continue
        pages = -(-ctx // page_tokens)
        bytes_ = cm.p.seq_mem_bytes(pages * page_tokens)
        reserved += bytes_ * (1.0 + headroom)
        if shared_pool:
            handoff.append(rid)
        else:
            migrated.append((rid, bytes_))
    return KVMigrationPlan(drained, migrated, handoff, reserved)
