"""Core datatypes shared by the OServe scheduling / switching stack.

Terminology follows the paper:
  - A *workload type* j clusters requests by (input_len, output_len); its arrival
    rate lambda_j is the number of requests arriving in one time span (1 minute).
  - A *replica* k is one model instance deployed on `chips` devices with a
    (tp, pp) parallelism strategy.  dp degree of the cluster = number of replicas.
  - A *deployment* is the list of replicas (resource allocation + strategies).
  - A *serving strategy* = deployment + workload assignment x[k][j].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class WorkloadType:
    """One k-means cluster of requests.

    Attributes:
      in_len / out_len: centroid sequence lengths (tokens).
      rate: arrival rate for the current time span (requests / span).
      cached_frac: observed fraction of this type's prompt tokens served
        from the prefix cache (0 = every prompt prefills from token 0).
        Fed back from the runtime (``Orchestrator.observe_prefix_hits``);
        the cost model discounts per-type prefill compute by it, so
        shared-prefix-heavy types steer toward warm pools.
    """

    in_len: int
    out_len: int
    rate: float = 0.0
    cached_frac: float = 0.0

    @property
    def total_len(self) -> int:
        return self.in_len + self.out_len

    def with_rate(self, rate: float) -> "WorkloadType":
        return dataclasses.replace(self, rate=rate)

    def with_cached_frac(self, cached_frac: float) -> "WorkloadType":
        return dataclasses.replace(
            self, cached_frac=min(max(float(cached_frac), 0.0), 1.0))


# Serving roles for disaggregated prefill/decode deployments: a "mixed"
# replica runs both phases (the default, and the only pre-disaggregation
# behavior); a "prefill" replica admits new requests and hands the finished
# context to a "decode" replica at first-token readiness; a "decode"
# replica never admits new requests — it only adopts handed-off contexts
# and runs the fused decode loop.
REPLICA_ROLES = ("mixed", "prefill", "decode")


@dataclasses.dataclass(frozen=True)
class ReplicaConfig:
    """Parallelism strategy (and serving role) for one model replica.

    tp * pp == chips.  `tp` may be non-power-of-two (the paper uses TP=3).
    ``role`` defaults to "mixed"; see ``REPLICA_ROLES`` and
    ``docs/architecture.md`` for the disaggregated prefill/decode split.
    """

    tp: int
    pp: int = 1
    role: str = "mixed"

    def __post_init__(self):
        if self.role not in REPLICA_ROLES:
            raise ValueError(f"unknown replica role {self.role!r} "
                             f"(expected one of {REPLICA_ROLES})")

    @property
    def chips(self) -> int:
        return self.tp * self.pp

    def with_role(self, role: str) -> "ReplicaConfig":
        return dataclasses.replace(self, role=role)

    def __str__(self) -> str:  # matches the paper's "(TP=3, PP=2)" notation
        tag = "" if self.role == "mixed" else f", {self.role}"
        if self.pp == 1:
            return f"(TP={self.tp}{tag})"
        return f"(TP={self.tp}, PP={self.pp}{tag})"


@dataclasses.dataclass(frozen=True)
class Deployment:
    """A heterogeneous model deployment: one ReplicaConfig per replica."""

    replicas: tuple[ReplicaConfig, ...]

    @property
    def dp(self) -> int:
        return len(self.replicas)

    @property
    def total_chips(self) -> int:
        return sum(r.chips for r in self.replicas)

    def __str__(self) -> str:
        return f"DP={self.dp} [" + ", ".join(str(r) for r in self.replicas) + "]"

    def canonical(self) -> "Deployment":
        """Order-independent form (replicas sorted) for dedup during search."""
        key = lambda r: (-r.chips, -r.tp, -r.pp, r.role)
        return Deployment(tuple(sorted(self.replicas, key=key)))


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for one accelerator generation.

    Defaults: TPU v5e (the target platform).  The paper's H100 cluster is kept
    as an alternate spec for reproducing its absolute numbers.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    hbm_bytes: float = 16e9             # HBM capacity per chip
    ici_bw: float = 50e9                # bytes/s per ICI link (intra-pod)
    dcn_bw: float = 12.5e9              # bytes/s per host (inter-pod)
    chips_per_pod: int = 256
    chips_per_host: int = 4             # v5e host = 4 chips
    host_load_bw: float = 2e9           # host->HBM reload path (disk/PCIe class)
    mxu_flops_efficiency: float = 0.6   # achievable fraction of peak in serving
    hbm_efficiency: float = 0.8

    def pod_of(self, chip: int) -> int:
        return chip // self.chips_per_pod

    def host_of(self, chip: int) -> int:
        return chip // self.chips_per_host


H100_SPEC = HardwareSpec(
    name="h100",
    peak_flops=989e12,
    hbm_bw=3.35e12,
    hbm_bytes=80e9,
    ici_bw=400e9,        # NVLink
    dcn_bw=200e9,        # InfiniBand
    chips_per_pod=8,     # one DGX box
    chips_per_host=8,
    host_load_bw=4e9,
)

TPU_V5E_SPEC = HardwareSpec()


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A serving cluster: `chips` devices with a hardware spec."""

    chips: int
    hw: HardwareSpec = TPU_V5E_SPEC

    @property
    def pods(self) -> int:
        return max(1, math.ceil(self.chips / self.hw.chips_per_pod))


def valid_strategies(
    chips: int,
    max_tp: int | None = None,
    max_pp: int = 8,
) -> list[ReplicaConfig]:
    """All (tp, pp) factorizations of `chips`, matching the paper's search space.

    TP is capped at the fast-interconnect domain (chips_per_pod for TPU; the
    paper capped TP at 8 = one NVLink node).
    """
    out = []
    for tp in range(1, chips + 1):
        if chips % tp:
            continue
        pp = chips // tp
        if max_tp is not None and tp > max_tp:
            continue
        if pp > max_pp:
            continue
        out.append(ReplicaConfig(tp=tp, pp=pp))
    return out


def assignment_as_fractions(
    x: Sequence[Sequence[float]], rates: Sequence[float]
) -> list[list[float]]:
    """x[k][j] request counts -> f[k][j] fraction of type j routed to replica k."""
    frac = []
    for row in x:
        frac.append([row[j] / rates[j] if rates[j] > 0 else 0.0 for j in range(len(row))])
    return frac
