"""Flow-network machinery for the lower-level workload assignment (paper S3.2).

Two solvers are provided (see DESIGN.md "Faithfulness note"):

  * ``maxflow_preflow_push`` — the paper's preflow-push algorithm (highest-label
    with gap heuristic) on integer capacities.  Exact for unit-uniform networks
    (each replica consumes the same normalized units per request regardless of
    type), and used as the general graph utility.
  * ``simplex_maximize`` — an exact dense-simplex packing-LP solver for the
    general mixed-unit network (generalized flow), maximizing served requests
    under constraints C1-C3.

``WorkloadFlowNetwork`` builds the paper's network (source, workload nodes,
intermediate nodes, replica in/out nodes with LCM-normalized capacity, sink),
dispatches to the right solver, and exposes the saturation analysis the
upper-level search (S3.3) consumes.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

EPS = 1e-9


# --------------------------------------------------------------------------
# Preflow-push max-flow (highest-label + gap heuristic), integer capacities.
# --------------------------------------------------------------------------

def maxflow_preflow_push(
    n: int,
    edges: list[tuple[int, int, int]],
    s: int,
    t: int,
) -> tuple[int, list[int]]:
    """Max s-t flow via preflow-push (Cheriyan & Maheshwari style).

    Args:
      n: number of nodes.
      edges: (u, v, capacity) with non-negative integer capacities.
      s, t: source / sink node ids.

    Returns:
      (flow_value, flow_per_input_edge)
    """
    if s == t:
        return 0, [0] * len(edges)
    # Build adjacency with paired residual arcs.
    head: list[int] = []       # arc -> destination
    cap: list[int] = []        # arc -> residual capacity
    adj: list[list[int]] = [[] for _ in range(n)]
    orig: list[int] = []       # input edge -> arc id
    for (u, v, c) in edges:
        orig.append(len(head))
        adj[u].append(len(head)); head.append(v); cap.append(int(c))
        adj[v].append(len(head)); head.append(u); cap.append(0)

    height = [0] * n
    excess = [0] * n
    count = [0] * (2 * n + 1)  # gap heuristic: nodes per height
    height[s] = n
    count[0] = n - 1
    count[n] = 1

    # Saturate source arcs.
    for a in adj[s]:
        if cap[a] > 0:
            v = head[a]
            excess[v] += cap[a]
            excess[s] -= cap[a]
            cap[a ^ 1] += cap[a]
            cap[a] = 0

    # Highest-label bucket queue.
    buckets: list[list[int]] = [[] for _ in range(2 * n + 1)]
    in_bucket = [False] * n
    hi = 0
    for v in range(n):
        if v not in (s, t) and excess[v] > 0:
            buckets[height[v]].append(v)
            in_bucket[v] = True
            hi = max(hi, height[v])

    arc_ptr = [0] * n  # current-arc optimization

    def push(a: int, u: int) -> None:
        nonlocal hi
        v = head[a]
        d = min(excess[u], cap[a])
        cap[a] -= d
        cap[a ^ 1] += d
        excess[u] -= d
        excess[v] += d
        if v not in (s, t) and not in_bucket[v] and excess[v] > 0:
            buckets[height[v]].append(v)
            in_bucket[v] = True
            # The pusher may have been relabeled above the current scan
            # pointer mid-discharge; keep `hi` an upper bound on active heights.
            hi = max(hi, height[v])

    def relabel(u: int) -> None:
        nonlocal hi
        old = height[u]
        mh = 2 * n
        for a in adj[u]:
            if cap[a] > 0:
                mh = min(mh, height[head[a]] + 1)
        count[old] -= 1
        # Gap heuristic: if old height has no nodes, lift everything above it.
        if count[old] == 0 and old < n:
            for v in range(n):
                if v != s and old < height[v] <= n:
                    count[height[v]] -= 1
                    height[v] = n + 1
                    count[height[v]] += 1
        height[u] = mh
        count[mh] += 1
        arc_ptr[u] = 0

    while True:
        while hi >= 0 and not buckets[hi]:
            hi -= 1
        if hi < 0:
            break
        u = buckets[hi].pop()
        in_bucket[u] = False
        if u in (s, t) or excess[u] <= 0:
            continue
        while excess[u] > 0:
            if arc_ptr[u] == len(adj[u]):
                relabel(u)
                if height[u] > 2 * n - 1:
                    break
            else:
                a = adj[u][arc_ptr[u]]
                if cap[a] > 0 and height[u] == height[head[a]] + 1:
                    push(a, u)
                else:
                    arc_ptr[u] += 1
        if excess[u] > 0 and height[u] <= 2 * n - 1:
            buckets[height[u]].append(u)
            in_bucket[u] = True
            hi = max(hi, height[u])
        else:
            hi = max(hi, 0)

    flow_val = excess[t]
    # Each input edge owns its residual pair, so the backward residual
    # capacity equals the net flow pushed through that edge.
    per_edge = [cap[a ^ 1] for a in orig]
    return flow_val, per_edge


def maxflow_edmonds_karp(
    n: int, edges: list[tuple[int, int, int]], s: int, t: int
) -> int:
    """Reference oracle for tests (BFS augmenting paths)."""
    capm = [[0] * n for _ in range(n)]
    for u, v, c in edges:
        capm[u][v] += c
    flow = 0
    while True:
        parent = [-1] * n
        parent[s] = s
        q = deque([s])
        while q and parent[t] == -1:
            u = q.popleft()
            for v in range(n):
                if parent[v] == -1 and capm[u][v] > 0:
                    parent[v] = u
                    q.append(v)
        if parent[t] == -1:
            return flow
        # find bottleneck
        v, aug = t, math.inf
        while v != s:
            u = parent[v]
            aug = min(aug, capm[u][v])
            v = u
        v = t
        while v != s:
            u = parent[v]
            capm[u][v] -= aug
            capm[v][u] += aug
            v = u
        flow += aug


# --------------------------------------------------------------------------
# Dense simplex for packing LPs:  max c.x  s.t.  A x <= b, x >= 0, b >= 0.
# --------------------------------------------------------------------------

def simplex_maximize(
    c: list[float], A: list[list[float]], b: list[float]
) -> tuple[list[float], float]:
    """Exact simplex (Bland's rule; slack-variable initial basis).

    Requires b >= 0 (always true for capacities), so phase-1 is unnecessary.
    """
    m = len(A)
    nvars = len(c)
    assert all(bi >= -EPS for bi in b), "packing LP requires b >= 0"
    # Tableau: rows 0..m-1 constraints, row m objective (maximize -> minimize -c).
    # Columns: nvars original + m slacks + 1 rhs.
    ncols = nvars + m + 1
    T = [[0.0] * ncols for _ in range(m + 1)]
    for i in range(m):
        for j in range(nvars):
            T[i][j] = float(A[i][j])
        T[i][nvars + i] = 1.0
        T[i][-1] = max(0.0, float(b[i]))
    for j in range(nvars):
        T[m][j] = -float(c[j])
    basis = [nvars + i for i in range(m)]

    max_iters = 50 * (m + nvars + 10)
    for _ in range(max_iters):
        # Bland: entering = lowest index with negative reduced cost.
        enter = -1
        for j in range(nvars + m):
            if T[m][j] < -EPS:
                enter = j
                break
        if enter == -1:
            break
        # Ratio test with Bland tie-break on basis index.
        leave, best, best_basis = -1, math.inf, math.inf
        for i in range(m):
            a = T[i][enter]
            if a > EPS:
                ratio = T[i][-1] / a
                if ratio < best - EPS or (abs(ratio - best) <= EPS
                                          and basis[i] < best_basis):
                    leave, best, best_basis = i, ratio, basis[i]
        if leave == -1:
            raise ArithmeticError("LP unbounded (capacities must be finite)")
        # Pivot.
        piv = T[leave][enter]
        T[leave] = [v / piv for v in T[leave]]
        for i in range(m + 1):
            if i != leave and abs(T[i][enter]) > EPS:
                f = T[i][enter]
                T[i] = [vi - f * vl for vi, vl in zip(T[i], T[leave])]
        basis[leave] = enter
    x = [0.0] * nvars
    for i in range(m):
        if basis[i] < nvars:
            x[basis[i]] = max(0.0, T[i][-1])
    value = sum(ci * xi for ci, xi in zip(c, x))
    return x, value


# --------------------------------------------------------------------------
# The paper's workload flow network.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FlowSolution:
    x: list[list[float]]            # x[k][j] requests of type j -> replica k
    throughput: float               # total served requests per span
    utilization: list[float]        # per-replica normalized load in [0, 1]
    unserved: list[float]           # per-type leftover demand
    solver: str                     # "preflow_push" | "simplex"


class WorkloadFlowNetwork:
    """S -> w_j -> i_{k,j} -> c_k_in -> c_k_out -> T with LCM normalization."""

    def __init__(self, rates: list[float], n_cap: list[list[float]],
                 e_cap: list[list[float]] | None = None):
        """Args:
          rates: lambda_j, requests of type j arriving this span.
          n_cap: n[k][j], replica-k capacity for pure type-j load (per span).
          e_cap: e[k][j] per-type routing caps; defaults to n[k][j].
        """
        self.rates = [max(0.0, r) for r in rates]
        self.n_cap = n_cap
        self.e_cap = e_cap or [row[:] for row in n_cap]
        self.K = len(n_cap)
        self.J = len(rates)
        # LCM normalization (paper S3.2) on integer-rounded capacities.
        # floor: integral flow on floored capacities keeps C3 <= 1 exactly
        self.n_int = [[max(0, int(v)) for v in row] for row in n_cap]
        self.M: list[int] = []
        self.m_units: list[list[int]] = []
        for k in range(self.K):
            pos = [v for v in self.n_int[k] if v > 0]
            Mk = 1
            for v in pos:
                Mk = Mk * v // math.gcd(Mk, v)
            self.M.append(Mk if pos else 0)
            self.m_units.append([
                (self.M[k] // v) if v > 0 else 0 for v in self.n_int[k]
            ])

    # -- structure ---------------------------------------------------------

    def node_ids(self):
        """S=0, w_j=1+j, i_{k,j}, c_k_in, c_k_out, T (for the flow graph)."""
        S = 0
        w = {j: 1 + j for j in range(self.J)}
        base = 1 + self.J
        i = {(k, j): base + k * self.J + j for k in range(self.K) for j in range(self.J)}
        base += self.K * self.J
        cin = {k: base + k for k in range(self.K)}
        cout = {k: base + self.K + k for k in range(self.K)}
        T = base + 2 * self.K
        return S, w, i, cin, cout, T, T + 1

    def unit_uniform(self) -> bool:
        """True iff every replica charges the same units per request across types
        it can serve -> the network is an exact standard max-flow instance."""
        for k in range(self.K):
            units = {self.m_units[k][j] for j in range(self.J)
                     if self.n_int[k][j] > 0}
            if len(units) > 1:
                return False
        return True

    # -- solvers -------------------------------------------------------------

    def solve(self) -> FlowSolution:
        if self.unit_uniform():
            return self._solve_maxflow()
        return self._solve_lp()

    def _solve_maxflow(self) -> FlowSolution:
        S, w, i, cin, cout, T, n_nodes = self.node_ids()
        edges: list[tuple[int, int, int]] = []
        eidx: dict[tuple[int, int], int] = {}
        for j in range(self.J):
            edges.append((S, w[j], int(self.rates[j])))   # floor: integral demand
        for k in range(self.K):
            for j in range(self.J):
                cap_kj = min(self.e_cap[k][j], self.n_int[k][j])
                if self.n_int[k][j] <= 0:
                    continue
                eidx[(k, j)] = len(edges)
                edges.append((w[j], i[(k, j)], int(cap_kj)))
                edges.append((i[(k, j)], cin[k], int(cap_kj)))
            # node capacity in requests (uniform units -> M_k/m = n)
            per_req = next((self.m_units[k][j] for j in range(self.J)
                            if self.n_int[k][j] > 0), 0)
            node_cap = self.M[k] // per_req if per_req else 0
            edges.append((cin[k], cout[k], node_cap))
            edges.append((cout[k], T, 10 ** 12))
        val, per_edge = maxflow_preflow_push(n_nodes, edges, S, T)
        x = [[0.0] * self.J for _ in range(self.K)]
        for (k, j), idx in eidx.items():
            x[k][j] = float(per_edge[idx])
        return self._finish(x, "preflow_push")

    def _solve_lp(self) -> FlowSolution:
        K, J = self.K, self.J
        nvars = K * J
        var = lambda k, j: k * J + j
        c = [1.0] * nvars
        A: list[list[float]] = []
        b: list[float] = []
        # C1: per-type demand.
        for j in range(J):
            row = [0.0] * nvars
            for k in range(K):
                row[var(k, j)] = 1.0
            A.append(row); b.append(self.rates[j])
        # C2: per-edge caps.
        for k in range(K):
            for j in range(J):
                row = [0.0] * nvars
                row[var(k, j)] = 1.0
                A.append(row)
                b.append(min(self.e_cap[k][j], self.n_cap[k][j])
                         if self.n_cap[k][j] > 0 else 0.0)
        # C3: node capacity sharing, sum_j x_kj / n_kj <= 1.
        for k in range(K):
            row = [0.0] * nvars
            any_pos = False
            for j in range(J):
                if self.n_cap[k][j] > 0:
                    row[var(k, j)] = 1.0 / self.n_cap[k][j]
                    any_pos = True
                else:
                    row[var(k, j)] = 0.0  # covered by C2 zero cap
            if any_pos:
                A.append(row); b.append(1.0)
        xs, _ = simplex_maximize(c, A, b)
        x = [[xs[var(k, j)] for j in range(J)] for k in range(K)]
        return self._finish(x, "simplex")

    def _finish(self, x: list[list[float]], solver: str) -> FlowSolution:
        util = []
        for k in range(self.K):
            u = 0.0
            for j in range(self.J):
                if self.n_cap[k][j] > 0:
                    u += x[k][j] / self.n_cap[k][j]
            util.append(u)
        served_per_type = [sum(x[k][j] for k in range(self.K)) for j in range(self.J)]
        unserved = [max(0.0, self.rates[j] - served_per_type[j]) for j in range(self.J)]
        throughput = sum(served_per_type)
        return FlowSolution(x, throughput, util, unserved, solver)

    # -- saturation analysis for the upper level -----------------------------

    def bottlenecks(self, sol: FlowSolution, sat: float = 0.99,
                    under: float = 0.7) -> tuple[list[int], list[int]]:
        """(overutilized replica ids, underutilized replica ids)."""
        over = [k for k, u in enumerate(sol.utilization) if u >= sat]
        low = [k for k, u in enumerate(sol.utilization) if u < under]
        return over, low

    # -- makespan balancing (paper Appendix D) --------------------------------

    def balance(self, sol: FlowSolution, iters: int = 200) -> FlowSolution:
        """Redistribute the optimal flow to minimize the max replica
        utilization (completion time) without changing per-type totals.

        Max-flow/LP solutions sit at simplex corners that may saturate one
        replica while another idles; the paper's Appendix-D examples balance
        fractions to equalize busy time.  Pairwise moves: shift type-j flow
        from the most- to a less-utilized replica, bounded by e_{k,j}.
        """
        K, J = self.K, self.J
        # Seed from the capacity-proportional allocation of the LP's per-type
        # totals (the unique symmetric point on identical replicas; LP corner
        # solutions skew type composition even at equal utilization), clipped
        # to the e_{k,j} routing caps with redistribution; the pairwise mover
        # below then repairs any C3 violations and polishes toward min sum(u^2).
        totals = [sum(sol.x[k][j] for k in range(K)) for j in range(J)]
        x = [[0.0] * J for _ in range(K)]
        for j in range(J):
            remaining = totals[j]
            open_ks = [k for k in range(K) if self.n_cap[k][j] > 0
                       and min(self.e_cap[k][j], self.n_cap[k][j]) > 0]
            for _ in range(4):
                if remaining <= 1e-9 or not open_ks:
                    break
                weights = {k: self.n_cap[k][j] for k in open_ks}
                wsum = sum(weights.values())
                placed = 0.0
                next_open = []
                for k in open_ks:
                    want = remaining * weights[k] / wsum
                    cap = min(self.e_cap[k][j], self.n_cap[k][j]) - x[k][j]
                    give = min(want, max(cap, 0.0))
                    x[k][j] += give
                    placed += give
                    if cap - give > 1e-9:
                        next_open.append(k)
                remaining -= placed
                open_ks = next_open
            if remaining > 1e-9:
                # fall back to the LP allocation for this type
                for k in range(K):
                    x[k][j] = sol.x[k][j]

        def util(k):
            return sum(x[k][j] / self.n_cap[k][j]
                       for j in range(J) if self.n_cap[k][j] > 0)

        us = [util(k) for k in range(K)]
        for _ in range(iters):
            # best pairwise move under the sum-of-squares objective (strictly
            # convex -> converges to the unique most-balanced feasible point,
            # robust to stochastic arrivals, unlike LP corner solutions)
            best = None
            for k1 in range(K):
                for j in range(J):
                    if x[k1][j] <= 1e-9 or self.n_cap[k1][j] <= 0:
                        continue
                    for k2 in range(K):
                        if k2 == k1 or self.n_cap[k2][j] <= 0:
                            continue
                        if us[k2] >= us[k1] - 1e-9:
                            continue
                        cap_e = min(self.e_cap[k2][j], self.n_cap[k2][j])
                        head = cap_e - x[k2][j]
                        if head <= 1e-9:
                            continue
                        delta = (us[k1] - us[k2]) / (
                            1.0 / self.n_cap[k1][j] + 1.0 / self.n_cap[k2][j])
                        delta = min(delta, x[k1][j], head)
                        du1 = delta / self.n_cap[k1][j]
                        du2 = delta / self.n_cap[k2][j]
                        gain = (us[k1] ** 2 + us[k2] ** 2
                                - (us[k1] - du1) ** 2 - (us[k2] + du2) ** 2)
                        # latency-aware preference (paper S5.2: route types
                        # that benefit from model parallelism to the bigger
                        # replicas): among near-equal-util moves, prefer
                        # placing flow where its per-request service is
                        # faster (higher n_{k,j})
                        lat_gain = delta * (1.0 / self.n_cap[k1][j]
                                            - 1.0 / self.n_cap[k2][j])
                        gain = gain + 0.2 * lat_gain
                        if best is None or gain > best[0]:
                            best = (gain, j, k1, k2, delta)
            if best is None or best[0] < 1e-12:
                break
            _, j, k1, k2, delta = best
            x[k1][j] -= delta
            x[k2][j] += delta
            us[k1] = util(k1)
            us[k2] = util(k2)
        out = self._finish(x, sol.solver + "+balance")
        # Guarantee: never worse than the input solution (the proportional
        # seed + mover is a heuristic; fall back when it loses on either
        # served throughput or peak utilization).
        if (out.throughput < sol.throughput - 1e-6
                or max(out.utilization, default=0.0)
                > max(sol.utilization, default=0.0) + 1e-9):
            return self._finish([row[:] for row in sol.x],
                                sol.solver + "+balance")
        return out
