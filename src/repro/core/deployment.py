"""Upper-level model deployment search (paper S3.3 + Appendix F, Algorithm 1).

Two searchers:

  * ``exhaustive_search`` — enumerate every multiset partition of the chips
    into replicas x every strategy combination.  The paper's optimality
    baseline (S5.4); tractable only for small clusters.
  * ``flow_guided_search`` — Algorithm 1: start from a uniform deployment,
    iteratively (a) solve the lower-level flow network, (b) classify replicas
    as over-/under-utilized, (c) randomly merge / split / swap chips between
    them, (d) re-optimize parallelism strategies, accepting only improvements,
    until no improvement for ``patience`` rounds.
"""
from __future__ import annotations

import dataclasses
import itertools
import random

from repro.core.assignment import AssignmentResult, assign_workloads
from repro.core.costmodel import CostModel
from repro.core.types import Deployment, WorkloadType, valid_strategies


@dataclasses.dataclass
class SearchResult:
    deployment: Deployment
    assignment: AssignmentResult
    evaluations: int
    iterations: int

    @property
    def throughput(self) -> float:
        return self.assignment.throughput


class _Evaluator:
    """Memoized lower-level evaluation keyed on the canonical deployment.

    ``score`` orders deployments by (served demand, served demand under 2x
    stress, -max utilization): the stress term measures true capacity
    headroom so demand-limited ties never keep junk replicas alive.
    """

    STRESS = 2.0

    def __init__(self, cm: CostModel, workloads: list[WorkloadType]):
        self.cm = cm
        self.workloads = workloads
        self.stressed = [w.with_rate(w.rate * self.STRESS) for w in workloads]
        self.cache: dict[tuple, AssignmentResult] = {}
        self.stress_cache: dict[tuple, float] = {}
        self.evaluations = 0

    @staticmethod
    def _key(dep: Deployment):
        return tuple(sorted((r.tp, r.pp, r.role) for r in dep.replicas))

    def __call__(self, dep: Deployment) -> AssignmentResult:
        key = self._key(dep)
        hit = self.cache.get(key)
        if hit is not None:
            return hit
        self.evaluations += 1
        res = assign_workloads(self.cm, dep, self.workloads)
        self.cache[key] = res
        return res

    def stress_throughput(self, dep: Deployment) -> float:
        key = self._key(dep)
        if key not in self.stress_cache:
            self.stress_cache[key] = assign_workloads(
                self.cm, dep, self.stressed, balance=False).throughput
        return self.stress_cache[key]

    def score(self, dep: Deployment) -> tuple:
        res = self(dep)
        # Residence (latency) terms under the optimized assignment: the tail
        # is set by the slowest (replica, type) pair actually carrying flow;
        # deployments that park long-output types on weak replicas lose here
        # even when raw throughput ties.
        max_resp, wsum, wresp = 0.0, 0.0, 0.0
        for k, rc in enumerate(dep.replicas):
            for j, w in enumerate(self.workloads):
                xkj = res.solution.x[k][j]
                if xkj > 1e-6:
                    p = self.cm.replica_perf(rc, w)
                    r = p.prefill_time + w.out_len * p.decode_step_time
                    max_resp = max(max_resp, r)
                    wresp += xkj * r
                    wsum += xkj
        mean_resp = wresp / max(wsum, 1e-9)

        def q(v: float) -> int:
            # 2% geometric buckets: differences below the cost model's
            # fidelity don't justify a more fragile deployment
            import math
            return int(math.log(max(v, 1e-9)) / math.log(1.02))

        return (q(res.throughput),
                q(self.stress_throughput(dep)),
                -round(max_resp, 1),
                -round(mean_resp, 2),
                -dep.dp,                      # Occam: fewer replicas on ties
                -res.latency_proxy())


# --------------------------------------------------------------------------
# Exhaustive enumeration (optimality baseline).
# --------------------------------------------------------------------------

def _partitions(total: int, min_part: int, max_parts: int):
    """Non-increasing partitions of `total` into parts >= min_part."""
    def rec(remaining: int, max_part: int, acc: list[int]):
        if remaining == 0:
            yield tuple(acc)
            return
        if len(acc) >= max_parts:
            return
        for part in range(min(max_part, remaining), min_part - 1, -1):
            acc.append(part)
            yield from rec(remaining - part, part, acc)
            acc.pop()
    yield from rec(total, total, [])


def enumerate_deployments(
    chips: int,
    min_chips: int,
    max_tp: int = 8,
    max_pp: int = 8,
    max_replicas: int = 16,
    limit: int = 200_000,
) -> list[Deployment]:
    out: list[Deployment] = []
    for sizes in _partitions(chips, min_chips, max_replicas):
        per_size_strats = [valid_strategies(s, max_tp=max_tp, max_pp=max_pp)
                           for s in sizes]
        if any(not s for s in per_size_strats):
            continue
        for combo in itertools.product(*per_size_strats):
            out.append(Deployment(tuple(combo)).canonical())
            if len(out) >= limit:
                return _dedup(out)
    return _dedup(out)


def _dedup(deps: list[Deployment]) -> list[Deployment]:
    seen, out = set(), []
    for d in deps:
        key = tuple(sorted((r.tp, r.pp, r.role) for r in d.replicas))
        if key not in seen:
            seen.add(key)
            out.append(d)
    return out


def exhaustive_search(
    cm: CostModel,
    chips: int,
    workloads: list[WorkloadType],
    max_tp: int = 8,
    max_pp: int = 8,
) -> SearchResult:
    ev = _Evaluator(cm, workloads)
    best = None
    deps = enumerate_deployments(chips, cm.min_chips(), max_tp, max_pp)
    for dep in deps:
        score = ev.score(dep)
        if best is None or score > best[0]:
            best = (score, dep)
    assert best is not None, "no feasible deployment (cluster too small?)"
    return SearchResult(best[1], ev(best[1]), ev.evaluations, len(deps))


# --------------------------------------------------------------------------
# Algorithm 1: flow-network-guided generation.
# --------------------------------------------------------------------------

def uniform_initial(cm: CostModel, chips: int, max_tp: int, max_pp: int
                    ) -> Deployment:
    """Paper initialization: identical replicas sized by min memory, pure TP."""
    per = max(cm.min_chips(), 1)
    # Prefer a size that admits a pure-TP strategy.
    while per <= chips and not valid_strategies(per, max_tp=max_tp, max_pp=max_pp):
        per += 1
    per = min(per, chips)
    n_replicas = max(1, chips // per)
    sizes = [per] * n_replicas
    leftover = chips - per * n_replicas
    i = 0
    while leftover > 0:
        sizes[i % n_replicas] += 1
        leftover -= 1
        i += 1
    reps = []
    for s in sizes:
        strats = valid_strategies(s, max_tp=max_tp, max_pp=max_pp)
        if not strats:
            strats = valid_strategies(s, max_tp=s, max_pp=s)
        pure_tp = [r for r in strats if r.pp == 1]
        reps.append(pure_tp[-1] if pure_tp else strats[0])
    return Deployment(tuple(reps))


def _reoptimize_strategies(
    ev: _Evaluator, sizes: list[int], max_tp: int, max_pp: int,
    full_product_limit: int = 256,
) -> tuple[Deployment, AssignmentResult] | None:
    """Pick {s_r} maximizing throughput for fixed chip sizes.

    Full cartesian enumeration when small (paper's description); coordinate
    ascent otherwise (documented heuristic for scalability).
    """
    per_size = [valid_strategies(s, max_tp=max_tp, max_pp=max_pp) for s in sizes]
    if any(not s for s in per_size):
        return None
    n_combos = 1
    for s in per_size:
        n_combos *= len(s)
    if n_combos <= full_product_limit:
        best = None
        for combo in itertools.product(*per_size):
            dep = Deployment(tuple(combo))
            sc = ev.score(dep)
            if best is None or sc > best[0]:
                best = (sc, dep)
        return best[1], ev(best[1])
    # Coordinate ascent.
    current = [opts[0] for opts in per_size]
    best_sc = ev.score(Deployment(tuple(current)))
    for _ in range(2):
        improved = False
        for r, opts in enumerate(per_size):
            for cand in opts:
                trial = current[:]
                trial[r] = cand
                sc = ev.score(Deployment(tuple(trial)))
                if sc > best_sc:
                    current, best_sc, improved = trial, sc, True
        if not improved:
            break
    dep = Deployment(tuple(current))
    return dep, ev(dep)


def flow_guided_search(
    cm: CostModel,
    chips: int,
    workloads: list[WorkloadType],
    max_tp: int = 8,
    max_pp: int = 8,
    patience: int = 20,
    max_iters: int = 200,
    seed: int = 0,
    initial: Deployment | None = None,
) -> SearchResult:
    """Algorithm 1 (Appendix F)."""
    rng = random.Random(seed)
    ev = _Evaluator(cm, workloads)
    min_chips = cm.min_chips()

    dep = initial if initial is not None else uniform_initial(cm, chips, max_tp, max_pp)
    best = ev(dep)
    best_score = ev.score(dep)
    stale = 0
    iters = 0
    for iters in range(1, max_iters + 1):
        sizes = [r.chips for r in dep.replicas]
        sol = ev(dep).solution
        over = [k for k, u in enumerate(sol.utilization) if u >= 0.99]
        under = [k for k, u in enumerate(sol.utilization) if u < 0.7]
        new_sizes = sizes[:]
        mutated = False

        # Over-utilized replicas: merge with a peer, or take chips from an
        # under-utilized one (swap).
        for k in list(over):
            if k >= len(new_sizes):
                continue
            op = rng.choice(["merge", "swap"])
            if op == "merge" and len(over) > 1 and len(new_sizes) > 1:
                others = [o for o in over if o != k and o < len(new_sizes)]
                if not others:
                    continue
                o = rng.choice(others)
                a, b = sorted((k, o))
                new_sizes[a] = new_sizes[a] + new_sizes[b]
                del new_sizes[b]
                over = [i for i in over if i != o]
                mutated = True
                break  # indices shifted; one structural op per round
            elif op == "swap" and under:
                u = rng.choice([u_ for u_ in under if u_ < len(new_sizes)] or [None])
                if u is None:
                    continue
                give = new_sizes[u] - min_chips
                if give <= 0:
                    continue
                delta = rng.randint(1, give)
                new_sizes[u] -= delta
                new_sizes[k] += delta
                mutated = True

        # Under-utilized replicas: split in two, or give chips away (handled
        # above as the receiving side of swap).
        if not mutated:
            for k in under:
                if k >= len(new_sizes):
                    continue
                if rng.random() < 0.5 and new_sizes[k] >= 2 * min_chips:
                    cut = rng.randint(min_chips, new_sizes[k] - min_chips)
                    new_sizes.append(new_sizes[k] - cut)
                    new_sizes[k] = cut
                    mutated = True
                    break
                elif over:
                    o = rng.choice(over)
                    give = new_sizes[k] - min_chips
                    if give <= 0:
                        continue
                    delta = rng.randint(1, give)
                    new_sizes[k] -= delta
                    new_sizes[o % len(new_sizes)] += delta
                    mutated = True
                    break

        if not mutated:
            # Random perturbation keeps the search unbiased (Appendix F).
            if len(new_sizes) >= 2 and rng.random() < 0.5:
                a, b = rng.sample(range(len(new_sizes)), 2)
                if new_sizes[a] > min_chips:
                    new_sizes[a] -= 1
                    new_sizes[b] += 1
                    mutated = True
            elif new_sizes and new_sizes[0] >= 2 * min_chips:
                cut = new_sizes[0] // 2
                new_sizes.append(new_sizes[0] - cut)
                new_sizes[0] = cut
                mutated = True

        if not mutated or sum(new_sizes) != chips:
            stale += 1
            if stale >= patience:
                break
            continue

        reopt = _reoptimize_strategies(ev, new_sizes, max_tp, max_pp)
        if reopt is None:
            stale += 1
            if stale >= patience:
                break
            continue
        cand_dep, cand_res = reopt
        if ev.score(cand_dep) > best_score:
            dep, best = cand_dep, cand_res
            best_score = ev.score(cand_dep)
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
    return SearchResult(dep, best, ev.evaluations, iters)


def role_split_search(
    cm: CostModel,
    dep: Deployment,
    workloads: list[WorkloadType],
    ev: _Evaluator | None = None,
) -> Deployment:
    """Pick the best prefill:decode role split for a fixed deployment shape.

    Disaggregation is a *role* axis on top of the chip/strategy search:
    for each split size the ``n_pre`` largest-TP replicas take the
    ``prefill`` role (prefill is compute-bound; TP divides its latency)
    and the rest take ``decode`` (bandwidth-bound, batch-hungry), scored
    by the same evaluator the deployment search uses — coupled admission
    capacity via ``profile_capacities``, then latency residence on ties.
    Because throughput quantizes into 2% buckets, a demand-limited span
    (both shapes serve all arrivals) is decided by the residence terms,
    where prefill-only replicas shine on long-prompt-heavy mixes — the
    planner disaggregates exactly when there is capacity headroom to
    spend on latency.  Returns the all-mixed baseline when no split wins.
    """
    if dep.dp < 2:
        return dep
    if ev is None:
        ev = _Evaluator(cm, workloads)
    mixed = Deployment(tuple(r.with_role("mixed") for r in dep.replicas))
    best, best_sc = mixed, ev.score(mixed)
    order = sorted(range(dep.dp),
                   key=lambda k: (-dep.replicas[k].tp,
                                  -dep.replicas[k].chips))
    for n_pre in range(1, dep.dp):
        pre = set(order[:n_pre])
        cand = Deployment(tuple(
            r.with_role("prefill" if k in pre else "decode")
            for k, r in enumerate(mixed.replicas)))
        sc = ev.score(cand)
        if sc > best_sc:
            best, best_sc = cand, sc
    return best
