"""Lower-level workload assignment (paper S3.2).

Given a concrete model deployment, profile per-replica capacities with the
cost model, build the workload flow network, and solve for the optimal
x[k][j] assignment (requests of type j routed to replica k this span).
"""
from __future__ import annotations

import dataclasses

from repro.core.costmodel import CostModel, profile_capacities
from repro.core.flownet import FlowSolution, WorkloadFlowNetwork
from repro.core.types import Deployment, WorkloadType, assignment_as_fractions


@dataclasses.dataclass
class AssignmentResult:
    deployment: Deployment
    workloads: list[WorkloadType]
    solution: FlowSolution
    n_cap: list[list[float]]
    e_cap: list[list[float]]

    @property
    def throughput(self) -> float:
        return self.solution.throughput

    @property
    def fractions(self) -> list[list[float]]:
        rates = [w.rate for w in self.workloads]
        return assignment_as_fractions(self.solution.x, rates)

    def latency_proxy(self) -> float:
        """Span completion-time proxy: max over replicas of (load / capacity).

        Matches the Appendix-D examples, where quality of a strategy is the
        max over replicas of its busy time.
        """
        return max(self.solution.utilization, default=0.0)


def assign_workloads(
    cm: CostModel,
    deployment: Deployment,
    workloads: list[WorkloadType],
    capacity_scale: list[float] | None = None,
    balance: bool = True,
) -> AssignmentResult:
    """Solve the lower-level problem for one deployment.

    Args:
      capacity_scale: optional per-replica multiplicative degradation factors
        (EWMA-observed health; straggler mitigation shrinks a slow replica's
        capacity so flow routes around it).
      balance: apply the Appendix-D makespan-balancing post-pass (same
        throughput, minimized max utilization).
    """
    replicas = list(deployment.replicas)
    n, e = profile_capacities(cm, replicas, workloads)
    if capacity_scale is not None:
        n = [[v * capacity_scale[k] for v in row] for k, row in enumerate(n)]
        e = [[v * capacity_scale[k] for v in row] for k, row in enumerate(e)]
    # Per-type latency SLO on the routing edges (paper S5.2: each type goes
    # to the replicas that suit it): a replica whose per-request residence is
    # far worse than the best available for that type gets edge capacity 0 —
    # unless it is the only feasible server for the type.
    slo_mult = 3.0
    for j, w in enumerate(workloads):
        resp = []
        for k, rc in enumerate(replicas):
            p = cm.replica_perf(rc, w)
            resp.append(p.prefill_time + w.out_len * p.decode_step_time
                        if p.fits else float("inf"))
        best = min(resp)
        if best == float("inf"):
            continue
        ok = [k for k in range(len(replicas)) if resp[k] <= slo_mult * best]
        for k in range(len(replicas)):
            if k not in ok:
                e[k][j] = 0.0
    rates = [w.rate for w in workloads]
    net = WorkloadFlowNetwork(rates, n, e)
    sol = net.solve()
    if balance and len(replicas) > 1:
        sol = net.balance(sol)
    return AssignmentResult(deployment, list(workloads), sol, n, e)
