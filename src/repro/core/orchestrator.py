"""OServe control loop (paper Appendix A) + failure/elasticity handling.

Per time span:
  1. the workload predictor forecasts per-type arrival rates for the next span;
  2. the scheduler (S3) searches the serving strategy — heterogeneous model
     deployment + max-flow workload assignment — warm-started from the current
     deployment;
  3. if the deployment changed, the switch planner (S4.2) computes the ad hoc
     parameter-transfer plan and its cost (vs. a naive reload).

``on_cluster_change`` implements Appendix C: node failures / elastic resizes
re-run the same loop with the surviving chip count; EWMA health scaling
(straggler mitigation) shrinks a degraded replica's capacities so the flow
re-routes around it.

Observation hooks (fed by ``serving.cluster.ClusterRuntime`` and the
discrete-event simulator driver, not just by predictions):

  * ``observe_health(achieved_fraction)`` — per-replica achieved/expected
    throughput for the last span; the EWMA scales the current deployment's
    capacities in the next assignment, so traffic shifts away from
    stragglers.
  * ``observe_rates(rates)`` — realized per-type arrival counts; the EWMA
    is exposed via ``blended_workloads`` so drivers can correct (or replace)
    the predictor's forecast with what actually arrived.
  * ``observe_inflight(context_lens, shared_pool)`` — context lengths the
    next deployment switch would have to migrate.  ``plan_span`` prices the
    KV migration (``switching.plan_kv_migration``) into the switch-cost
    term: a runtime whose replicas share one ``BlockPool`` migrates by page
    handoff (free), while a cross-pool cluster pays bytes-over-link — so
    plans prefer handoff-friendly switches and demand a larger predicted
    gain before a switch that would stall long in-flight contexts.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.assignment import assign_workloads
from repro.core.costmodel import CostModel
from repro.core.deployment import flow_guided_search, role_split_search
from repro.core.switching import (PlacedDeployment, place_deployment,
                                  plan_kv_migration, plan_switch)
from repro.core.types import ClusterSpec, Deployment, WorkloadType


@dataclasses.dataclass
class OrchestratorConfig:
    span_seconds: float = 60.0
    max_tp: int = 8
    max_pp: int = 4
    search_seed: int = 0
    search_patience: int = 20
    switch_hysteresis: float = 1.05   # require 5% predicted gain to switch
    ewma_alpha: float = 0.3
    # how strongly mid-span rebalance churn raises the switch bar: each
    # EWMA'd rebalance/preempt move adds this much to the hysteresis margin
    # (capped at +0.25), so a cluster the rebalancer is actively reshaping
    # demands a bigger predicted win before the planner reshapes it again
    rebalance_churn_gain: float = 0.02
    # consider disaggregated prefill/decode role splits on top of the
    # chip/strategy search (``deployment.role_split_search``); the
    # all-mixed deployment remains the baseline every split must beat
    disaggregate: bool = False


@dataclasses.dataclass
class SpanPlan:
    deployment: Deployment
    placed: PlacedDeployment
    fractions: list[list[float]]
    throughput: float
    switch_seconds: float       # param transfer + KV migration stall
    reload_seconds: float
    changed_replicas: list[int]
    search_time: float
    kv_migration_seconds: float = 0.0   # the KV share of switch_seconds


class Orchestrator:
    def __init__(self, cm: CostModel, cluster: ClusterSpec,
                 cfg: OrchestratorConfig | None = None):
        self.cm = cm
        self.cluster = cluster
        self.cfg = cfg or OrchestratorConfig()
        self.current: Deployment | None = None
        self.placed: PlacedDeployment | None = None
        self.health: np.ndarray | None = None   # per-replica EWMA in (0, 1]
        self.observed_rates: np.ndarray | None = None  # per-type EWMA
        self.prefix_hit_rate: np.ndarray | None = None  # per-type EWMA [0, 1]
        self.inflight_lens: list[int] = []      # contexts a switch migrates
        self.inflight_shared_pool: bool = True  # page handoff available?
        self.rebalance_churn = 0.0              # EWMA of moves per span
        # decision audit sink (serving.telemetry.DecisionAudit): when set
        # (by ClusterRuntime wiring a Telemetry bundle), every plan_span
        # decision records its inputs + predicted share for later joining
        # with the realized SpanReport into a calibration error
        self.audit = None

    # -- observation (health / stragglers, realized rates) ---------------------

    def observe_health(self, achieved_fraction: list[float]) -> None:
        """achieved/(expected) throughput per replica for the last span."""
        obs = np.clip(np.asarray(achieved_fraction, float), 0.05, 1.0)
        if self.health is None or len(self.health) != len(obs):
            self.health = obs
        else:
            a = self.cfg.ewma_alpha
            self.health = (1 - a) * self.health + a * obs

    def observe_rates(self, rates) -> None:
        """Realized per-type arrival counts for the last span (EWMA)."""
        obs = np.asarray(rates, float)
        if self.observed_rates is None or len(self.observed_rates) != len(obs):
            self.observed_rates = obs
        else:
            a = self.cfg.ewma_alpha
            self.observed_rates = (1 - a) * self.observed_rates + a * obs

    def observe_prefix_hits(self, hit_rates) -> None:
        """Per-type prefix-cache hit rates for the last span (EWMA).

        ``hit_rates[j]``: fraction of type j's prompt tokens served from
        the prefix cache this span (token-weighted).  NaN entries mean the
        type saw no admissions — their EWMA is left untouched rather than
        decayed toward zero.  ``plan_span`` feeds the EWMA into
        ``WorkloadType.cached_frac`` so the cost model discounts per-type
        prefill compute and steers shared-prefix-heavy types toward
        replicas whose pools are warm.
        """
        obs = np.asarray(hit_rates, float)
        if (self.prefix_hit_rate is None
                or len(self.prefix_hit_rate) != len(obs)):
            self.prefix_hit_rate = np.clip(np.nan_to_num(obs), 0.0, 1.0)
            return
        a = self.cfg.ewma_alpha
        seen = ~np.isnan(obs)
        blended = ((1 - a) * self.prefix_hit_rate
                   + a * np.clip(np.nan_to_num(obs), 0.0, 1.0))
        self.prefix_hit_rate = np.where(seen, blended, self.prefix_hit_rate)

    def observe_rebalance(self, moves: int) -> None:
        """Mid-span rebalancer activity for the last span (EWMA).

        ``moves``: sequences the cluster rebalancer migrated or preempted
        during the span.  High churn means the *intra*-span mechanism is
        already reshaping load — the planner then raises its switch
        hysteresis bar (see ``plan_span``) so the two control loops do not
        fight over the same imbalance."""
        a = self.cfg.ewma_alpha
        self.rebalance_churn = ((1 - a) * self.rebalance_churn
                                + a * float(moves))

    def observe_inflight(self, context_lens: list[int],
                         shared_pool: bool = True) -> None:
        """Record what a deployment switch decided now would migrate.

        ``context_lens``: current context (prompt + generated) of every
        in-flight request; ``shared_pool``: replicas partition one device
        pool, so migrations are page handoffs (zero bytes moved).
        """
        self.inflight_lens = [int(c) for c in context_lens]
        self.inflight_shared_pool = bool(shared_pool)

    def switch_kv_seconds(self, drain_threshold: int = 2048) -> float:
        """KV-migration stall a switch would add, per the last observation."""
        if not self.inflight_lens:
            return 0.0
        plan = plan_kv_migration(
            self.cm, dict(enumerate(self.inflight_lens)),
            drain_threshold=drain_threshold,
            shared_pool=self.inflight_shared_pool)
        return plan.estimate_seconds(self.cluster.hw)

    def blended_workloads(self, workloads: list[WorkloadType],
                          trust: float = 0.5) -> list[WorkloadType]:
        """Correct predicted rates with the observed-rate EWMA.

        ``trust`` is the weight on the observation (0 = pure prediction,
        1 = pure observation); with no observations yet, predictions pass
        through unchanged."""
        if (self.observed_rates is None
                or len(self.observed_rates) != len(workloads)):
            return list(workloads)
        return [w.with_rate((1 - trust) * w.rate + trust * float(o))
                for w, o in zip(workloads, self.observed_rates)]

    # -- the per-span decision ---------------------------------------------------

    def plan_span(self, workloads: list[WorkloadType],
                  force: bool = False) -> SpanPlan:
        t0 = time.time()
        # fold the observed per-type prefix-cache hit rate into the types
        # before pricing anything: the cost model then discounts prefill
        # compute for shared-prefix-heavy types (warm-pool steering)
        if (self.prefix_hit_rate is not None
                and len(self.prefix_hit_rate) == len(workloads)):
            workloads = [w.with_cached_frac(float(h))
                         for w, h in zip(workloads, self.prefix_hit_rate)]
        search = flow_guided_search(
            self.cm, self.cluster.chips, workloads,
            max_tp=self.cfg.max_tp, max_pp=self.cfg.max_pp,
            patience=self.cfg.search_patience, seed=self.cfg.search_seed,
            initial=self.current)
        new_dep, result = search.deployment, search.assignment
        if self.cfg.disaggregate and new_dep.dp >= 2:
            # role axis on top of the shape search: split the chosen
            # deployment into prefill/decode specialists when the
            # evaluator scores a split above the all-mixed baseline
            rd = role_split_search(self.cm, new_dep, workloads)
            if rd.replicas != new_dep.replicas:
                new_dep = rd
                result = assign_workloads(self.cm, new_dep, workloads)
        scale = None
        if (self.health is not None and self.current is not None
                and len(self.health) == self.current.dp):
            scale = list(self.health)

        # KV-migration stall the candidate switch would add (free when the
        # runtime migrates by page handoff): switching must clear a bar
        # raised by the stall's share of the span, so plans prefer
        # handoff-friendly switches.
        kv_s = 0.0
        if (self.current is not None
                and new_dep.replicas != self.current.replicas):
            kv_s = self.switch_kv_seconds()

        result_scaled = False
        margin = self.cfg.switch_hysteresis   # the gain bar actually applied
        if self.current is not None and not force:
            cur_res = assign_workloads(self.cm, self.current, workloads,
                                       capacity_scale=scale)
            # Switch only for a clear win: >hysteresis gain in served demand
            # or in stressed capacity (robust headroom), or the same
            # throughput at materially lower peak utilization (queueing).
            stressed = [w.with_rate(w.rate * 2.0) for w in workloads]
            new_cap = assign_workloads(self.cm, new_dep, stressed,
                                       balance=False).throughput
            cur_cap = assign_workloads(self.cm, self.current, stressed,
                                       balance=False).throughput
            h = (self.cfg.switch_hysteresis
                 + kv_s / self.cfg.span_seconds
                 + min(0.25, self.cfg.rebalance_churn_gain
                       * self.rebalance_churn))
            margin = h
            thr_gain = result.throughput > h * cur_res.throughput
            cap_gain = (result.throughput >= 0.999 * cur_res.throughput
                        and new_cap > h * cur_cap)
            lat_gain = (result.throughput >= 0.999 * cur_res.throughput
                        and new_cap >= 0.999 * cur_cap
                        and kv_s <= 0.05 * self.cfg.span_seconds
                        and result.latency_proxy()
                        < 0.95 * cur_res.latency_proxy())
            if not (thr_gain or cap_gain or lat_gain):
                new_dep, result = self.current, cur_res
                kv_s = 0.0               # no switch -> nothing migrates
            result_scaled = result is cur_res

        # Health must reach the routed fractions even when the *search* wins
        # with the structurally-same deployment: re-solve its assignment under
        # the EWMA capacity scale so stragglers shed traffic either way
        # (skipped when the kept result already carries the scale).
        if (scale is not None and self.current is not None
                and new_dep.replicas == self.current.replicas
                and not result_scaled):
            result = assign_workloads(self.cm, new_dep, workloads,
                                      capacity_scale=scale)

        switch_s = 0.0
        reload_s = self.cm.reload_seconds()
        changed: list[int] = list(range(new_dep.dp))
        new_placed = place_deployment(new_dep, self.cluster)
        if (self.placed is not None and self.current is not None
                and new_dep.replicas == self.current.replicas):
            changed = []
            kv_s = 0.0
        elif self.placed is not None:
            plan = plan_switch(self.placed, new_placed, self.cm,
                               self.cluster.hw)
            switch_s = plan.estimate_seconds(self.cluster.hw) + kv_s
        self.current, self.placed = new_dep, new_placed
        plan = SpanPlan(new_dep, new_placed, result.fractions,
                        result.throughput, switch_s, reload_s, changed,
                        time.time() - t0, kv_migration_seconds=kv_s)
        if self.audit is not None:
            # workloads already carry the cached_frac EWMA folded in above
            self.audit.record_plan(plan, workloads, health=scale,
                                   hysteresis_margin=margin,
                                   kv_stall_s=kv_s,
                                   switched=bool(changed))
        return plan

    # -- fault tolerance / elasticity (Appendix C) -------------------------------

    def observe_failures(self, dead_replicas: list[int],
                         surviving_chips: int) -> None:
        """Replica deaths reported by the runtime: shrink the chip budget
        and prune the dead replicas from the planner's deployment state.

        ``dead_replicas`` index the deployment the runtime was running
        (cluster replica order == ``current.replicas`` order after an
        applied plan).  Pruning keeps ``current``'s total chips equal to
        the surviving budget, so the next ``plan_span`` both warm-starts
        from and compares against a deployment that is actually feasible —
        degraded-mode replanning re-solves over the survivors.  Health
        entries are pruned in lockstep so EWMA state stays aligned.
        """
        self.cluster = ClusterSpec(int(surviving_chips), self.cluster.hw)
        dead = set(dead_replicas)
        if self.current is not None:
            alive = tuple(rc for i, rc in enumerate(self.current.replicas)
                          if i not in dead)
            self.current = Deployment(alive) if alive else None
        if self.placed is not None:
            alive_p = tuple(r for i, r in enumerate(self.placed.replicas)
                            if i not in dead)
            self.placed = PlacedDeployment(alive_p) if alive_p else None
        if self.health is not None:
            keep = [a for i, a in enumerate(self.health) if i not in dead]
            self.health = np.asarray(keep) if keep else None

    def observe_rejoin(self, live_replicas: tuple, surviving_chips: int,
                       health_index: int | None = None) -> None:
        """A dead replica was repaired: re-admit its chips to the planning
        budget and point the planner's deployment state at what the runtime
        now runs (inverse of ``observe_failures``).

        ``live_replicas``: the full live ``ReplicaConfig`` tuple in cluster
        order after the repair; ``health_index``: the repaired replica's
        position within it — a neutral health entry (1.0) is inserted there
        so the EWMA stays aligned and the rebuilt replica starts with a
        clean record rather than inheriting its dying throughput.
        """
        self.cluster = ClusterSpec(int(surviving_chips), self.cluster.hw)
        if not live_replicas:
            return
        self.current = Deployment(tuple(live_replicas))
        self.placed = place_deployment(self.current, self.cluster)
        if self.health is not None and health_index is not None:
            if len(self.health) == len(live_replicas) - 1:
                self.health = np.insert(self.health, health_index, 1.0)
            elif len(self.health) != len(live_replicas):
                self.health = None    # stale shape: restart the EWMA

    def on_switch_rollback(self, live_replicas: tuple) -> None:
        """A transactional switch failed and the runtime restored the old
        deployment: point the planner back at what is actually running
        (the most recent ``plan_span`` had already committed the new
        deployment to ``current``/``placed``)."""
        if not live_replicas:
            self.current = None
            self.placed = None
            return
        self.current = Deployment(tuple(live_replicas))
        self.placed = place_deployment(self.current, self.cluster)

    def on_cluster_change(self, new_chips: int,
                          workloads: list[WorkloadType]) -> SpanPlan:
        """Node failure or elastic resize: re-plan on the surviving chips.

        The switch plan sources only from chips present in both clusters, so
        a shrink never reads from dead devices.
        """
        self.cluster = ClusterSpec(new_chips, self.cluster.hw)
        # keep the old placement for switch-plan sourcing, but search fresh:
        # the warm-started mutation loop preserves total chips, which no
        # longer matches the pool
        if self.placed is not None and new_chips < self.placed.all_chips[-1] + 1:
            # shrink: drop shards on dead chips from the source set
            surviving = []
            for rep in self.placed.replicas:
                if all(c < new_chips for c in rep.chips):
                    surviving.append(rep)
            self.placed = (PlacedDeployment(tuple(surviving))
                           if surviving else None)
        self.current = None
        return self.plan_span(workloads, force=True)
