"""Appendix-E "one-time profiling", adapted to TPU v5e as an analytical model.

The paper profiles (i) per-layer prefill latency, (ii) per-layer decode latency,
and (iii) pipeline communication latency per (TP degree x workload type), then
composes them into per-replica capacities ``n_{k,j}`` (max type-j requests per
time span) and edge capacities ``e_{k,j}``.

This container has no TPU, so the measurement step is replaced by a roofline
cost model over the same quantities (the profiling *interface* is pluggable:
``CostModel.measure_*`` can be overridden by a table of real measurements).
The model follows Vidur-style decomposition, which the paper itself cites as
the basis of its profiler:

  prefill: compute-bound   t = FLOPs / (chips * peak * eff) + TP collectives + PP sends
  decode : HBM-bound       t = bytes(weights + KV) / (chips * bw * eff) + collectives

Capacities additionally respect the replica's KV/state memory budget.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

from repro.core.types import HardwareSpec, ReplicaConfig, WorkloadType

BF16 = 2  # bytes


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """The minimal architecture description the cost model needs.

    Derived from a full ``repro.models.config.ModelConfig`` via
    ``ModelConfig.profile()``; kept separate so the scheduler layer has no
    dependency on the model zoo.
    """

    name: str
    n_layers: int
    d_model: int
    n_q_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # MoE (0 experts == dense)
    n_experts: int = 0
    top_k: int = 0
    # SSM / hybrid
    ssm_state: int = 0            # d_state per head (0 == no SSM path)
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    hybrid_attn: bool = True      # hybrid archs keep an attention path too
    attn_free: bool = False       # pure SSM (mamba2): no KV cache at all
    param_bytes_per: float = BF16

    # ---------------- parameter counts ----------------

    @property
    def q_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attn_params_per_layer(self) -> int:
        if self.attn_free:
            return 0
        d = self.d_model
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    @property
    def mlp_params_per_layer(self) -> int:
        if self.n_experts > 0:
            router = self.d_model * self.n_experts
            return router + self.n_experts * 3 * self.d_model * self.d_ff
        if self.d_ff == 0:
            return 0
        return 3 * self.d_model * self.d_ff  # SwiGLU: gate, up, down

    @property
    def mlp_active_params_per_layer(self) -> int:
        if self.n_experts > 0:
            router = self.d_model * self.n_experts
            return router + self.top_k * 3 * self.d_model * self.d_ff
        return self.mlp_params_per_layer

    @property
    def ssm_params_per_layer(self) -> int:
        if self.ssm_state == 0:
            return 0
        d_inner = self.ssm_heads * self.ssm_head_dim
        # in_proj (x, z, B, C, dt) + out_proj + conv
        n_bc = 2 * self.ssm_state
        return (self.d_model * (2 * d_inner + n_bc + self.ssm_heads)
                + d_inner * self.d_model + 4 * (d_inner + n_bc))

    @property
    def params_per_layer(self) -> int:
        return (self.attn_params_per_layer + self.mlp_params_per_layer
                + self.ssm_params_per_layer + 2 * self.d_model)

    @property
    def active_params_per_layer(self) -> int:
        return (self.attn_params_per_layer + self.mlp_active_params_per_layer
                + self.ssm_params_per_layer + 2 * self.d_model)

    @property
    def param_count(self) -> int:
        return self.n_layers * self.params_per_layer + 2 * self.vocab * self.d_model

    @property
    def active_param_count(self) -> int:
        return self.n_layers * self.active_params_per_layer + 2 * self.vocab * self.d_model

    @property
    def param_bytes(self) -> float:
        return self.param_count * self.param_bytes_per

    # ---------------- per-token memory ----------------

    @property
    def kv_bytes_per_token(self) -> float:
        if self.attn_free:
            return 0.0
        return 2 * self.kv_dim * self.n_layers * BF16

    @property
    def state_bytes_per_seq(self) -> float:
        if self.ssm_state == 0:
            return 0.0
        per_layer = self.ssm_heads * self.ssm_head_dim * self.ssm_state * 4  # fp32 state
        return per_layer * self.n_layers

    def seq_mem_bytes(self, total_len: int) -> float:
        """Resident bytes for one sequence at context length ``total_len``."""
        return self.kv_bytes_per_token * total_len + self.state_bytes_per_seq

    # ---------------- FLOPs ----------------

    def matmul_flops_per_token(self) -> float:
        """Dense matmul FLOPs per token (excludes attention score FLOPs)."""
        per_layer = 2 * (self.attn_params_per_layer
                         + self.mlp_active_params_per_layer
                         + self.ssm_params_per_layer)
        return self.n_layers * per_layer + 2 * self.vocab * self.d_model

    def attn_score_flops(self, new_tokens: int, ctx: int) -> float:
        """QK^T + AV FLOPs for `new_tokens` queries attending to <=ctx keys."""
        if self.attn_free:
            return 0.0
        avg_keys = (ctx + max(ctx - new_tokens, 0)) / 2  # causal average
        return self.n_layers * 4 * new_tokens * avg_keys * self.q_dim

    def ssm_scan_flops(self, new_tokens: int) -> float:
        if self.ssm_state == 0:
            return 0.0
        d_inner = self.ssm_heads * self.ssm_head_dim
        return self.n_layers * 6 * new_tokens * d_inner * self.ssm_state

    def prefill_flops(self, in_len: int) -> float:
        return (in_len * self.matmul_flops_per_token()
                + self.attn_score_flops(in_len, in_len)
                + self.ssm_scan_flops(in_len))

    def decode_flops_per_token(self, ctx: int) -> float:
        return (self.matmul_flops_per_token()
                + self.attn_score_flops(1, ctx)
                + self.ssm_scan_flops(1))


@dataclasses.dataclass(frozen=True)
class ReplicaPerf:
    """Measured/estimated serving characteristics for one (replica, workload)."""

    prefill_time: float          # s, one request's prefill on the replica
    decode_step_time: float      # s, one batched decode step at b_eff
    b_eff: int                   # effective decode batch size
    throughput: float            # requests/s for this type if served alone
    fits: bool


class CostModel:
    """One-time profiling result for one model on one hardware spec."""

    def __init__(self, profile: ModelProfile, hw: HardwareSpec | None = None,
                 span_seconds: float = 60.0, max_batch: int = 256,
                 prefill_chunk: int = 512, step_overhead: float = 3e-3,
                 collective_alpha: float = 15e-6):
        self.p = profile
        self.hw = hw or HardwareSpec()
        self.span_seconds = span_seconds
        self.max_batch = max_batch
        self.prefill_chunk = prefill_chunk
        # fixed costs that create the paper's DP-vs-TP trade-off (Fig. 1):
        # per-step scheduler/sampling/launch overhead (amortized over the
        # batch -> favors consolidation for memory-bound workloads) and a
        # per-collective latency floor (hurts large TP at small batch ->
        # favors DP for compute-bound short workloads).
        self.step_overhead = step_overhead
        self.collective_alpha = collective_alpha

    # -- building blocks (the quantities Appendix E profiles) ---------------

    def tp_collective_time(self, tokens: int, tp: int) -> float:
        """Two ring all-reduces of [tokens, d_model] bf16 per layer
        (bandwidth term + per-collective latency floor)."""
        if tp == 1:
            return 0.0
        bytes_ = tokens * self.p.d_model * BF16
        ring = 2.0 * (tp - 1) / tp * bytes_ / self.hw.ici_bw
        return 2 * self.p.n_layers * (ring + self.collective_alpha)

    def pp_send_time(self, tokens: int, pp: int) -> float:
        """(pp-1) boundary activations of [tokens, d_model] bf16."""
        if pp == 1:
            return 0.0
        return (pp - 1) * tokens * self.p.d_model * BF16 / self.hw.ici_bw

    def measure_prefill(self, cfg: ReplicaConfig, in_len: int) -> float:
        """End-to-end prefill latency of one request (compute-bound phase)."""
        flops = self.p.prefill_flops(in_len)
        compute = flops / (cfg.chips * self.hw.peak_flops * self.hw.mxu_flops_efficiency)
        return compute + self.tp_collective_time(in_len, cfg.tp) + \
            self.pp_send_time(in_len, cfg.pp)

    def measure_decode_step(self, cfg: ReplicaConfig, batch: int, ctx: int) -> float:
        """One decode step (all pp stages) for `batch` sequences at context ctx."""
        p, hw = self.p, self.hw
        weight_bytes = p.active_param_count * p.param_bytes_per
        kv_bytes = batch * p.seq_mem_bytes(ctx)
        mem_t = (weight_bytes + kv_bytes) / (cfg.chips * hw.hbm_bw * hw.hbm_efficiency)
        flops = batch * p.decode_flops_per_token(ctx)
        comp_t = flops / (cfg.chips * hw.peak_flops * hw.mxu_flops_efficiency)
        return (max(mem_t, comp_t) + self.step_overhead
                + self.tp_collective_time(batch, cfg.tp)
                + self.pp_send_time(batch, cfg.pp))

    # -- composition ---------------------------------------------------------

    def kv_budget_bytes(self, cfg: ReplicaConfig) -> float:
        """HBM left for KV/state across the whole replica (10% runtime reserve)."""
        total_hbm = cfg.chips * self.hw.hbm_bytes
        return 0.9 * total_hbm - self.p.param_bytes

    def fits(self, cfg: ReplicaConfig) -> bool:
        return self.kv_budget_bytes(cfg) > 0

    def max_concurrency(self, cfg: ReplicaConfig, w: WorkloadType) -> int:
        budget = self.kv_budget_bytes(cfg)
        if budget <= 0:
            return 0
        per_seq = max(self.p.seq_mem_bytes(w.total_len), 1.0)
        return max(0, min(self.max_batch, int(budget / per_seq)))

    def kv_hop_seconds(self, w: WorkloadType) -> float:
        """The prefill→decode handoff hop of a disaggregated pair: the
        prompt's KV pages cross the interconnect once.  (With a shared
        pool the runtime moves zero bytes — this prices the general
        cross-pool case, and acts as a mild tax that keeps the planner
        from disaggregating when the phases don't warrant it.)"""
        return self.p.kv_bytes_per_token * w.in_len / self.hw.ici_bw

    @lru_cache(maxsize=100_000)
    def replica_perf(self, cfg: ReplicaConfig, w: WorkloadType) -> ReplicaPerf:
        b_eff = self.max_concurrency(cfg, w)
        if b_eff == 0:
            return ReplicaPerf(math.inf, math.inf, 0, 0.0, False)
        avg_ctx = w.in_len + w.out_len // 2
        # Prefix-cache discount: a type whose prompts hit the cache for a
        # fraction of their tokens only prefills the uncached suffix (the
        # cached pages attach by refcount — zero compute).  The KV memory
        # term stays at full total_len: shared pages still occupy HBM.
        prefill_in = max(1, int(round(w.in_len * (1.0 - w.cached_frac))))
        prefill_t = self.measure_prefill(cfg, prefill_in)
        decode_t = self.measure_decode_step(cfg, b_eff, avg_ctx)
        # Pipeline bubble: decode across pp stages overlaps across microbatches;
        # with m in-flight microbatch groups, efficiency = m / (m + pp - 1).
        m = 4
        pp_eff = m / (m + cfg.pp - 1)
        # Disaggregated roles price their single phase: a prefill replica's
        # request costs one prefill forward plus the KV handoff hop (its
        # slot frees at first token); a decode replica's costs only the
        # decode stream.  (``cfg`` is frozen and hashable, so the role is
        # part of the lru_cache key automatically.)
        if cfg.role == "prefill":
            time_per_req = prefill_t + self.kv_hop_seconds(w)
            return ReplicaPerf(prefill_t, 0.0, b_eff,
                               1.0 / time_per_req, True)
        if cfg.role == "decode":
            time_per_req = w.out_len * decode_t / (b_eff * pp_eff)
            return ReplicaPerf(0.0, decode_t, b_eff,
                               1.0 / time_per_req, True)
        # Continuous batching: a request occupies one decode slot for out_len
        # steps, plus its prefill is chunked into the decode stream
        # (Sarathi-style), costing prefill_t of replica time.
        time_per_req = prefill_t + w.out_len * decode_t / (b_eff * pp_eff)
        thr = 1.0 / time_per_req
        return ReplicaPerf(prefill_t, decode_t, b_eff, thr, True)

    def capacity(self, cfg: ReplicaConfig, w: WorkloadType) -> float:
        """n_{k,j}: max type-j requests per time span if replica serves only j."""
        return self.replica_perf(cfg, w).throughput * self.span_seconds

    def edge_capacity(self, cfg: ReplicaConfig, w: WorkloadType) -> float:
        """e_{k,j}: per-type cap on requests routed to k in one span.

        Bounded by the pure-type capacity; memory concurrency is already folded
        into the throughput estimate.
        """
        return self.capacity(cfg, w)

    # -- reload / switching costs (used by the switch planner) ---------------

    def reload_seconds(self) -> float:
        """Naive model reload from host storage (the paper: minutes~50s)."""
        return self.p.param_bytes / self.hw.host_load_bw

    def min_chips(self) -> int:
        """Smallest chip count whose HBM fits params + reserve (paper: 140GB/70B)."""
        need = self.p.param_bytes / (0.9 * self.hw.hbm_bytes)
        return max(1, math.ceil(need))


def profile_capacities(
    cm: CostModel,
    replicas: list[ReplicaConfig],
    workloads: list[WorkloadType],
) -> tuple[list[list[float]], list[list[float]]]:
    """(n[k][j], e[k][j]) for the flow network.

    Disaggregated roles couple here: the flow network routes *admissions*,
    and in a disaggregated pair only the prefill replica admits — the
    decode replica receives contexts by handoff, outside the flow.  So a
    ``decode`` replica contributes zero admission capacity, and each
    ``prefill`` replica's capacity for type j is clipped by the decode
    side's ability to absorb its first-token-ready contexts:
    ``min(1, decode_cap_j / prefill_cap_j)`` — admitting prompts faster
    than the decode pool drains them just moves the queue downstream.
    ``mixed`` replicas are untouched.
    """
    n = [[cm.capacity(r, w) for w in workloads] for r in replicas]
    e = [[cm.edge_capacity(r, w) for w in workloads] for r in replicas]
    pre = [k for k, r in enumerate(replicas) if r.role == "prefill"]
    dec = [k for k, r in enumerate(replicas) if r.role == "decode"]
    if pre or dec:
        for j in range(len(workloads)):
            p_j = sum(n[k][j] for k in pre)
            d_j = sum(n[k][j] for k in dec)
            scale = min(1.0, d_j / p_j) if p_j > 0 else 0.0
            for k in pre:
                n[k][j] *= scale
                e[k][j] *= scale
        for k in dec:
            for j in range(len(workloads)):
                n[k][j] = e[k][j] = 0.0
    return n, e
