"""Fine-grained time-series workload forecasting (paper S4.1).

Pipeline (S1/S2 in the paper):
  1. k-means over (input_len, output_len) clusters historical requests into
     workload types; per-span request counts per type form J time series.
  2. A per-type LSTM (history window = 50 spans) predicts the next span's
     arrival rate for each type.

Baselines reproduced for S5.3: a moving-average predictor and an aggregate
LSTM that forecasts the total rate without type decomposition.

Everything is implemented in JAX (the LSTM runs under ``jax.lax.scan`` and is
trained with a self-contained Adam), sized so training takes seconds on CPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# S1: k-means workload typing.
# --------------------------------------------------------------------------


def kmeans(points: np.ndarray, k: int, iters: int = 50, seed: int = 0
           ) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd's algorithm with k-means++ init.

    Args:
      points: [N, D] float array (we use D=2: in_len, out_len, log-scaled).
    Returns:
      (centroids [k, D], labels [N])
    """
    rng = np.random.RandomState(seed)
    n = len(points)
    k = min(k, n)
    # k-means++ seeding.
    centroids = [points[rng.randint(n)]]
    for _ in range(1, k):
        d2 = np.min(
            [np.sum((points - c) ** 2, axis=1) for c in centroids], axis=0)
        total = d2.sum()
        if total <= 0:
            centroids.append(points[rng.randint(n)])
            continue
        centroids.append(points[rng.choice(n, p=d2 / total)])
    C = np.array(centroids, dtype=np.float64)
    labels = np.zeros(n, dtype=np.int64)
    for _ in range(iters):
        d2 = ((points[:, None, :] - C[None, :, :]) ** 2).sum(-1)
        new_labels = d2.argmin(1)
        if np.array_equal(new_labels, labels) and _ > 0:
            break
        labels = new_labels
        for j in range(k):
            mask = labels == j
            if mask.any():
                C[j] = points[mask].mean(0)
    return C, labels


@dataclasses.dataclass
class WorkloadClusterer:
    """Maps requests -> workload type via k-means on log sequence lengths."""

    centroids: np.ndarray  # [k, 2] in log1p space
    raw_centroids: np.ndarray  # [k, 2] in token space (in_len, out_len)

    @classmethod
    def fit(cls, in_lens: np.ndarray, out_lens: np.ndarray, k: int,
            seed: int = 0) -> tuple["WorkloadClusterer", np.ndarray]:
        pts = np.stack([np.log1p(in_lens), np.log1p(out_lens)], axis=1)
        C, labels = kmeans(pts, k, seed=seed)
        raw = np.zeros_like(C)
        for j in range(len(C)):
            m = labels == j
            if m.any():
                raw[j] = [in_lens[m].mean(), out_lens[m].mean()]
            else:
                raw[j] = np.expm1(C[j])
        return cls(C, raw), labels

    @property
    def k(self) -> int:
        return len(self.centroids)

    def assign(self, in_lens: np.ndarray, out_lens: np.ndarray) -> np.ndarray:
        pts = np.stack([np.log1p(in_lens), np.log1p(out_lens)], axis=1)
        d2 = ((pts[:, None, :] - self.centroids[None, :, :]) ** 2).sum(-1)
        return d2.argmin(1)


def count_series(labels: np.ndarray, arrival_spans: np.ndarray, k: int,
                 n_spans: int) -> np.ndarray:
    """Per-span request counts per type: [n_spans, k]."""
    out = np.zeros((n_spans, k), dtype=np.float64)
    for lbl, span in zip(labels, arrival_spans):
        if 0 <= span < n_spans:
            out[int(span), int(lbl)] += 1
    return out


# --------------------------------------------------------------------------
# S2: LSTM predictor (JAX).
# --------------------------------------------------------------------------


def lstm_init(key: jax.Array, in_dim: int, hidden: int, out_dim: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale = 1.0 / np.sqrt(hidden)
    return {
        "wx": jax.random.normal(k1, (in_dim, 4 * hidden)) * scale,
        "wh": jax.random.normal(k2, (hidden, 4 * hidden)) * scale,
        "b": jnp.zeros((4 * hidden,)),
        "w_out": jax.random.normal(k3, (hidden, out_dim)) * scale,
        "b_out": jnp.zeros((out_dim,)),
    }


def lstm_apply(params: dict, xs: jax.Array) -> jax.Array:
    """xs: [T, in_dim] -> prediction [out_dim] from the final hidden state."""
    hidden = params["wh"].shape[0]

    def cell(carry, x):
        h, c = carry
        z = x @ params["wx"] + h @ params["wh"] + params["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    init = (jnp.zeros((hidden,)), jnp.zeros((hidden,)))
    (h, _), _ = jax.lax.scan(cell, init, xs)
    return h @ params["w_out"] + params["b_out"]


def _windows(series: np.ndarray, window: int) -> tuple[np.ndarray, np.ndarray]:
    """series [T, D] -> (X [N, window, D], Y [N, D]) next-step pairs."""
    T = len(series)
    xs, ys = [], []
    for t in range(T - window):
        xs.append(series[t:t + window])
        ys.append(series[t + window])
    return np.asarray(xs), np.asarray(ys)


class LSTMWorkloadPredictor:
    """Per-type next-span arrival-rate forecaster (paper defaults: window 50)."""

    def __init__(self, n_types: int, window: int = 50, hidden: int = 32,
                 per_type: bool = True, seed: int = 0):
        self.n_types = n_types
        self.window = window
        self.hidden = hidden
        self.per_type = per_type  # False => aggregate baseline (no decomposition)
        self.seed = seed
        self.params: dict | None = None
        self.scale: np.ndarray | None = None
        self.train_loss: float = float("nan")

    def _normalize(self, series: np.ndarray) -> np.ndarray:
        if self.scale is None:
            self.scale = np.maximum(series.max(axis=0), 1.0)
        return series / self.scale

    def fit(self, series: np.ndarray, epochs: int = 200, lr: float = 1e-2,
            batch: int = 64) -> float:
        """series: [T, n_types] per-span counts. Returns final train loss."""
        if not self.per_type:
            series = series.sum(axis=1, keepdims=True)
        d = series.shape[1]
        norm = self._normalize(series)
        X, Y = _windows(norm, self.window)
        if len(X) == 0:
            raise ValueError("series shorter than prediction window")
        key = jax.random.PRNGKey(self.seed)
        params = lstm_init(key, d, self.hidden, d)

        @jax.jit
        def loss_fn(p, xb, yb):
            preds = jax.vmap(lambda x: lstm_apply(p, x))(xb)
            return jnp.mean((preds - yb) ** 2)

        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        # Self-contained Adam.
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)

        @jax.jit
        def adam_step(p, m, v, g, t):
            b1, b2, eps = 0.9, 0.999, 1e-8
            m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
            v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
            mh = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
            vh = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
            p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + eps),
                             p, mh, vh)
            return p, m, v

        rng = np.random.RandomState(self.seed)
        n = len(X)
        t = 0
        final = float("nan")
        for _ in range(epochs):
            idx = rng.permutation(n)[:batch]
            t += 1
            final, g = grad_fn(params, X[idx], Y[idx])
            params, m, v = adam_step(params, m, v, g, jnp.asarray(float(t)))
        self.params = params
        self.train_loss = float(final)
        return self.train_loss

    def predict(self, history: np.ndarray) -> np.ndarray:
        """history: [>=window, n_types] -> predicted next-span counts [n_types]."""
        assert self.params is not None, "call fit() first"
        h = history[-self.window:]
        if not self.per_type:
            h = h.sum(axis=1, keepdims=True)
        h = h / self.scale
        pred = np.asarray(lstm_apply(self.params, jnp.asarray(h)))
        pred = np.maximum(pred * self.scale, 0.0)
        if not self.per_type:
            # Aggregate baseline: split the total by the recent type mix.
            recent = history[-self.window:].sum(axis=0)
            mix = recent / max(recent.sum(), 1.0)
            return pred[0] * mix
        return pred

    def predict_series(self, series: np.ndarray) -> np.ndarray:
        """One-step-ahead predictions over a held-out series: [T-window, n_types]."""
        out = []
        for t in range(self.window, len(series)):
            out.append(self.predict(series[:t]))
        return np.asarray(out)


class MovingAveragePredictor:
    """S5.3 baseline: mean of the last `window` spans."""

    def __init__(self, n_types: int, window: int = 5):
        self.n_types = n_types
        self.window = window

    def fit(self, series: np.ndarray, **_) -> float:
        return 0.0

    def predict(self, history: np.ndarray) -> np.ndarray:
        return history[-self.window:].mean(axis=0)

    def predict_series(self, series: np.ndarray, start: int = 50) -> np.ndarray:
        return np.asarray([self.predict(series[:t])
                           for t in range(start, len(series))])


def rrmse(pred: np.ndarray, true: np.ndarray) -> float:
    """Relative root mean squared error (paper's predictor metric)."""
    pred = np.asarray(pred, dtype=np.float64)
    true = np.asarray(true, dtype=np.float64)
    denom = max(float(np.abs(true).mean()), 1e-9)
    return float(np.sqrt(np.mean((pred - true) ** 2)) / denom)
