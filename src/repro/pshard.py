"""Logical-axis sharding annotations, decoupled from the model code.

Model code calls ``logical(x, "batch", "seq", "d_model")``; outside of a
``sharding_rules`` context this is the identity (CPU smoke tests see one
device and zero annotations).  The launcher installs a rules mapping
(logical axis name -> mesh axis / None) plus the mesh, and every annotation
becomes a ``with_sharding_constraint`` so GSPMD propagates the deployment's
parallelism through the whole program.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules() -> tuple[Mesh, dict] | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, rules: dict):
    """Install logical->mesh axis rules for the enclosed trace."""
    prev = getattr(_state, "rules", None)
    _state.rules = (mesh, rules)
    try:
        yield
    finally:
        _state.rules = prev


def spec_for(*axes: str | None) -> P:
    ctx = current_rules()
    if ctx is None:
        return P()
    _, rules = ctx
    entries = []
    for a in axes:
        if a is None:
            entries.append(None)
        else:
            entries.append(rules.get(a))
    return P(*entries)


def logical(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain `x` (rank == len(axes)) to the logical sharding, if rules set."""
    ctx = current_rules()
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = spec_for(*axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
