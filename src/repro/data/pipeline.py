"""Synthetic token data pipeline: corpus generation, packing, sharded batches.

No external datasets are available offline, so the corpus is a seeded
Zipf-distributed token stream with injected n-gram structure (so models have
something learnable: loss should drop well below ln(vocab)).  Documents are
packed into fixed-length sequences with EOS separators, mirroring a real
LM pipeline's pack-and-shift stage.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    eos_id: int = 0
    ngram_order: int = 3
    ngram_strength: float = 0.8   # prob of following the n-gram machine


class SyntheticCorpus:
    """Deterministic pseudo-corpus with learnable bigram/trigram structure."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        rng = np.random.RandomState(dc.seed)
        V = dc.vocab_size
        # sparse deterministic successor table: each (a, b) -> c
        self._succ = rng.randint(1, V, size=(min(V, 4096), min(V, 4096)))
        # zipf unigram fallback
        ranks = np.arange(1, V + 1)
        p = 1.0 / ranks ** 1.1
        self._unigram = p / p.sum()

    def doc(self, rng: np.random.RandomState, length: int) -> np.ndarray:
        V = self.dc.vocab_size
        n = self._succ.shape[0]
        out = np.empty(length, np.int64)
        a, b = rng.randint(1, V), rng.randint(1, V)
        for i in range(length):
            if rng.rand() < self.dc.ngram_strength:
                c = int(self._succ[a % n, b % n])
            else:
                c = int(rng.choice(V, p=self._unigram))
            out[i] = c
            a, b = b, c
        return out


def packed_batches(dc: DataConfig) -> Iterator[dict]:
    """Yields {"tokens": [B, S], "labels": [B, S]} int32 batches forever."""
    corpus = SyntheticCorpus(dc)
    rng = np.random.RandomState(dc.seed + 1)
    buf = np.empty(0, np.int64)
    need = dc.batch_size * (dc.seq_len + 1)
    while True:
        while buf.size < need:
            doc_len = int(rng.randint(dc.seq_len // 4, dc.seq_len * 2))
            doc = corpus.doc(rng, doc_len)
            buf = np.concatenate([buf, doc, [dc.eos_id]])
        chunk = buf[:need].reshape(dc.batch_size, dc.seq_len + 1)
        buf = buf[need:]
        yield {"tokens": chunk[:, :-1].astype(np.int32),
               "labels": chunk[:, 1:].astype(np.int32)}


def embeds_batches(dc: DataConfig, d_model: int) -> Iterator[dict]:
    """Stub-frontend batches (musicgen): precomputed frame embeddings."""
    rng = np.random.RandomState(dc.seed + 2)
    tok_iter = packed_batches(dc)
    table = rng.randn(dc.vocab_size, d_model).astype(np.float32) * 0.02
    for batch in tok_iter:
        yield {"embeds": table[batch["tokens"]],
               "labels": batch["labels"]}
