from repro.data.pipeline import DataConfig, packed_batches  # noqa: F401
