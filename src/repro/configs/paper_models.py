"""The paper's own evaluation models (S5.1): OPT-30B/66B, Llama-30B, Llama2-70B.

Used by the end-to-end trace benchmarks and the scheduler/switching studies so
EXPERIMENTS.md can be compared against the paper's absolute claims.
"""
from repro.models.config import ModelConfig

CONFIGS = {
    "opt-30b": ModelConfig(
        name="opt-30b", family="dense", n_layers=48, d_model=7168,
        n_q_heads=56, n_kv_heads=56, head_dim=128, d_ff=28672,
        vocab_size=50_272, mlp_variant="gelu", qkv_bias=True, mlp_bias=True,
        pos_embedding="sincos", tie_embeddings=True),
    "opt-66b": ModelConfig(
        name="opt-66b", family="dense", n_layers=64, d_model=9216,
        n_q_heads=72, n_kv_heads=72, head_dim=128, d_ff=36864,
        vocab_size=50_272, mlp_variant="gelu", qkv_bias=True, mlp_bias=True,
        pos_embedding="sincos", tie_embeddings=True),
    "llama-30b": ModelConfig(
        name="llama-30b", family="dense", n_layers=60, d_model=6656,
        n_q_heads=52, n_kv_heads=52, head_dim=128, d_ff=17920,
        vocab_size=32_000, tie_embeddings=False),
    "llama2-70b": ModelConfig(
        name="llama2-70b", family="dense", n_layers=80, d_model=8192,
        n_q_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672,
        vocab_size=32_000, tie_embeddings=False),
}
