"""Hymba-1.5B [arXiv:2411.13676]: 32L, d=1600, parallel attn + mamba heads.

25H GQA kv=5 (head_dim 64) in parallel with SSM heads (d_state=16); the two
path outputs are normalized and averaged.  Meta-tokens from the paper are out
of scope (noted in DESIGN.md).  Hybrid -> assigned long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_q_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    hybrid=True,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    max_seq_len=524_288,
    attn_sharding="replicate",  # 25 heads: pad would be 25->32 (28%) but KV=5
)
