"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

The 10 assigned architectures (exact public configs) plus the paper's own
evaluation models (OPT-30B/66B, Llama-30B, Llama2-70B).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ASSIGNED_ARCHS = [
    "olmoe-1b-7b",
    "granite-moe-3b-a800m",
    "starcoder2-3b",
    "gemma2-2b",
    "qwen1.5-110b",
    "yi-9b",
    "mamba2-370m",
    "hymba-1.5b",
    "chameleon-34b",
    "musicgen-medium",
]

PAPER_ARCHS = ["opt-30b", "opt-66b", "llama-30b", "llama2-70b"]

ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "starcoder2-3b": "starcoder2_3b",
    "gemma2-2b": "gemma2_2b",
    "qwen1.5-110b": "qwen1_5_110b",
    "yi-9b": "yi_9b",
    "mamba2-370m": "mamba2_370m",
    "hymba-1.5b": "hymba_1_5b",
    "chameleon-34b": "chameleon_34b",
    "musicgen-medium": "musicgen_medium",
    "opt-30b": "paper_models",
    "opt-66b": "paper_models",
    "llama-30b": "paper_models",
    "llama2-70b": "paper_models",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIGS[arch] if hasattr(mod, "CONFIGS") else mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return get_config(arch).reduced()
