"""MusicGen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens.

48L, d=1536, 24H MHA (kv=24), d_ff=6144 (non-gated GeLU), vocab 2048
(EnCodec codebook).  Absolute sinusoidal positions.  The EnCodec frontend +
codebook delay-pattern interleaving is a stub: ``input_specs()`` provides
precomputed frame embeddings [B, S, d]; logits predict the next codebook id.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_q_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    mlp_variant="gelu",
    mlp_bias=True,
    pos_embedding="sincos",
    tie_embeddings=False,
    modality="audio_stub",
    attn_sharding="pad",        # 24 -> 32 on TP=16
)
