"""StarCoder2-3B [arXiv:2402.19173]: 30L, d=3072, 24H GQA kv=2, d_ff=12288.

Plain (non-gated) GeLU MLP with biases, RoPE.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_q_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    mlp_variant="gelu",
    qkv_bias=True,
    mlp_bias=True,
    rope_theta=999_999.4,
    norm_eps=1e-5,
    tie_embeddings=True,
    attn_sharding="pad",        # 24 -> 32 on TP=16
)
