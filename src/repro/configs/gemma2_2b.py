"""Gemma2-2B [arXiv:2408.00118]: 26L, d=2304, 8H GQA kv=4, d_ff=9216.

Alternating local(4096)/global attention, attn softcap 50, final softcap 30,
GeGLU MLP, sandwich (pre+post) norms, sqrt(d)-scaled embeddings, vocab 256k.
8 heads < TP=16 -> attention replicated over the model axis; MLP/vocab TP'd.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_q_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    mlp_variant="geglu",
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sandwich_norm=True,
    scale_embedding=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    # hillclimb-adopted (EXPERIMENTS.md SPerf cell C): GQA-group-preserving
    # head padding 8->16 beats replicated attention ~2x on HLO flops/bytes
    attn_sharding="pad",
)
