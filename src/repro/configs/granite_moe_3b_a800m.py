"""Granite-3.0 MoE [hf:ibm-granite]: 32L, d=1536, 24H GQA kv=8, 40 experts top-8.

Assignment-sheet discrepancy ("MoE 40e top-8" vs trailing "32 experts"): we
implement the structured field, 40 experts (matches granite-3.0-3b-a800m).
40 % 16 != 0, so expert sharding falls back to expert-TP (shard each
expert's d_ff=512 across the model axis) — see DESIGN.md §4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_q_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
    expert_sharding="tp",
    attn_sharding="pad",        # 24 heads -> pad to 32 on TP=16
)
