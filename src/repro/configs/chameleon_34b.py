"""Chameleon-34B [arXiv:2405.09818]: early-fusion VLM backbone.

48L, d=8192, 64H GQA kv=8, d_ff=22016, unified vocab 65536 (text + VQ image
tokens).  QK-norm (chameleon's training stabilizer).  The VQ image tokenizer
is a stub: ``input_specs()`` provides already-tokenized ids in the shared
vocab, per the assignment ("modality frontend is a STUB").
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_q_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
    qk_norm=True,
    rope_theta=10_000.0,
    tie_embeddings=False,
    modality="image_stub",
    attn_sharding="heads",
)
