"""Qwen1.5-110B-class [hf:Qwen]: 80L, d=8192, 64H GQA kv=8, d_ff=49152.

QKV bias (the Qwen1.5 signature), RoPE, SwiGLU.  The largest assigned arch:
TP-heavy deployments dominate its scheduler search space.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_q_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    attn_sharding="heads",      # 64 % 16 == 0
)
