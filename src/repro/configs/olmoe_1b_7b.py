"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d=2048, 16H MHA, 64 experts top-8.

d_ff=1024 is the per-expert FFN width; ~1.3B active / ~6.9B total params.
OLMoE uses QK-norm and softmax-then-topk routing with normalized weights.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_q_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    qk_norm=True,
    rope_theta=10_000.0,
    tie_embeddings=False,
    expert_sharding="ep",       # 64 experts / 16-way model axis = 4 per shard
    # hillclimb-adopted (EXPERIMENTS.md SPerf cell A): at 16L x d=2048 the
    # sequence-parallel residual costs more in collectives than it saves
    seq_parallel=False,
)
