"""Yi-9B [arXiv:2403.04652]: llama-arch, 48L, d=4096, 32H GQA kv=4, d_ff=11008."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_q_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    rope_theta=10_000.0,
    tie_embeddings=False,
    attn_sharding="heads",      # 32 % 16 == 0
)
