"""Mamba2-370M [arXiv:2405.21060]: 48L, d=1024, attention-free SSD.

d_inner = 2*d = 2048, head_dim 64 -> 32 SSM heads, d_state=128, 1 group.
Constant-size recurrent state -> assigned the long_500k decode shape.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_q_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                     # no MLP block (mamba2 mixer-only layers)
    vocab_size=50_280,
    ssm_state=128,
    ssm_heads=32,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    max_seq_len=524_288,
)
