"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def _expand(k: jax.Array, Hq: int) -> jax.Array:
    B, S, Hkv, D = k.shape
    rep = Hq // Hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def flash_attention_ref(q, k, v, *, causal=True, softcap=0.0, window=0):
    """q: [B, Sq, Hq, D]; k/v: [B, Sk, Hkv, D]."""
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    k = _expand(k, Hq)
    v = _expand(v, Hq)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok = ok & (kp <= qp)
    if window > 0:
        ok = ok & (kp > qp - window)
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def flash_decode_ref(q, k, v, lens, *, softcap=0.0, start=None):
    """q: [B, Hq, D]; k/v: [B, S, Hkv, D]; lens [B]; start [B] lower bound."""
    B, Hq, D = q.shape
    S = k.shape[1]
    k = _expand(k, Hq)
    v = _expand(v, Hq)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None, None, :]
    ok = pos < lens[:, None, None]
    if start is not None:
        ok = ok & (pos >= start[:, None, None])
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def flash_decode_paged_ref(q, k_pages, v_pages, block_table, lens, *,
                           softcap=0.0, start=None):
    """Gather pages into a dense cache, then dense decode."""
    k = k_pages[block_table]          # [B, max_pages, page, Hkv, D]
    v = v_pages[block_table]
    B_, n, p, H, D = k.shape
    k = k.reshape(B_, n * p, H, D)
    v = v.reshape(B_, n * p, H, D)
    return flash_decode_ref(q, k, v, lens, softcap=softcap, start=start)


def ssd_chunk_ref(x, dt, A, B_, C_):
    """Within-chunk SSD oracle (same signature as kernels.ssd_scan.ssd_chunk)."""
    dtA = dt * A[None, None, None, :]
    cs = jnp.cumsum(dtA, axis=2)
    Q = x.shape[2]
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcthn,bcshn->bchts", C_, B_)
    scores = cb * jnp.moveaxis(M, -1, 2)
    xdt = x * dt[..., None]
    y = jnp.einsum("bchts,bcshp->bcthp", scores, xdt)
    total = cs[:, :, -1, :]
    w = jnp.exp(total[:, :, None, :] - cs) * dt
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, B_, x)
    return y, S
