"""jit'd public wrappers around the Pallas kernels.

Handle the alignment bookkeeping so callers never think about it:
  * pad head_dim to a multiple of 128 (zero columns are exact for attention:
    scores and outputs are unchanged, padded output columns are sliced off);
  * pad sequence lengths to block multiples (masked off inside the kernels);
  * pick MXU-aligned default block sizes.

``interpret=True`` (the CPU validation mode) runs the kernel bodies in
Python via the Pallas interpreter; on TPU the same calls emit Mosaic kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import ssd_scan as _ssd


def _pad_axis(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "softcap", "window",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, softcap=0.0, window=0,
                    block_q=128, block_k=128, interpret=False):
    """Drop-in causal attention: q [B,Sq,Hq,D], k/v [B,Sk,Hkv,D]."""
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    qp = _pad_axis(_pad_axis(q, 128, 3), bq, 1)
    kp = _pad_axis(_pad_axis(k, 128, 3), bk, 1)
    vp = _pad_axis(_pad_axis(v, 128, 3), bk, 1)
    # padded k positions must be masked: they are > real positions only when
    # Sk pads; causal masking handles q-tail, use window-free explicit mask
    # via lens trick: rely on causal mask q_pos<S for pads at the end when
    # causal; for non-causal, padded keys would leak — mask via big negative
    # handled by causal-only support here.
    out = _fa.flash_attention(qp, kp, vp, causal=causal, softcap=softcap,
                              window=window, block_q=bq, block_k=bk,
                              scale=1.0 / (D ** 0.5),   # pre-padding head_dim
                              interpret=interpret)
    return out[:, :Sq, :, :D]


@functools.partial(jax.jit, static_argnames=("softcap", "block_k",
                                             "interpret"))
def flash_decode(q, k, v, lens, *, softcap=0.0, block_k=128,
                 interpret=False):
    """q [B,Hq,D], k/v [B,S,Hkv,D], lens [B] -> [B,Hq,D]."""
    B, Hq, D = q.shape
    S = k.shape[1]
    bk = min(block_k, max(8, S))
    qp = _pad_axis(q, 128, 2)
    kp = _pad_axis(_pad_axis(k, 128, 3), bk, 1)
    vp = _pad_axis(_pad_axis(v, 128, 3), bk, 1)
    out = _fd.flash_decode(qp, kp, vp, lens, softcap=softcap, block_k=bk,
                           scale=1.0 / (D ** 0.5), interpret=interpret)
    return out[:, :, :D]


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def flash_decode_paged(q, k_pages, v_pages, block_table, lens, *,
                       start=None, softcap=0.0, interpret=False):
    """q [B,Hq,D]; pages [P,page,Hkv,D]; block_table [B,max_pages]; lens [B];
    start [B] optional lower position bound (local attention)."""
    D = q.shape[-1]
    qp = _pad_axis(q, 128, 2)
    kp = _pad_axis(k_pages, 128, 3)
    vp = _pad_axis(v_pages, 128, 3)
    out = _fd.flash_decode_paged(qp, kp, vp, block_table, lens, start=start,
                                 softcap=softcap, scale=1.0 / (D ** 0.5),
                                 interpret=interpret)
    return out[:, :, :D]


# Kernel-native pools ([P, Hkv, page, D], pre-padded head_dim) go through
# flash_decode.flash_decode_paged_native directly — a padding wrapper here
# would copy the whole pool per call, which the native layout exists to avoid.


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def flash_decode_paged_batch(q, k_pages, v_pages, block_table, lens, *,
                             start=None, softcap=0.0, interpret=False):
    """Multi-layer paged decode: q [L,B,Hq,D]; pages [L,P,Hkv,page,D]
    (kernel-native layout); one pallas_call per layer, reshapes hoisted."""
    D = q.shape[-1]
    qp = _pad_axis(q, 128, 3)
    kp = _pad_axis(k_pages, 128, 4)
    vp = _pad_axis(v_pages, 128, 4)
    out = _fd.flash_decode_paged_batch(qp, kp, vp, block_table, lens,
                                       start=start, softcap=softcap,
                                       scale=1.0 / (D ** 0.5),
                                       interpret=interpret)
    return out[..., :D]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, A, B_, C_, *, interpret=False):
    """Within-chunk SSD: x [B,Nc,Q,H,P], dt [B,Nc,Q,H], A [H],
    B_/C_ [B,Nc,Q,H,N] -> (y [B,Nc,Q,H,P], S [B,Nc,H,P,N])."""
    P = x.shape[-1]
    xp = _pad_axis(x, 128, 4)
    y, S = _ssd.ssd_chunk(xp, dt, A, B_, C_, interpret=interpret)
    return y[..., :P], S[..., :P, :]
