"""Flash-decode — batched single-token attention over a (paged) KV cache.

Two variants:
  * ``flash_decode``       — dense cache [B, S, Hkv, D], grid (B, Hq, n_k)
    with online-softmax scratch accumulation and per-sequence length masking.
  * ``flash_decode_paged`` — vLLM-style paged cache: the block table rides in
    scalar-prefetch SMEM (PrefetchScalarGridSpec) and the K/V index maps
    dereference it, so pages are fetched HBM->VMEM exactly once, in table
    order.  This is the TPU-native form of the serving engine's decode path.

Lengths mask invalid tail positions; softcap supports gemma2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, n_k: int, softcap: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # [1, D] (token block)
    k = k_ref[0, 0].astype(jnp.float32)              # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < len_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       )[0].astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, lens: jax.Array,
                 *, softcap: float = 0.0, block_k: int = 128,
                 scale: float | None = None,
                 interpret: bool = False) -> jax.Array:
    """q: [B, Hq, D] one token per sequence; k/v: [B, S, Hkv, D]; lens [B].

    Returns [B, Hq, D].  S % block_k == 0 (ops.py pads).
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    group = Hq // Hkv
    n_k = S // block_k
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qt = q[:, :, None, :]                         # [B, Hq, 1, D]
    kt = jnp.swapaxes(k, 1, 2)                    # [B, Hkv, S, D]
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_k=n_k, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, j, lens: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, j, lens: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j, lens: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    # kernel signature with scalar prefetch: (lens, q, k, v, o, scratch...)
    def kern(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr)

    def kspec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *scratch):
        kern(len_ref, q_ref, k_ref, v_ref, o_ref, *scratch)

    out = pl.pallas_call(
        kspec_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), qt, kt, vt)
    return out


def _paged_kernel(lens_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, block, n_blocks, softcap):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [1, D]
    k = k_ref[0, 0].astype(jnp.float32)            # [block, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    pos = j * block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < lens_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(j == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       )[0].astype(o_ref.dtype)


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       block_table: jax.Array, lens: jax.Array,
                       *, softcap: float = 0.0, scale: float | None = None,
                       interpret: bool = False) -> jax.Array:
    """Paged decode attention.

    Args:
      q: [B, Hq, D]; k_pages/v_pages: [num_pages, page, Hkv, D];
      block_table: [B, max_pages] int32 physical page per logical page;
      lens: [B] sequence lengths.
    Returns [B, Hq, D].
    """
    B, Hq, D = q.shape
    num_pages, page, Hkv, _ = k_pages.shape
    group = Hq // Hkv
    max_pages = block_table.shape[1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qt = q[:, :, None, :]
    kt = jnp.swapaxes(k_pages, 1, 2)               # [pages, Hkv, page, D]
    vt = jnp.swapaxes(v_pages, 1, 2)

    kernel = functools.partial(_paged_kernel, scale=scale, block=page,
                               n_blocks=max_pages, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # lens, block_table
        grid=(B, Hq, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, j, lens, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, D),
                         lambda b, h, j, lens, tbl: (tbl[b, j], h // group,
                                                     0, 0)),
            pl.BlockSpec((1, 1, page, D),
                         lambda b, h, j, lens, tbl: (tbl[b, j], h // group,
                                                     0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D),
                               lambda b, h, j, lens, tbl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), block_table.astype(jnp.int32), qt, kt, vt)
    return out
