"""Flash-decode — batched single-token attention over a (paged) KV cache.

Variants:
  * ``flash_decode``             — dense cache [B, S, Hkv, D], grid (B, Hq, n_k)
    with online-softmax scratch accumulation and per-sequence length masking.
  * ``flash_decode_paged``       — vLLM-style paged cache: the block table rides
    in scalar-prefetch SMEM (PrefetchScalarGridSpec) and the K/V index maps
    dereference it, so pages are fetched HBM->VMEM exactly once, in table
    order.  This is the TPU-native form of the serving engine's decode path.
  * ``flash_decode_paged_batch`` — multi-layer entry point over pools already
    stored in kernel-native layout [L, P, Hkv, page, D]: the engine issues one
    pallas_call per layer with no per-(layer, step) transposes or reshapes.

Per-sequence masking is a [start, len) window: ``lens`` masks the invalid
tail, ``start`` (optional) masks the head for local/sliding-window layers.
Blocks entirely outside the window are skipped (``pl.when`` early exit) and
their K/V index maps are clamped into the live range so no extra pages are
DMA'd.  Softcap supports gemma2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, n_k: int, softcap: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # [1, D] (token block)
    k = k_ref[0, 0].astype(jnp.float32)              # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < len_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       )[0].astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, lens: jax.Array,
                 *, softcap: float = 0.0, block_k: int = 128,
                 scale: float | None = None,
                 interpret: bool = False) -> jax.Array:
    """q: [B, Hq, D] one token per sequence; k/v: [B, S, Hkv, D]; lens [B].

    Returns [B, Hq, D].  S % block_k == 0 (ops.py pads).
    """
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    group = Hq // Hkv
    n_k = S // block_k
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qt = q[:, :, None, :]                         # [B, Hq, 1, D]
    kt = jnp.swapaxes(k, 1, 2)                    # [B, Hkv, S, D]
    vt = jnp.swapaxes(v, 1, 2)

    # kernel signature with scalar prefetch: (lens, q, k, v, o, scratch...)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_k=n_k, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, j, lens: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, j, lens: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, j, lens: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), qt, kt, vt)
    return out


def _paged_kernel(lens_ref, start_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, block, n_blocks, softcap):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lens_ref[b]
    start = start_ref[b]
    first_blk = start // block
    last_blk = jnp.maximum(length - 1, 0) // block

    # early exit: blocks fully outside [start, length) contribute nothing;
    # their index maps are clamped into the live range so they also move no
    # new data HBM->VMEM (same block index as the previous grid step).
    @pl.when((j >= first_blk) & (j <= last_blk) & (length > 0))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [1, D]
        k = k_ref[0, 0].astype(jnp.float32)            # [block, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        pos = j * block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((pos >= start) & (pos < length), s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(j == jnp.minimum(last_blk, n_blocks - 1))
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       )[0].astype(o_ref.dtype)


def _paged_call(qt: jax.Array, kt: jax.Array, vt: jax.Array,
                block_table: jax.Array, lens: jax.Array, start: jax.Array,
                *, softcap: float, scale: float, interpret: bool) -> jax.Array:
    """Core pallas_call over kernel-native layouts.

    qt: [B, Hq, 1, D]; kt/vt: [P, Hkv, page, D]; block_table: [B, max_pages];
    lens/start: [B].  Returns [B, Hq, D].
    """
    B, Hq, _, D = qt.shape
    _, Hkv, page, _ = kt.shape
    group = Hq // Hkv
    max_pages = block_table.shape[1]

    def kv_index(b, h, j, lens, start, tbl):
        first = start[b] // page
        last = jnp.maximum(lens[b] - 1, 0) // page
        jj = jnp.clip(j, first, last)
        return (tbl[b, jj], h // group, 0, 0)

    kernel = functools.partial(_paged_kernel, scale=scale, block=page,
                               n_blocks=max_pages, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                     # lens, start, block_table
        grid=(B, Hq, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, j, lens, start, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, D), kv_index),
            pl.BlockSpec((1, 1, page, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, D),
                               lambda b, h, j, lens, start, tbl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), qt.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), start.astype(jnp.int32),
      block_table.astype(jnp.int32), qt, kt, vt)


def flash_decode_paged_native(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_table: jax.Array,
                              lens: jax.Array, *,
                              start: jax.Array | None = None,
                              softcap: float = 0.0,
                              scale: float | None = None,
                              interpret: bool = False) -> jax.Array:
    """Paged decode over kernel-native pools (the serving engine's layout).

    q: [B, Hq, D]; k_pages/v_pages: [num_pages, Hkv, page, D] — already in
    kernel layout, so no per-call transpose.  Other args as
    ``flash_decode_paged``.  Returns [B, Hq, D].
    """
    D = q.shape[-1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if start is None:
        start = jnp.zeros_like(lens)
    return _paged_call(q[:, :, None, :], k_pages, v_pages, block_table, lens,
                       start, softcap=softcap, scale=scale,
                       interpret=interpret)


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       block_table: jax.Array, lens: jax.Array,
                       *, start: jax.Array | None = None,
                       softcap: float = 0.0, scale: float | None = None,
                       interpret: bool = False) -> jax.Array:
    """Paged decode attention.

    Args:
      q: [B, Hq, D]; k_pages/v_pages: [num_pages, page, Hkv, D];
      block_table: [B, max_pages] int32 physical page per logical page;
      lens: [B] sequence lengths; start: [B] optional lower position bound
        (local/sliding-window attention), defaults to 0.
    Returns [B, Hq, D].
    """
    B, Hq, D = q.shape
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if start is None:
        start = jnp.zeros_like(lens)
    qt = q[:, :, None, :]
    kt = jnp.swapaxes(k_pages, 1, 2)               # [pages, Hkv, page, D]
    vt = jnp.swapaxes(v_pages, 1, 2)
    return _paged_call(qt, kt, vt, block_table, lens, start,
                       softcap=softcap, scale=scale, interpret=interpret)


def flash_decode_paged_batch(q: jax.Array, k_pages: jax.Array,
                             v_pages: jax.Array, block_table: jax.Array,
                             lens: jax.Array, *,
                             start: jax.Array | None = None,
                             softcap: float = 0.0, scale: float | None = None,
                             interpret: bool = False) -> jax.Array:
    """Multi-layer paged decode over kernel-native pools.

    Args:
      q: [L, B, Hq, D] one token per sequence per layer;
      k_pages/v_pages: [L, num_pages, Hkv, page, D] (kernel-native layout —
        no per-call transpose); block_table: [B, max_pages]; lens/start: [B].
    Returns [L, B, Hq, D] with exactly one pallas_call per layer (the layer
    loop is a rolled ``lax.map``; table/lens prefetch is shared).
    """
    D = q.shape[-1]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if start is None:
        start = jnp.zeros_like(lens)

    def one_layer(args):
        ql, kl, vl = args
        return _paged_call(ql[:, :, None, :], kl, vl, block_table, lens,
                           start, softcap=softcap, scale=scale,
                           interpret=interpret)

    return jax.lax.map(one_layer, (q, k_pages, v_pages))
