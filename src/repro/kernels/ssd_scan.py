"""SSD (Mamba2) chunk kernel — the within-chunk quadratic form on the MXU.

For each (batch, head, chunk) grid cell the kernel computes
  y_intra = ((C B^T) .* exp(cs_t - cs_s) .* causal) @ (dt * x)
  S_chunk = (exp(cs_Q - cs) * dt * B)^T @ x            [N, P]
entirely in VMEM; the cheap cross-chunk recurrence (combining S_chunk into
running states) stays in jnp (``repro.models.ssm``).

Chunk length Q and state/head dims are MXU-friendly (Q=128/256, N=128, P=64
padded to 128 by ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, B_ref, C_ref, A_ref, y_ref, s_ref):
    # blocks: x [Q, P]; dt [Q, 1]; B/C [Q, N]; A [1, 1]
    x = x_ref[0, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [Q, 1]
    B_ = B_ref[0, 0].astype(jnp.float32)         # [Q, N]
    C_ = C_ref[0, 0].astype(jnp.float32)
    A = A_ref[0, 0].astype(jnp.float32)          # [1, 1] (negative)

    dtA = dt * A                                 # [Q, 1]
    cs = jnp.cumsum(dtA, axis=0)                 # inclusive
    Q = x.shape[0]
    # decay matrix M[t, s] = exp(cs_t - cs_s) for t >= s
    diff = cs - cs.T                             # [Q(t), Q(s)] broadcast
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    M = jnp.where(tri, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(C_, B_, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    scores = cb * M
    xdt = x * dt                                 # [Q, P]
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    total = cs[-1:, :]                           # [1, 1]
    w = jnp.exp(total - cs) * dt                 # [Q, 1]
    S = jax.lax.dot_general(B_ * w, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [N, P]
    s_ref[0, 0] = S.astype(s_ref.dtype)


def ssd_chunk(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
              C_: jax.Array, *, interpret: bool = False
              ) -> tuple[jax.Array, jax.Array]:
    """Within-chunk SSD.

    Args:
      x:  [B, Nc, Q, H, P] fp32 (chunked inputs, post conv/activation)
      dt: [B, Nc, Q, H]    fp32 softplus'd steps
      A:  [H]              fp32 negative decays
      B_, C_: [B, Nc, Q, H, N] (groups already broadcast to heads)
    Returns:
      (y_intra [B, Nc, Q, H, P], S_chunk [B, Nc, H, N, P])
    """
    Bsz, Nc, Q, H, P = x.shape
    N = B_.shape[-1]
    # layout: lead (B*Nc, H) grid, blocks [Q, P] / [Q, N]
    xb = x.reshape(Bsz * Nc, Q, H, P).swapaxes(1, 2)       # [G, H, Q, P]
    dtb = dt.reshape(Bsz * Nc, Q, H).swapaxes(1, 2)[..., None]
    Bb = B_.reshape(Bsz * Nc, Q, H, N).swapaxes(1, 2)
    Cb = C_.reshape(Bsz * Nc, Q, H, N).swapaxes(1, 2)
    Ab = jnp.broadcast_to(A[None, :, None, None], (Bsz * Nc, H, 1, 1))

    y, S = pl.pallas_call(
        _ssd_kernel,
        grid=(Bsz * Nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda g, h: (g, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda g, h: (g, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda g, h: (g, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz * Nc, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz * Nc, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xb, dtb, Bb, Cb, Ab)
    y = y.swapaxes(1, 2).reshape(Bsz, Nc, Q, H, P)
    S = S.reshape(Bsz, Nc, H, N, P).swapaxes(-1, -2)       # [B,Nc,H,P,N]
    return y, S
