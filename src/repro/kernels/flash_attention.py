"""Causal flash attention (prefill) — Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_k_blocks); the innermost k dimension
is sequential on TPU, so the fp32 (m, l, acc) online-softmax state lives in
VMEM scratch and the output block (whose index_map ignores the k index) is
written once on the final k step.  GQA is handled in the K/V index maps
(q head h reads kv head h // group) — no materialized KV expansion.

Block shapes are MXU-aligned (multiples of 128 on the lane dim; the ops.py
wrapper pads head_dim 64 -> 128 with zeros, which is exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, block_q: int, block_k: int, n_k: int,
            causal: bool, softcap: float, window: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # k block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # [BQ, D]
    k = k_ref[0, 0].astype(jnp.float32)           # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(j == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, softcap: float = 0.0,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    scale: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Sq, Hq, D]; k/v: [B, Sk, Hkv, D] -> [B, Sq, Hq, D].

    Requires Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    group = Hq // Hkv
    n_q, n_k = Sq // block_q, Sk // block_k
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    # layout: [B, H, S, D] blocks
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, n_k=n_k,
        causal=causal, softcap=softcap, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
