"""Production mesh construction (multi-pod dry-run spec).

A function — not a module-level constant — so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(devices: int = 8, model: int = 2):
    """CPU-test mesh (requires XLA_FLAGS host device count >= devices)."""
    return jax.make_mesh((devices // model, model), ("data", "model"))


def make_replica_mesh(devices, tp: int, pp: int = 1):
    """Mesh for ONE serving replica: shape (pp, tp), axes ("pipe", "model").

    ``devices`` is this replica's slice of the device set (len == tp * pp);
    the cluster runtime carves ``jax.devices()`` into per-replica slices so
    heterogeneous deployments place each replica on its own sub-mesh.
    Tensor parallelism shards heads / d_ff / vocab over ``model`` (the
    serving ``ShardingPlan`` rules); pipeline parallelism shards the
    layer-stacked parameter (and paged-pool) leading axis over ``pipe``.
    """
    import numpy as np

    devices = list(devices)
    if len(devices) != tp * pp:
        raise ValueError(
            f"replica mesh needs tp*pp={tp * pp} devices, got {len(devices)}")
    return jax.sharding.Mesh(
        np.asarray(devices, dtype=object).reshape(pp, tp), ("pipe", "model"))
