"""Production mesh construction (multi-pod dry-run spec).

A function — not a module-level constant — so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(devices: int = 8, model: int = 2):
    """CPU-test mesh (requires XLA_FLAGS host device count >= devices)."""
    return jax.make_mesh((devices // model, model), ("data", "model"))
