import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  Everything below is ordinary code.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, get_config          # noqa: E402
from repro.launch import sharding as shd                      # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.shapes import SHAPES, applicable            # noqa: E402
from repro.models import config as mcfg                       # noqa: E402
from repro.models.model import (DecodeCache, decode_step,     # noqa: E402
                                init_cache, init_params, prefill)
from repro.pshard import sharding_rules                       # noqa: E402
from repro.train.trainer import (TrainConfig, init_train_state,  # noqa: E402
                                 make_train_step)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# --------------------------------------------------------------------------
# Inputs (ShapeDtypeStruct stand-ins; no allocation).
# --------------------------------------------------------------------------


def input_specs(cfg: mcfg.ModelConfig, shape_name: str,
                cache_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for every model input of the given shape cell."""
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    sds = jax.ShapeDtypeStruct
    use_embeds = cfg.modality == "audio_stub"
    if sh.kind == "train":
        batch = {"labels": sds((B, S), jnp.int32)}
        if use_embeds:
            batch["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        return {"batch": batch}
    if sh.kind == "prefill":
        if use_embeds:
            return {"embeds": sds((B, S, cfg.d_model), jnp.bfloat16)}
        return {"tokens": sds((B, S), jnp.int32)}
    # decode: one new token against a cache of S tokens
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, cache_dtype))
    out = {"cache": cache}
    if use_embeds:
        out["embeds"] = sds((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = sds((B,), jnp.int32)
    return out


# --------------------------------------------------------------------------
# Cell construction: (fn, example args, in/out shardings).
# --------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               fsdp: bool | None = None, tp: int = 16,
               remat: bool = True, extra_rules: dict | None = None,
               unroll: bool = False, cfg_overrides: dict | None = None,
               cache_dtype=jnp.bfloat16):
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    if unroll:
        cfg = _dc.replace(cfg, scan_unroll=cfg.n_layers)
    sh = SHAPES[shape_name]
    ok, reason = applicable(cfg, sh)
    if not ok:
        raise ValueError(f"skip: {reason}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan, run_cfg = shd.make_plan(cfg, sh.kind, multi_pod, sh.global_batch,
                                  tp=tp, fsdp=fsdp)
    if extra_rules:
        plan.rules.update(extra_rules)
    pspecs = shd.param_pspecs(run_cfg, plan)
    batch_axes = plan.rules["batch"]
    ins = input_specs(run_cfg, shape_name, cache_dtype)

    if sh.kind == "train":
        tcfg = TrainConfig(remat=remat, param_dtype=jnp.float32,
                           microbatches=1)
        fn = make_train_step(run_cfg, tcfg)
        state_sds = jax.eval_shape(
            lambda: init_train_state(run_cfg, jax.random.PRNGKey(0), tcfg))
        state_specs = {"params": pspecs, "opt": shd.opt_pspecs(pspecs)}
        batch_specs = jax.tree.map(
            lambda x: P(batch_axes, *([None] * (len(x.shape) - 1))),
            ins["batch"])
        metrics_specs = {k: P() for k in
                         ("nll", "accuracy", "tokens", "aux_loss",
                          "grad_norm", "lr")}
        args = (state_sds, ins["batch"])
        in_specs = (state_specs, batch_specs)
        out_specs = (state_specs, metrics_specs)
        donate = (0,)
    elif sh.kind == "prefill":
        def fn(params, inputs):
            return prefill(params, run_cfg,
                           tokens=inputs.get("tokens"),
                           embeds=inputs.get("embeds"))
        in_batch = {k: v for k, v in ins.items()}
        in_specs = (pspecs, jax.tree.map(
            lambda x: P(batch_axes, *([None] * (len(x.shape) - 1))), in_batch))
        cache_specs = shd.cache_pspecs(run_cfg, plan)
        # prefill produces the cache already sequence-sharded for decode
        out_specs = (P(batch_axes, plan.rules["vocab"]), cache_specs)
        params_sds = jax.eval_shape(
            lambda: init_params(run_cfg, jax.random.PRNGKey(0), jnp.bfloat16))
        args = (params_sds, in_batch)
        donate = ()
    else:  # decode
        def fn(params, inputs, cache):
            return decode_step(params, run_cfg, inputs.get("tokens"),
                               cache, embeds=inputs.get("embeds"))
        cache_specs = shd.cache_pspecs(run_cfg, plan)
        tok = {k: v for k, v in ins.items() if k != "cache"}
        tok_specs = jax.tree.map(
            lambda x: P(batch_axes, *([None] * (len(x.shape) - 1))), tok)
        params_sds = jax.eval_shape(
            lambda: init_params(run_cfg, jax.random.PRNGKey(0), jnp.bfloat16))
        in_specs = (pspecs, tok_specs, cache_specs)
        out_specs = (P(batch_axes, plan.rules["vocab"]), cache_specs)
        args = (params_sds, tok, ins["cache"])
        donate = (2,)

    return dict(cfg=run_cfg, mesh=mesh, plan=plan, fn=fn, args=args,
                in_specs=in_specs, out_specs=out_specs, donate=donate,
                shape=sh)


# --------------------------------------------------------------------------
# HLO collective accounting.
# --------------------------------------------------------------------------


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bpe


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind {count, operand bytes} summed over the module (per-device
    shapes: the compiled module is already SPMD-partitioned)."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            token = f" {kind}("
            idx = line.find(token)
            if idx < 0:
                # also match "-start(" variants for async collectives
                token = f" {kind}-start("
                idx = line.find(token)
                if idx < 0:
                    continue
            operand_part = line[idx + len(token):]
            matches = _SHAPE_RE.findall(operand_part)
            b = sum(_shape_bytes(dt, dims) for dt, dims in matches)
            if b == 0:
                # fall back to the result shape(s) before '='
                matches = _SHAPE_RE.findall(line[:idx])
                b = sum(_shape_bytes(dt, dims) for dt, dims in matches)
            stats[kind]["count"] += 1
            stats[kind]["bytes"] += b
            break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


# --------------------------------------------------------------------------
# One cell: lower + compile + analyses.
# --------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True,
             **build_kwargs) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False,
           "unroll": bool(build_kwargs.get("unroll", False))}
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, multi_pod, **build_kwargs)
    except ValueError as e:
        rec.update(skipped=True, reason=str(e))
        if out_dir:
            _save(rec, out_dir)
        return rec
    mesh, plan = cell["mesh"], cell["plan"]
    rec["plan"] = plan.describe()
    try:
        named_in = shd.named(mesh, cell["in_specs"])
        named_out = shd.named(mesh, cell["out_specs"])
        jitted = jax.jit(cell["fn"], in_shardings=named_in,
                         out_shardings=named_out,
                         donate_argnums=cell["donate"])
        with mesh:
            with sharding_rules(mesh, plan.rules):
                lowered = jitted.lower(*cell["args"])
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        cost = compiled.cost_analysis() or {}
        rec["per_device_flops"] = float(cost.get("flops", 0.0))
        rec["per_device_bytes"] = float(cost.get("bytes accessed", 0.0))
        mem = compiled.memory_analysis()
        if mem is not None:
            rec["memory"] = {
                k: int(getattr(mem, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")}
            if verbose:
                print(mem)
        if verbose:
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        rec["n_devices"] = mesh.size
        rec["ok"] = True
    except Exception as e:  # record failure for the report
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    if out_dir:
        _save(rec, out_dir)
    return rec


def _save(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


# --------------------------------------------------------------------------
# CLI.
# --------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--subprocess", action="store_true",
                    help="one subprocess per cell (isolates compile memory)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan (true HLO FLOP accounting; "
                         "slower compiles)")
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    for arch in archs:
        cfg = get_config(arch)
        for sname in shapes:
            ok, reason = applicable(cfg, SHAPES[sname])
            if not ok:
                print(f"SKIP {arch} x {sname}: {reason}")
                continue
            for mp in meshes:
                cells.append((arch, sname, mp))

    failures = []
    for arch, sname, mp in cells:
        mesh_name = "multi" if mp else "single"
        path = os.path.join(args.out, f"{arch}_{sname}_{mesh_name}.json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("ok"):
                    print(f"EXISTS {arch} x {sname} x {mesh_name}")
                    continue
        print(f"=== {arch} x {sname} x {mesh_name} ===", flush=True)
        if args.subprocess:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", sname,
                   "--mesh", mesh_name, "--out", args.out]
            if args.unroll:
                cmd.append("--unroll")
            r = subprocess.run(cmd, capture_output=True, text=True)
            print(r.stdout[-2000:])
            ok = False
            if os.path.exists(path):
                with open(path) as f:
                    ok = json.load(f).get("ok", False)
            if not ok:
                print(r.stderr[-2000:])
                failures.append((arch, sname, mesh_name))
        else:
            rec = run_cell(arch, sname, mp, out_dir=args.out,
                           unroll=args.unroll)
            if not rec["ok"] and not rec.get("skipped"):
                print(rec.get("error"))
                failures.append((arch, sname, mesh_name))
            else:
                print(f"ok={rec['ok']} lower={rec.get('lower_s')}s "
                      f"compile={rec.get('compile_s')}s "
                      f"coll={rec.get('collectives', {}).get('total_bytes', 0)/1e9:.2f}GB/dev")
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells OK")
    if failures:
        print("FAILURES:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
