"""Sharding rules: logical axes -> mesh axes, per architecture and mode.

The resolution logic implements DESIGN.md §4:
  * MLP d_ff / vocab / experts over `model`;
  * attention by heads when divisible, padded heads ("pad") or replicated
    ("replicate") otherwise; KV-head sharding only when divisible;
  * decode KV caches sequence-sharded over `model` (flash-decoding);
  * batch over (`pod`, `data`); long_500k (batch=1) shards the KV sequence
    over (`data`, `model`) instead;
  * optional FSDP row-sharding of parameters over `data` (required for
    qwen1.5-110b, whose fp32 train state cannot fit TP-only).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    rules: dict                  # logical activation axis -> mesh axes
    fsdp: bool                   # row-shard params over data
    attn_mode: str               # heads | pad | replicate
    tp: int                      # size of the model axis

    def describe(self) -> str:
        return (f"attn={self.attn_mode} fsdp={self.fsdp} "
                + " ".join(f"{k}:{v}" for k, v in sorted(
                    self.rules.items(), key=lambda kv: kv[0])
                    if v is not None))


def _divisible(n: int, tp: int) -> bool:
    return n > 0 and n % tp == 0


def resolve_attn_mode(cfg: ModelConfig, tp: int) -> str:
    mode = cfg.attn_sharding
    if mode == "auto":
        if _divisible(cfg.n_q_heads, tp):
            return "heads"
        padded = pad_heads(cfg, tp)
        if padded is not None and padded[0] <= 2 * cfg.n_q_heads:
            return "pad"
        return "replicate"
    if mode == "heads" and not _divisible(cfg.n_q_heads, tp):
        return "pad" if pad_heads(cfg, tp) else "replicate"
    if mode == "pad" and pad_heads(cfg, tp) is None:
        # no function-preserving padding below the 4x bound: an explicit
        # "pad" must degrade too, or the plan would shard unpadded heads
        return "replicate"
    return mode


def pad_heads(cfg: ModelConfig, tp: int) -> tuple[int, int] | None:
    """(padded_q_heads, padded_kv_heads) preserving the GQA group mapping.

    MHA: pad q and kv together.  GQA: pad heads-per-group so kv*g' % tp == 0.
    Returns None if no preserving padding exists below 4x.
    """
    q, kv = cfg.n_q_heads, cfg.n_kv_heads
    if q == kv:
        qp = ((q + tp - 1) // tp) * tp
        if qp > 4 * q:
            return None     # tp so large the pad would exceed the 4x bound
        return (qp, qp)
    g = q // kv
    gp = g
    while kv * gp <= 4 * q:      # same 4x bound as the MHA branch
        if (kv * gp) % tp == 0:
            return (kv * gp, kv)
        gp += 1
    return None


def padded_config(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Apply head padding for 'pad' mode (identity function preserved by
    zero-padding weights; see pad_attention_params)."""
    res = pad_heads(cfg, tp)
    if res is None:
        return cfg
    qp, kvp = res
    if qp == cfg.n_q_heads and kvp == cfg.n_kv_heads:
        return cfg
    return dataclasses.replace(cfg, n_q_heads=qp, n_kv_heads=kvp)


def make_plan(cfg: ModelConfig, shape_kind: str, multi_pod: bool,
              global_batch: int, tp: int = 16, fsdp: bool | None = None,
              pp: int = 1) -> tuple[ShardingPlan, ModelConfig]:
    """Returns (plan, possibly-padded config).

    ``shape_kind="serve"`` is the paged serving-replica mode: KV pools are
    head-sharded over ``model`` (never sequence-sharded — pages are the
    storage unit), batch stays host-scheduled (unsharded), and ``pp > 1``
    shards the layer-stacked parameter/pool leading axis over ``pipe``
    (see ``launch.mesh.make_replica_mesh``).
    """
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    attn_mode = resolve_attn_mode(cfg, tp)
    run_cfg = padded_config(cfg, tp) if attn_mode == "pad" else cfg

    heads = "model" if attn_mode in ("heads", "pad") else None
    kv_heads = ("model" if attn_mode in ("heads", "pad")
                and _divisible(run_cfg.n_kv_heads, tp) else None)

    if fsdp is None:
        # FSDP (row-shard params over `data`) only when TP-only sharding
        # cannot fit ~60% of v5e HBM: train state = fp32 params + adam m/v
        # + fp32 grads = 16 B/param; serving = bf16 weights.
        if shape_kind == "train":
            fsdp = True   # fp32 state + grads: TP-only never leaves headroom
        else:
            fsdp = cfg.param_count() * 2 / tp > 9e9

    expert_mode = cfg.expert_sharding
    if expert_mode == "auto":
        expert_mode = "ep" if _divisible(cfg.n_experts, tp) else "tp"

    rules = {
        "batch": batch_axes if global_batch > 1 else None,
        "seq": None,
        "heads": heads,
        "kv_heads": kv_heads,
        "d_ff": "model",
        "d_model": None,
        "vocab": "model",
        "experts": "model" if expert_mode == "ep" else None,
        "expert_ff": "model" if expert_mode == "tp" else None,
        "moe_groups": batch_axes if global_batch > 1 else None,
        "ssm_heads": "model" if _divisible(cfg.ssm_heads, tp) else None,
        "kv_seq": None,
        # sequence-parallel residual stream (Korthikanti-style) for training:
        # layer-boundary activations shard over `model`; per-arch opt-out
        # (hillclimb: SP is a net loss for small-d_model MoE, see EXPERIMENTS)
        "act_seq": ("model" if shape_kind == "train" and cfg.seq_parallel
                    else None),
        "fsdp": "data" if fsdp else None,
        # layer-stacked leading axis of params / paged pools (pipeline
        # parallelism inside a serving replica); an indivisible layer count
        # replicates across `pipe` instead of failing placement
        "layers": ("pipe" if pp > 1 and cfg.n_layers % pp == 0 else None),
    }
    if shape_kind == "serve":
        # serving replica: batch is host-scheduled (decode batches are tiny
        # and padded to buckets), KV pools shard by head, never by sequence
        rules["batch"] = None
        rules["moe_groups"] = None
        rules["fsdp"] = None
        return ShardingPlan(rules, False, attn_mode, tp), run_cfg
    if shape_kind == "decode":
        if global_batch == 1:
            # long-context single sequence: shard the KV sequence everywhere
            rules["kv_seq"] = tuple(a for a in (*batch_axes, "model"))
        else:
            rules["kv_seq"] = "model"
        # the cache sequence axis owns `model`; KV heads replicate at decode
        rules["kv_heads"] = None
    return ShardingPlan(rules, fsdp, attn_mode, tp), run_cfg


# --------------------------------------------------------------------------
# Parameter / cache / batch PartitionSpecs.
# --------------------------------------------------------------------------


def param_pspecs(cfg: ModelConfig, plan: ShardingPlan):
    """Pytree of PartitionSpec mirroring init_params(cfg)."""
    r = plan.rules
    row = r["fsdp"]   # None or "data"
    layers = r.get("layers")   # None, or "pipe" for pp-sharded replicas

    def blocks(spec: P) -> P:
        return P(layers, *spec)  # layer-stacked leading dim

    b: dict = {"ln1": blocks(P(None))}
    if cfg.has_attn:
        attn = {
            "wq": blocks(P(row, r["heads"])),
            "wk": blocks(P(row, r["kv_heads"])),
            "wv": blocks(P(row, r["kv_heads"])),
            "wo": blocks(P(r["heads"], row)),
        }
        if cfg.qkv_bias:
            attn["bq"] = blocks(P(r["heads"]))
            attn["bk"] = blocks(P(r["kv_heads"]))
            attn["bv"] = blocks(P(r["kv_heads"]))
        if cfg.qk_norm:
            attn["q_norm"] = blocks(P(None))
            attn["k_norm"] = blocks(P(None))
        b["attn"] = attn
    if cfg.has_ssm:
        sh = r["ssm_heads"]
        b["ssm"] = {
            "w_z": blocks(P(row, sh)), "w_x": blocks(P(row, sh)),
            "w_B": blocks(P(row, None)), "w_C": blocks(P(row, None)),
            "w_dt": blocks(P(row, sh)),
            "conv_x": blocks(P(None, sh)),
            "conv_B": blocks(P(None, None)), "conv_C": blocks(P(None, None)),
            "conv_b": blocks(P(None)),
            "dt_bias": blocks(P(sh)), "A_log": blocks(P(sh)),
            "D": blocks(P(sh)), "norm_w": blocks(P(sh)),
            "out_proj": blocks(P(sh, row)),
        }
    if cfg.hybrid:
        b["attn_out_norm"] = blocks(P(None))
        b["ssm_out_norm"] = blocks(P(None))
    if cfg.sandwich_norm:
        b["post_ln1"] = blocks(P(None))
    if cfg.is_moe:
        e, eff = r["experts"], r["expert_ff"]
        b["ln2"] = blocks(P(None))
        b["moe"] = {
            "router": blocks(P(row, None)),
            "w_gate": blocks(P(e, row, eff)),
            "w_up": blocks(P(e, row, eff)),
            "w_down": blocks(P(e, eff, row)),
        }
    elif cfg.d_ff > 0:
        b["ln2"] = blocks(P(None))
        mlp = {
            "w_gate": blocks(P(row, r["d_ff"])),
            "w_up": blocks(P(row, r["d_ff"])),
            "w_down": blocks(P(r["d_ff"], row)),
        }
        if cfg.mlp_variant == "gelu":
            del mlp["w_gate"]
        if cfg.mlp_bias:
            mlp["b_up"] = blocks(P(r["d_ff"]))
            mlp["b_down"] = blocks(P(None))
        b["mlp"] = mlp
    if cfg.sandwich_norm and (cfg.is_moe or cfg.d_ff > 0):
        b["post_ln2"] = blocks(P(None))

    specs = {
        "embed": P(r["vocab"], None),
        "blocks": b,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(row, r["vocab"])
    return specs


def opt_pspecs(param_specs):
    return {"m": param_specs, "v": param_specs, "step": P()}


def cache_pspecs(cfg: ModelConfig, plan: ShardingPlan):
    """PartitionSpecs for a DecodeCache pytree."""
    from repro.models.model import DecodeCache
    r = plan.rules
    layers = r.get("layers")
    k = v = ssm = conv = None
    if cfg.has_attn:
        k = P(layers, r["batch"], r["kv_seq"], r["kv_heads"], None)
        v = k
    if cfg.has_ssm:
        ssm = P(layers, r["batch"], r["ssm_heads"], None, None)
        conv = P(layers, r["batch"], None, None)
    return DecodeCache(k=k, v=v, ssm=ssm, conv=conv, pos=P(r["batch"]))


def pool_pspecs(cfg: ModelConfig, plan: ShardingPlan) -> P | None:
    """PartitionSpec for one paged K/V ``BlockPool`` array.

    Pool layout is ``[L, num_blocks + 1, Hkv, page, D]`` (kernel-native):
    the layer axis shards over ``pipe`` (pp), the KV-head axis over
    ``model`` (tp, when divisible), and pages/positions stay whole — block
    tables address a head-sharded pool exactly like an unsharded one, which
    is what keeps the host allocator and the migration page-handoff path
    oblivious to sharding.
    """
    if not cfg.has_attn:
        return None
    r = plan.rules
    return P(r.get("layers"), None, r["kv_heads"], None, None)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# Head padding for parameters (function-preserving).
# --------------------------------------------------------------------------


def pad_attention_params(params, cfg: ModelConfig, padded: ModelConfig):
    """Zero-pad q (and kv) head weights so the padded model computes the
    identical function: padded q-head rows of wo are zero, padded kv heads
    are only attended by padded q heads.

    Real heads keep their GQA group: group g occupies slots
    [g*gp, g*gp + g_real) in the padded layout.
    """
    if padded.n_q_heads == cfg.n_q_heads and padded.n_kv_heads == cfg.n_kv_heads:
        return params
    D = cfg.head_dim
    q_old, q_new = cfg.n_q_heads, padded.n_q_heads
    kv_old, kv_new = cfg.n_kv_heads, padded.n_kv_heads
    if kv_new != kv_old:
        # MHA: kv pads together with q (pad_heads returns (qp, qp)), so the
        # whole head axis is ONE group — real heads keep slots [0, q_old)
        # and padded q/kv heads pair up at the tail (wk/wv pad below
        # appends kv zeros at the end, matching)
        groups, per_old, per_new = 1, q_old, q_new
    else:
        # GQA: kv heads unchanged; pad heads-per-group inside each group
        groups, per_old, per_new = kv_old, q_old // kv_old, q_new // kv_new

    def scatter_cols(w, heads_old, heads_new, groups, per_old, per_new):
        # w: [..., heads_old*D] -> [..., heads_new*D] group-aware
        shape = w.shape[:-1]
        w = w.reshape(*shape, groups, per_old, D)
        out = jnp.zeros((*shape, groups, per_new, D), w.dtype)
        out = out.at[..., :per_old, :].set(w)
        return out.reshape(*shape, heads_new * D)

    def fix_attn(a):
        a = dict(a)
        a["wq"] = scatter_cols(a["wq"], q_old, q_new, groups,
                               per_old, per_new)
        a["wo"] = jnp.moveaxis(
            scatter_cols(jnp.moveaxis(a["wo"], -1, -2), q_old, q_new,
                         groups, per_old, per_new), -1, -2)
        if "bq" in a:
            a["bq"] = scatter_cols(a["bq"], q_old, q_new, groups,
                                   per_old, per_new)
        if kv_new != kv_old:
            for name in ("wk", "wv"):
                w = a[name]
                w = w.reshape(*w.shape[:-1], kv_old, D)
                out = jnp.zeros((*w.shape[:-2], kv_new, D), w.dtype)
                a[name] = out.at[..., :kv_old, :].set(w).reshape(
                    *w.shape[:-2], kv_new * D)
            for name in ("bk", "bv"):
                if name in a:
                    w = a[name].reshape(*a[name].shape[:-1], kv_old, D)
                    out = jnp.zeros((*w.shape[:-2], kv_new, D), w.dtype)
                    a[name] = out.at[..., :kv_old, :].set(w).reshape(
                        *w.shape[:-2], kv_new * D)
        return a

    new_params = dict(params)
    new_blocks = dict(params["blocks"])
    new_blocks["attn"] = fix_attn(params["blocks"]["attn"])
    new_params["blocks"] = new_blocks
    return new_params
