"""The assigned input-shape set (one per arch x shape cell).

  train_4k      seq 4,096   global_batch 256   -> train_step
  prefill_32k   seq 32,768  global_batch 32    -> serve prefill
  decode_32k    seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                  KV cache of seq_len)
  long_500k     seq 524,288 global_batch 1     -> serve_step; SSM/hybrid only

``long_500k`` is skipped for pure full-attention archs per the assignment
(noted in DESIGN.md §5); all archs are decoder-style so decode shapes apply.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip per "
                       "assignment, noted in DESIGN.md)")
    return True, ""


def cells(configs: dict[str, ModelConfig]) -> list[tuple[str, str]]:
    """All runnable (arch, shape) cells."""
    out = []
    for arch, cfg in configs.items():
        for sname, sh in SHAPES.items():
            ok, _ = applicable(cfg, sh)
            if ok:
                out.append((arch, sname))
    return out
