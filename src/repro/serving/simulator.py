"""Discrete-event serving simulator (drives the paper's e2e experiments).

Model (faithful to continuous batching):
  * each replica is a G/G/c multi-slot server: c = the cost model's effective
    decode batch for the replica's *assigned blend* of types; a request holds
    one slot for its full residence time response_j = prefill + out_len *
    decode_step(blend);
  * co-batched long-context sequences slow every decode step on the replica
    (shared KV reads), so both residency and capacity degrade with the blend
    — the interference that the scheduler's type segregation removes;
  * deployment switches happen at span boundaries: replicas whose
    configuration changed are blocked from admitting new requests for the
    switch duration (ad hoc transfer vs naive reload — the policy decides);
    queued requests are re-routed through the new assignment (KV migration
    per paper S4.2).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Protocol

import numpy as np

from repro.core.costmodel import CostModel
from repro.core.types import Deployment, WorkloadType
from repro.serving.request import Request


@dataclasses.dataclass
class SpanDecision:
    deployment: Deployment
    fractions: list[list[float]]          # [k][j]
    switch_seconds: float = 0.0           # applied to changed replicas
    changed: list[int] | None = None      # replica indices blocked during switch


class Policy(Protocol):
    def decide(self, span: int, rates: np.ndarray, current: Deployment | None
               ) -> SpanDecision: ...
    # Policies may also define observe(achieved: list[float]) — the driver
    # reports each replica's achieved/expected service fraction for the span
    # that just ended (requests that began service / requests routed), the
    # same health signal ClusterRuntime feeds Orchestrator.observe_health.


@dataclasses.dataclass
class SimResult:
    requests: list[Request]
    spans: int
    span_seconds: float
    deployments: list[str]
    switch_spans: int
    dropped: int

    def metrics(self) -> dict:
        lat = np.array([r.latency for r in self.requests if r.finish >= 0])
        done = len(lat)
        ttft = np.array([r.ttft for r in self.requests if r.first_token >= 0])
        dur = self.spans * self.span_seconds
        out = {"completed": done, "throughput_rps": done / dur,
               "dropped": self.dropped}
        # goodput: only requests inside their TTFT + TPOT budgets count
        # (requests without budgets — inf — count whenever they finish)
        good = sum(1 for r in self.requests if r.slo_met)
        out["goodput_rps"] = good / dur
        out["slo_attainment"] = good / max(len(self.requests), 1)
        if done:
            out.update(
                avg_latency=float(lat.mean()),
                p50=float(np.percentile(lat, 50)),
                p90=float(np.percentile(lat, 90)),
                p95=float(np.percentile(lat, 95)),
                p99=float(np.percentile(lat, 99)),
                p99_ttft=float(np.percentile(ttft, 99)) if len(ttft) else 0.0,
            )
        return out

    def span_metrics(self, span: int) -> dict:
        lo, hi = span * self.span_seconds, (span + 1) * self.span_seconds
        rs = [r for r in self.requests if lo <= r.arrival < hi]
        lat = np.array([r.latency for r in rs if r.finish >= 0])
        return {"n": len(rs),
                "p99": float(np.percentile(lat, 99)) if len(lat) else float("inf"),
                "avg": float(lat.mean()) if len(lat) else float("inf")}


class _ReplicaSim:
    """Continuous-batching replica: c parallel slots + FIFO admission queue."""

    def __init__(self, rid: int, slots: int):
        self.rid = rid
        self.slots = max(1, slots)
        self.busy: list[float] = []               # end-times heap
        self.queue: list[tuple[float, int]] = []  # (arrival, req idx)
        self.blocked_until = 0.0
        self.work_queued = 0.0                    # slot-seconds waiting

    def free_at(self, now: float) -> bool:
        while self.busy and self.busy[0] <= now + 1e-9:
            heapq.heappop(self.busy)
        return len(self.busy) < self.slots and now >= self.blocked_until

    def wait_estimate(self, now: float) -> float:
        backlog = self.work_queued / self.slots
        if len(self.busy) >= self.slots and self.busy:
            backlog += max(0.0, self.busy[0] - now)
        return backlog + max(0.0, self.blocked_until - now)


def simulate(
    requests: list[Request],
    policy,
    cm: CostModel,
    workloads: list[WorkloadType],
    n_spans: int,
    span_seconds: float = 60.0,
    queue_cap_seconds: float = 240.0,
) -> SimResult:
    """Run the trace through the policy-controlled cluster."""
    J = len(workloads)
    counts = np.zeros((n_spans, J))
    for r in requests:
        s = min(int(r.arrival // span_seconds), n_spans - 1)
        counts[s, r.type_id] += 1

    deployment: Deployment | None = None
    replicas: list[_ReplicaSim] = []
    span_routed: list[list[int]] = []     # [k] -> request idx routed this span
    perf: list[list] = []
    response: list[list[float]] = []   # [k][j] residence under the blend
    fractions = None
    sent = seen = None
    deployments_log: list[str] = []
    switch_spans = 0
    dropped = 0

    events: list[tuple] = []
    for i, r in enumerate(requests):
        heapq.heappush(events, (r.arrival, 2 * i + 1, "arrive", i))
    for s in range(n_spans):
        heapq.heappush(events, (s * span_seconds, 2 * s, "span", s))

    ctxs = np.array([w.in_len + w.out_len // 2 for w in workloads], float)

    def configure(dep: Deployment, fracs: np.ndarray, rates: np.ndarray):
        """(Re)build blended residence times + per-replica slot counts."""
        nonlocal perf, response
        perf = [[cm.replica_perf(rc, w) for w in workloads]
                for rc in dep.replicas]
        response = []
        slot_counts = []
        for k, rc in enumerate(dep.replicas):
            share = fracs[k] * np.maximum(rates, 0.0)
            tot = share.sum()
            blend = float((share * ctxs).sum() / tot) if tot > 0 else None
            row = []
            c_est = 0.0
            for j, w in enumerate(workloads):
                p = perf[k][j]
                if not p.fits:
                    row.append(float("inf"))
                    continue
                ctx = int(max(blend if blend is not None else ctxs[j],
                              w.in_len))
                dstep = cm.measure_decode_step(rc, p.b_eff, ctx)
                row.append(p.prefill_time + w.out_len * dstep)
                weight = share[j] / tot if tot > 0 else 1.0 / J
                c_est += p.b_eff * weight
            response.append(row)
            slot_counts.append(max(1, int(round(c_est))))
        return slot_counts

    def start_next(k: int, now: float):
        rep = replicas[k]
        while rep.queue and rep.free_at(now):
            _, idx = heapq.heappop(rep.queue)
            r = requests[idx]
            resp = response[k][r.type_id]
            if resp == float("inf"):
                nonlocal dropped
                dropped += 1
                continue
            rep.work_queued = max(0.0, rep.work_queued - resp)
            r.start = now
            r.first_token = now + perf[k][r.type_id].prefill_time
            r.finish = now + resp
            heapq.heappush(rep.busy, r.finish)
            heapq.heappush(events, (r.finish, 2 * idx + 1, "free", k))

    def route(r: Request, now: float) -> int:
        nonlocal sent, seen
        j = r.type_id
        seen[j] += 1
        deficit = fractions[:, j] * seen[j] - sent[:, j]
        for k in range(len(replicas)):
            if response[k][j] == float("inf"):
                deficit[k] = -np.inf
        k = int(np.argmax(deficit))
        sent[k, j] += 1
        return k

    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == "span":
            s = payload
            rates = counts[s]
            # report the ended span's per-replica achieved fraction (requests
            # that began service / requests routed) before the next decision
            observe = getattr(policy, "observe", None)
            if observe is not None and replicas and any(span_routed):
                achieved = [
                    (sum(1 for i in routed if requests[i].start >= 0)
                     / len(routed)) if routed else 1.0
                    for routed in span_routed]
                observe(achieved)
            decision = policy.decide(s, rates, deployment)
            new_dep = decision.deployment
            fracs = np.asarray(decision.fractions, dtype=np.float64)
            if deployment is None or new_dep.replicas != deployment.replicas:
                if deployment is not None:
                    switch_spans += 1
                old_queues = [rep.queue for rep in replicas]
                deployment = new_dep
                slot_counts = configure(deployment, fracs, rates)
                K = len(deployment.replicas)
                replicas = [_ReplicaSim(k, slot_counts[k]) for k in range(K)]
                changed = (decision.changed if decision.changed is not None
                           else list(range(K)))
                for k in changed:
                    replicas[k].blocked_until = now + decision.switch_seconds
                sent = np.zeros((K, J))
                seen = np.zeros(J)
                fractions = fracs
                span_routed = [[] for _ in replicas]
                # re-route carried-over requests through the new assignment
                # (KV migrated per paper S4.2)
                for item in sorted(i for q in old_queues for i in q):
                    r = requests[item[1]]
                    k = route(r, now)
                    heapq.heappush(replicas[k].queue, item)
                    span_routed[k].append(item[1])
                    resp = response[k][r.type_id]
                    if resp != float("inf"):
                        replicas[k].work_queued += resp
            else:
                fractions = fracs
                slot_counts = configure(deployment, fracs, rates)
                for k, rep in enumerate(replicas):
                    rep.slots = slot_counts[k]
                span_routed = [[] for _ in replicas]
            deployments_log.append(str(deployment))
            for k in range(len(replicas)):
                start_next(k, now)
        elif kind == "arrive":
            r = requests[payload]
            if deployment is None:
                dropped += 1
                continue
            k = route(r, now)
            rep = replicas[k]
            resp = response[k][r.type_id]
            if (resp == float("inf")
                    or rep.wait_estimate(now) > queue_cap_seconds):
                dropped += 1
                continue
            r.replica = k
            rep.work_queued += resp
            heapq.heappush(rep.queue, (r.arrival, payload))
            span_routed[k].append(payload)
            start_next(k, now)
        else:  # free
            if payload < len(replicas):
                start_next(payload, now)

    return SimResult(requests, n_spans, span_seconds, deployments_log,
                     switch_spans, dropped)
