"""Real-compute validation driver: orchestrator plans on live engines.

Shared by ``examples/serve_orchestrated.py --real`` and
``benchmarks/bench_e2e.real_validation`` so the two surfaces cannot drift:
plans are made by the real ``Orchestrator`` against the paper-scale cost
model, executed by ``ClusterRuntime`` on a smoke-scale model (CPU-sized),
and each span's planner-predicted per-replica traffic share is scored
against the share the engines actually served.

The requests executed are tiny per-type stand-ins of the paper archetypes,
so the comparison is about routing shares, switch execution (drain /
migrate counters), and the health/rate feedback loop — not absolute
throughput.  Requests deliberately remain in flight across span boundaries
(no mid-run flush) so every deployment change exercises the live
drain/export/migrate path, not an idle-cluster rebuild.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.types import WorkloadType

# Paper-scale archetypes used for *planning*; per-type tiny stand-ins for
# *execution* on the smoke model.
REAL_ARCHETYPES = [WorkloadType(1275, 287), WorkloadType(139, 133),
                   WorkloadType(1181, 1824), WorkloadType(282, 1121)]
REAL_PROMPT_LEN = [14, 6, 12, 8]
REAL_NEW_TOKENS = [4, 4, 8, 6]
# alternate between a short-task-heavy and a long-output-heavy mix so the
# orchestrator has a reason to re-deploy mid-run
REAL_SPAN_RATES = ([5, 300, 2, 3], [40, 10, 60, 40])


@dataclasses.dataclass
class RealSpanOutcome:
    span: int
    plan: object                  # core.orchestrator.SpanPlan
    switch: object                # serving.cluster.SwitchReport
    report: object                # serving.cluster.SpanReport
    predicted_share: np.ndarray   # planner fractions @ rates, normalized
    achieved_share: np.ndarray    # tokens actually served per replica
    observed_rates: np.ndarray    # orchestrator's per-type EWMA after span
    n_requests: int
    seconds: float

    @property
    def share_l1(self) -> float:
        return float(np.abs(self.predicted_share - self.achieved_share).sum())


def run_real_spans(model: str = "opt-30b", chips: int = 6, n_spans: int = 2,
                   requests_per_span: int = 6, seed: int = 0,
                   shard: bool = False, prefix_cache: bool = True,
                   shared_prefix_len: int = 16, telemetry=None,
                   rebalance: bool = False, disagg: bool = False
                   ) -> tuple[list[RealSpanOutcome], "object"]:
    """Drive ``n_spans`` orchestrator plans through a real ClusterRuntime.

    Returns the per-span outcomes and the runtime (whose ``results`` hold
    every finished request for parity / completeness checks).

    ``telemetry`` (a ``serving.telemetry.Telemetry``) is threaded into the
    runtime when given: lifecycle events, latency histograms and the
    orchestrator decision audit accumulate there, and the caller can export
    a Chrome trace of the run afterwards.

    ``shared_prefix_len`` > 0 turns the trace into the shared-prefix shape
    real traffic has (system prompts / few-shot templates): every request
    of a type starts with that type's fixed template prefix (page-aligned
    at the runtime's block size), so the prefix cache has something to hit
    and the per-type hit-rate loop into ``plan_span`` is exercised end to
    end.  0 restores fully random prompts.

    ``rebalance=True`` turns on the runtime's live rebalancer (watchdog
    straggler drains, hot-spot relief, priority preemption — see the policy
    section in ``serving.cluster``); the per-span move counters land on
    ``SpanReport.rebalanced`` / ``SpanReport.preempted``.

    ``disagg=True`` lets the planner consider disaggregated prefill/decode
    role splits (``OrchestratorConfig.disaggregate``); when a span plan
    carries roles, the runtime routes new requests to prefill replicas and
    hands first-token-ready contexts to decode replicas
    (``SpanReport.handoffs`` / ``SpanReport.role_util``).

    ``shard=True`` executes each replica's (tp, pp) on a real per-replica
    device sub-mesh (needs >= ``chips`` jax devices, e.g. under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); plans are
    otherwise identical, so the predicted-vs-achieved scoring is directly
    comparable between the two modes.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.core.costmodel import CostModel
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    from repro.core.types import ClusterSpec, H100_SPEC
    from repro.models import init_params
    from repro.serving.cluster import ClusterRuntime

    cfg = get_smoke_config(model)
    params = init_params(cfg, jax.random.PRNGKey(seed), jnp.float32)
    cm = CostModel(get_config(model).profile(), hw=H100_SPEC)
    orch = Orchestrator(cm, ClusterSpec(chips, hw=H100_SPEC),
                        OrchestratorConfig(search_patience=8,
                                           disaggregate=disagg))
    runtime = ClusterRuntime(cfg, params, orch, blocks_per_chip=16,
                             seqs_per_chip=1, block_size=8, drain_steps=2,
                             seed=seed, shard=shard,
                             prefix_cache=prefix_cache, telemetry=telemetry,
                             rebalance=rebalance)
    rng = np.random.RandomState(seed)
    # one fixed template per type, drawn from a separate stream so toggling
    # the mode doesn't perturb the per-request draws below
    t_rng = np.random.RandomState(seed + 1)
    templates = [t_rng.randint(0, cfg.vocab_size,
                               shared_prefix_len).astype(np.int32)
                 for _ in range(len(REAL_ARCHETYPES))]
    outcomes: list[RealSpanOutcome] = []
    rid = 0
    for s in range(n_spans):
        t0 = time.time()
        rates = REAL_SPAN_RATES[s % len(REAL_SPAN_RATES)]
        ws = [a.with_rate(float(r)) for a, r in zip(REAL_ARCHETYPES, rates)]
        plan = orch.plan_span(ws)
        switch = runtime.apply_plan(plan)
        types = rng.choice(4, size=requests_per_span,
                           p=np.asarray(rates, float) / np.sum(rates))
        for t in types:
            t = int(t)
            prompt = rng.randint(0, cfg.vocab_size,
                                 REAL_PROMPT_LEN[t]).astype(np.int32)
            if shared_prefix_len:
                prompt = np.concatenate([templates[t], prompt])
            runtime.submit(rid, prompt, REAL_NEW_TOKENS[t], type_id=t)
            rid += 1
            runtime.step(); runtime.step()
        # do NOT run to idle mid-run: later requests stay in flight across
        # the span boundary so the next apply_plan exercises the live
        # drain/migrate switch path; only the last span flushes everything
        if s == n_spans - 1:
            runtime.run_until_idle()
        report = runtime.finish_span()
        frac = np.array(plan.fractions)
        # score in *token* shares on both sides: the plan's request fractions
        # are weighted by each type's decode length so the predicted share is
        # comparable to the tokens the replicas actually emitted (carryover
        # from the previous span adds a little noise — this is a smoke
        # metric, not a benchmark)
        load = frac @ (np.asarray(rates, float)
                       * np.asarray(REAL_NEW_TOKENS, float))
        predicted = load / max(load.sum(), 1e-9)
        achieved = (np.asarray(report.tokens, float)
                    / max(sum(report.tokens), 1))
        outcomes.append(RealSpanOutcome(
            s, plan, switch, report, predicted, achieved,
            np.array(orch.observed_rates), requests_per_span,
            time.time() - t0))
    return outcomes, runtime
