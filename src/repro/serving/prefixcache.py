"""Content-addressed prefix cache + host-tiered KV store over a BlockPool.

Serving traffic at scale is dominated by shared prefixes — system prompts,
few-shot templates, multi-turn chat — yet a plain paged engine prefills
every prompt from token 0 and keeps every page in device HBM.  This module
removes both costs:

**Prefix reuse.**  ``PrefixCache`` indexes *full, page-aligned* chunks of
token streams by a chained content hash: page ``i``'s key is
``H(key[i-1] || tokens[i*bs:(i+1)*bs])``, so a key identifies the page's
content *and* its entire prefix — two prompts share a cached page iff they
are token-identical up to and including it.  On admission, the engine walks
the new prompt's chain through the index (``match``/``attach``) and attaches
every matched page to the sequence via the allocator's refcount path
(``BlockAllocator.share``): zero bytes move, zero tokens are recomputed, and
prefill starts at the first uncached token.  When the match covers the whole
prompt the last matched page is returned as a **copy-on-write source**
instead of a shared page — the sequence diverges *inside* it (its final
prompt token, and decode after it, must be written mid-page), so the page is
copied into a private block at admission and the shared original stays
immutable.  Fully-shared pages are never written: prefill resumes past them
and decode writes only positions ``>= prompt_len``, which land in the COW
page or later private pages.

**Refcount lifecycle.**  Every *device-resident* index entry holds exactly
one allocator reference on its page (taken by ``publish``/restore); each
sequence that attaches the page holds one more (``share`` at admission,
released by the normal ``release_slot`` decref).  A page is therefore
*cold* when its allocator refcount is exactly 1 — the index's own — i.e.
zero sequences reference it.  Finished/evicted sequences ``publish`` their
prompt (and generated-context) pages back to the index before their refs
drop, so the pages outlive the sequence at refcount 1 instead of returning
to the free list.

**Host tier / eviction policy.**  Cold pages oversubscribe HBM: when the
allocator cannot satisfy an allocation (``BlockPool.reclaim``), the cache
evicts cold pages — LRU over device-resident entries with zero sequence
refs — to a host-memory store (dense ``[L, bs, Hkv, D]`` numpy, the pinned
staging layout ``gather_tokens``/``scatter_tokens`` already speak) and
releases their device blocks.  A later ``attach`` hit on a host-tier entry
restores it into a fresh pool block via the same jitted scatter, paying one
host→device copy instead of a prefill forward.  ``evicted_bytes`` /
``restored_bytes`` feed ``load_stats``/``SpanReport`` so the orchestrator
sees tier pressure, and per-type hit rates discount prefill cost in
``core.costmodel`` (``WorkloadType.cached_frac``).

The cache is pool-scoped: replicas sharing one ``BlockPool`` (the default
``ClusterRuntime``) share one index, so a prefix prefilled by any replica
warms every sibling — and survives the replica's death, which is what lets
re-prefill-from-log recovery re-hit the cache.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.serving.kvcache import BlockPool, gather_tokens, scatter_tokens


def _page_key(parent: bytes, chunk: np.ndarray) -> bytes:
    """Chained content hash of one full page of tokens."""
    h = hashlib.blake2b(parent, digest_size=16)
    h.update(np.ascontiguousarray(chunk, np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass
class _Entry:
    """One cached page: device-resident (``block``) or host-tiered (``host``)."""
    key: bytes
    block: int | None                 # physical pool page; None = evicted
    host: tuple | None = None         # (k, v) dense [L, bs, Hkv, D] numpy
    tick: int = 0                     # LRU clock at last touch


@dataclasses.dataclass
class PrefixMatch:
    """A peeked index walk over one prompt (no side effects yet)."""
    cached_tokens: int                # tokens the cache can provide (< prompt)
    keys: list                        # matched entry keys, page order
    cow: bool                         # last matched page must be copied


class PrefixCache:
    """Content-addressed page index + host tier for one ``BlockPool``.

    Attach to a pool with ``PrefixCache(pool)``; the pool's ``reclaim``
    hook then evicts cold pages under allocation pressure.  All methods are
    host-side bookkeeping except the evict/restore data moves, which ride
    the existing jitted ``gather_tokens``/``scatter_tokens``.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        pool.prefix_cache = self
        self.index: dict[bytes, _Entry] = {}
        self._tick = 0
        # observability (monotonic, cluster reads deltas per span)
        self.hits = 0                 # admissions that reused >= 1 page
        self.misses = 0               # admissions with no cached prefix
        self.hit_tokens = 0           # prompt tokens served from the cache
        self.published_pages = 0
        self.evicted_bytes = 0        # device -> host tier
        self.restored_bytes = 0       # host tier -> device
        self.dropped_pages = 0        # cold pages freed without a host copy
        # telemetry sink for evict/restore events; engines sharing the pool
        # point this at the cluster's Telemetry (pool-scoped: replica=-1)
        self.telemetry = None

    # -- lookup / attach -------------------------------------------------------

    def match(self, tokens: np.ndarray, limit: int) -> PrefixMatch:
        """Walk the prompt's page chain through the index; pure peek.

        ``limit`` caps the cached length (callers pass ``prompt_len - 1`` so
        at least the final prompt token always goes through a prefill
        forward — its logits produce the first generated token).  A cap
        that lands mid-page marks the last matched page copy-on-write.
        """
        bs = self.pool.block_size
        keys: list[bytes] = []
        parent = b""
        n_full = min(len(tokens), limit if limit >= 0 else 0) // bs
        matched = 0
        for i in range(int(np.ceil(len(tokens) / bs))):
            if matched * bs >= limit:
                break
            chunk = tokens[i * bs:(i + 1) * bs]
            if len(chunk) < bs:
                break                 # partial tail page is never indexed
            key = _page_key(parent, chunk)
            if key not in self.index:
                break
            keys.append(key)
            parent = key
            matched += 1
        del n_full
        cached = min(matched * bs, limit)
        cow = bool(cached % bs) and matched > 0
        return PrefixMatch(cached if matched else 0, keys, cow)

    def attach(self, m: PrefixMatch) -> tuple[int, list[int], int | None]:
        """Realize a match: restore host-tier pages, return attachable blocks.

        Returns ``(cached_tokens, shared_blocks, cow_src)``: the caller
        (``PagedKVCache.admit``) bumps each shared block's refcount and
        copies ``cow_src`` (a block id, or None) into a private page.  A
        host-tier entry that cannot be restored (pool truly full even after
        reclaim) truncates the match there — the suffix is simply
        recomputed.  No refcounts move here, so an admission that fails
        after ``attach`` leaves the index untouched.
        """
        bs = self.pool.block_size
        blocks: list[int] = []
        ok_tokens = 0
        for key in m.keys:
            e = self.index.get(key)
            if e is None:
                break
            if e.block is None:
                try:
                    self._restore(e)
                except MemoryError:
                    break
            self._tick += 1
            e.tick = self._tick
            blocks.append(e.block)
            ok_tokens += bs
        cached = min(ok_tokens, m.cached_tokens)
        if cached <= 0:
            self.misses += 1
            return 0, [], None
        cow_src = None
        n_shared = cached // bs
        if cached % bs:
            # the sequence diverges inside the last matched page: attach it
            # by copy, not by reference
            cow_src = blocks[n_shared]
        self.hits += 1
        self.hit_tokens += cached
        return cached, blocks[:n_shared], cow_src

    # -- publish ---------------------------------------------------------------

    def publish(self, tokens: np.ndarray, blocks: list[int]) -> int:
        """Index every full page of ``tokens`` resident in ``blocks``.

        Called when a sequence's context is fully in pages (end of prefill)
        and again at retirement (decode pages extend the reusable prefix —
        multi-turn traffic hits them).  New entries take one allocator ref
        on their page so it survives the sequence's release; pages whose
        chain key is already indexed are skipped (content dedup).  Returns
        the number of pages newly indexed.
        """
        bs = self.pool.block_size
        n_full = min(len(tokens) // bs, len(blocks))
        parent = b""
        added = 0
        for i in range(n_full):
            key = _page_key(parent, tokens[i * bs:(i + 1) * bs])
            e = self.index.get(key)
            if e is None:
                self._tick += 1
                self.index[key] = _Entry(key, blocks[i], tick=self._tick)
                self.pool.allocator.share([blocks[i]])
                self.published_pages += 1
                added += 1
            elif e.block is None:
                # same content is back on device: re-point the entry at the
                # live page and drop the stale host copy
                e.block = blocks[i]
                e.host = None
                self.pool.allocator.share([blocks[i]])
                self._tick += 1
                e.tick = self._tick
            parent = key
        return added

    # -- host tier -------------------------------------------------------------

    def _page_nbytes(self) -> int:
        return self.pool.page_nbytes

    def _evict(self, e: _Entry) -> None:
        """Move one cold page to the host store and free its device block."""
        bs = self.pool.block_size
        k, v = gather_tokens(self.pool, [e.block], bs)
        e.host = (np.asarray(k), np.asarray(v))
        nbytes = e.host[0].nbytes + e.host[1].nbytes
        self.evicted_bytes += nbytes
        self.pool.allocator.release([e.block])
        e.block = None
        if self.telemetry is not None and self.telemetry.enabled:
            self.telemetry.emit("evict", pages=1, bytes=nbytes)

    def _restore(self, e: _Entry) -> None:
        """Bring a host-tiered page back into a fresh device block."""
        alloc = self.pool.allocator
        if alloc.n_free < 1:
            self.reclaim(1, skip=e)
        if alloc.n_free < 1:
            raise MemoryError("no device block free to restore cached page")
        (b,) = alloc.alloc(1)
        scatter_tokens(self.pool, [b], e.host[0], e.host[1])
        nbytes = e.host[0].nbytes + e.host[1].nbytes
        self.restored_bytes += nbytes
        e.block = b
        e.host = None
        if self.telemetry is not None and self.telemetry.enabled:
            self.telemetry.emit("restore", pages=1, bytes=nbytes)

    def cold_blocks(self) -> int:
        """Device pages held only by the index (reclaimable on demand)."""
        refs = self.pool.allocator.refs
        return sum(1 for e in self.index.values()
                   if e.block is not None and refs[e.block] == 1)

    def reclaim(self, n: int, skip: _Entry | None = None) -> None:
        """Evict cold pages (LRU first) until ``n`` blocks are free.

        Only entries with zero sequence refs (allocator refcount exactly 1,
        the index's own) are candidates; shared pages in live use are never
        touched.  Called by ``BlockPool.reclaim`` under allocation pressure
        — this is what lets admissions oversubscribe HBM with cold cached
        pages instead of shedding.
        """
        alloc = self.pool.allocator
        if alloc.n_free >= n:
            return
        cold = [e for e in self.index.values()
                if e is not skip and e.block is not None
                and alloc.refs[e.block] == 1]
        cold.sort(key=lambda e: e.tick)
        for e in cold:
            if alloc.n_free >= n:
                break
            self._evict(e)

    def drop_cold(self) -> int:
        """Free every cold device page without keeping a host copy (tests /
        teardown); returns the number of pages dropped."""
        alloc = self.pool.allocator
        dropped = 0
        for key in list(self.index):
            e = self.index[key]
            if e.block is not None and alloc.refs[e.block] == 1:
                alloc.release([e.block])
                del self.index[key]
                dropped += 1
                self.dropped_pages += 1
        return dropped

    # -- observability ---------------------------------------------------------

    def stats(self) -> dict:
        return {
            "entries": len(self.index),
            "device_pages": sum(1 for e in self.index.values()
                                if e.block is not None),
            "host_pages": sum(1 for e in self.index.values()
                              if e.host is not None),
            "cold_blocks": self.cold_blocks(),
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "evicted_bytes": self.evicted_bytes,
            "restored_bytes": self.restored_bytes,
        }
