"""KV migration subsystem: zero-recompute deployment switches (paper S4.2).

When a deployment switch tears a replica down, its in-flight sequences used
to resume by *re-prefilling* ``prompt + generated`` on the destination — a
stall that grows with context length, exactly what the paper's migration
design avoids.  This module routes every migrated sequence through the
cheapest restore path available, in order:

  1. **Page handoff** (same ``BlockPool``): the sequence's KV pages do not
     move at all — block ownership re-registers from the source replica's
     cache view to the destination's (allocator accounting + one block-table
     row scatter), and the destination resumes decoding with ZERO tokens
     recomputed.  Because ``ClusterRuntime`` partitions one shared device
     pool across all replicas, this is the common case for every in-cluster
     switch.
  2. **Device page copy / relayout / reshard** (different pools): a jitted
     gather/scatter moves the pages between pools (``kvcache.copy_blocks``),
     falling back to a dense gather + re-chunked scatter when the page
     geometry differs (``kvcache.relayout_blocks``), or — when the pools
     live on *different replica meshes / head shardings* (sharded
     ``ClusterRuntime``, per-replica (tp, pp) sub-meshes) — to
     ``kvcache.reshard_blocks``, which adds an explicit cross-mesh
     ``device_put`` hop and a KV-head slice/pad between head-padded
     configs.  Still zero tokens recomputed — only bytes move; all three
     count as ``copied``/``pages_copied`` in the report.
  3. **Re-prefill** (no pages, or the destination cannot hold them): the
     token-state fallback inherited from the previous design; with chunked
     prefill enabled on the destination engine the recompute interleaves
     with its decode batch instead of stalling it.

All three paths are token-for-token identical to an uninterrupted run under
greedy decoding; they differ only in stall and bytes moved — measured in
``benchmarks/bench_switch.py`` and costed analytically by
``core.switching.plan_kv_migration``.

The same ladder is a *steady-state* scheduling action, not just a switch /
crash-recovery mechanism: the cluster's live rebalancer (see the policy
section in ``serving.cluster``) calls ``migrate_batch`` with single-request
snapshots from ``ServingEngine.export_request`` every tick it moves work —
straggler drains, hot-spot relief, and priority preemption all ride the
identical handoff > copy > re-prefill cost ordering, so a mid-span move is
exactly as cheap as a switch-time one.
"""
from __future__ import annotations

import dataclasses

from repro.serving.engine import InflightSnapshot, ServingEngine


@dataclasses.dataclass
class MigrationReport:
    """What one migration batch did, by restore path."""
    handoff: int = 0            # same-pool ownership transfers (0 bytes)
    copied: int = 0             # cross-pool device page copies
    reprefilled: int = 0        # re-prefill fallback (tokens recomputed)
    requeued: int = 0           # never-admitted requests, plain re-submit
    pages_handoff: int = 0      # pages transferred by accounting only
    pages_copied: int = 0       # pages physically moved between pools
    recompute_tokens: int = 0   # context tokens the fallback re-prefills
    # failure recovery only: requests no survivor could hold, released and
    # shed instead of wedging the cluster (never set by planned switches,
    # whose stranding pre-check runs before any engine is touched)
    dropped: int = 0
    # per-request restore path: rid -> (path, pages-or-recompute-tokens)
    # where path in {"handoff", "copy", "reprefill", "requeue"}; telemetry
    # joins this with src/dst replica indices for per-request trace flows
    paths: dict = dataclasses.field(default_factory=dict)

    @property
    def migrated(self) -> int:
        """In-flight (mid-generation) sequences moved, any path."""
        return self.handoff + self.copied + self.reprefilled

    def merge(self, other: "MigrationReport") -> None:
        for f in dataclasses.fields(self):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if isinstance(a, dict):
                a.update(b)
            else:
                setattr(self, f.name, a + b)


def release_snapshot_pages(snap: InflightSnapshot) -> None:
    """Return a snapshot's held pages to their pool's allocator.

    Disowned pages belong to nobody's cache view, so this is pure allocator
    bookkeeping.  Idempotent: the page fields are cleared.

    This is a *decref*, not a free: pages the sequence attached from the
    prefix cache (``serving.prefixcache``) are also referenced by the
    cache's index (and possibly by other live sequences), so releasing a
    dead replica's snapshot must never recycle a shared page out from
    under a survivor — ``BlockAllocator.release`` only returns a block to
    the free list when its refcount reaches zero.
    """
    if snap.blocks is not None and snap.pool is not None:
        snap.pool.allocator.release(snap.blocks)
    snap.blocks = None
    snap.pool = None
    snap.ssm = None
    snap.conv = None


def migrate_batch(dst: ServingEngine, snaps: list[InflightSnapshot]
                  ) -> MigrationReport:
    """Restore a batch of exported requests on ``dst``, cheapest path first.

    Page-bearing snapshots go through ``import_by_pages`` (handoff or device
    copy); whatever the destination cannot hold by pages — plus queued
    requests that never had pages — falls back to ``import_inflight``
    (re-prefill), batched so same-length contexts share one forward pass at
    admission.  Every held page ends owned by ``dst`` or released here.
    """
    report = MigrationReport()
    paged = [s for s in snaps if s.blocks is not None and s.generated]
    rest = [s for s in snaps if not (s.blocks is not None and s.generated)]
    # capture per-snapshot path info before adoption clears the page fields
    meta = {id(s): (s.pool is dst.cache.pool, len(s.blocks)) for s in paged}
    rejected = dst.import_by_pages(paged)
    rejected_ids = {id(s) for s in rejected}
    for s in paged:
        if id(s) in rejected_ids:
            continue
        same_pool, n = meta[id(s)]
        if same_pool:
            report.handoff += 1
            report.pages_handoff += n
            report.paths[s.rid] = ("handoff", n)
        else:
            report.copied += 1
            report.pages_copied += n
            report.paths[s.rid] = ("copy", n)
    fallback = rejected + rest
    for s in fallback:
        release_snapshot_pages(s)
        if s.generated:
            report.reprefilled += 1
            tokens = len(s.prompt) + len(s.generated)
            report.recompute_tokens += tokens
            report.paths[s.rid] = ("reprefill", tokens)
        else:
            report.requeued += 1
            report.paths[s.rid] = ("requeue", 0)
    if fallback:
        dst.import_inflight(fallback)
    return report
