"""Request routing policies behind one interface.

``FlowRouter`` realizes the lower-level assignment x[k][j]: per workload type
it routes by largest-deficit (deterministic low-discrepancy realization of the
fractional solution).  Baselines: round-robin (DeepSpeed-MII), least-loaded
(Llumnix-style), KV/load-aware (Dynamo-style).

Every policy implements the same entry points, so ``ClusterRuntime`` and the
baselines swap routers without isinstance checks:

  * ``route(type_id, up)`` — pick a replica for one typed request;``up`` is
    an optional boolean mask of replicas currently admitting.
  * ``update_loads(loads)`` — inject current per-replica load (a no-op for
    policies that don't use it; ``LeastLoadedRouter`` stores it).
  * ``reconfigure(fractions)`` — adopt a new span plan's [k][j] assignment
    (policies that ignore fractions just resize to the new replica count).
"""
from __future__ import annotations

import numpy as np


class Router:
    """Shared interface; subclasses override ``route`` (and what they need)."""

    def route(self, type_id: int, up: np.ndarray | None = None) -> int:
        raise NotImplementedError

    def update_loads(self, loads) -> None:
        """Per-replica load snapshot; ignored unless the policy is load-aware."""

    def reconfigure(self, fractions) -> None:
        """Adopt a new span plan ([k][j] fractions; shape fixes replica count)."""


class FlowRouter(Router):
    def __init__(self, fractions: list[list[float]]):
        """fractions[k][j]: share of type-j traffic for replica k."""
        self.f = np.asarray(fractions, dtype=np.float64)
        self.sent = np.zeros_like(self.f)
        self.seen = np.zeros(self.f.shape[1])

    def update(self, fractions: list[list[float]]) -> None:
        """Adopt a new span's fractions.  Deficit state always resets: the
        assignment is per-span, so traffic routed under the old fractions
        must not be 'corrected' retroactively under the new ones."""
        f = np.asarray(fractions, dtype=np.float64)
        self.f = f
        self.sent = np.zeros_like(f)
        self.seen = np.zeros(f.shape[1])

    reconfigure = update

    def route(self, type_id: int, up: np.ndarray | None = None) -> int:
        """Pick the replica with the largest routing deficit for this type."""
        j = type_id
        self.seen[j] += 1
        deficit = self.f[:, j] * self.seen[j] - self.sent[:, j]
        if up is not None:
            deficit = np.where(up, deficit, -np.inf)
        k = int(np.argmax(deficit))
        self.sent[k, j] += 1
        return k


class RoundRobinRouter(Router):
    def __init__(self, n_replicas: int):
        self.n = n_replicas
        self.i = 0

    def update(self, n_replicas: int) -> None:
        self.n = n_replicas
        self.i = 0

    def reconfigure(self, fractions) -> None:
        self.update(len(fractions))

    def route(self, type_id: int, up=None) -> int:
        for _ in range(self.n):
            k = self.i % self.n
            self.i += 1
            if up is None or up[k]:
                return k
        return 0


class LeastLoadedRouter(Router):
    """Route to the replica with the lowest normalized load (queue + running
    work / capacity weight).  Loads are injected via ``update_loads`` before
    each decision (the cluster runtime does this from ``load_stats``)."""

    def __init__(self, n_replicas: int = 0):
        self.loads = np.zeros(n_replicas, dtype=np.float64)

    def update_loads(self, loads) -> None:
        self.loads = np.asarray(loads, dtype=np.float64)

    def reconfigure(self, fractions) -> None:
        self.loads = np.zeros(len(fractions), dtype=np.float64)

    def route(self, type_id: int, up=None) -> int:
        return self.route_from_loads(self.loads, up)

    def route_from_loads(self, loads: np.ndarray, up=None) -> int:
        loads = np.asarray(loads, dtype=np.float64)
        if up is not None:
            loads = np.where(up, loads, np.inf)
        return int(np.argmin(loads))
