"""Request routing policies.

``FlowRouter`` realizes the lower-level assignment x[k][j]: per workload type
it routes by largest-deficit (deterministic low-discrepancy realization of the
fractional solution).  Baselines: round-robin (DeepSpeed-MII), least-loaded
(Llumnix-style), KV/load-aware (Dynamo-style).
"""
from __future__ import annotations

import numpy as np


class FlowRouter:
    def __init__(self, fractions: list[list[float]]):
        """fractions[k][j]: share of type-j traffic for replica k."""
        self.f = np.asarray(fractions, dtype=np.float64)
        self.sent = np.zeros_like(self.f)
        self.seen = np.zeros(self.f.shape[1])

    def update(self, fractions: list[list[float]]) -> None:
        f = np.asarray(fractions, dtype=np.float64)
        if f.shape != self.f.shape:
            self.sent = np.zeros_like(f)
            self.seen = np.zeros(f.shape[1])
        self.f = f

    def route(self, type_id: int, up: np.ndarray | None = None) -> int:
        """Pick the replica with the largest routing deficit for this type."""
        j = type_id
        self.seen[j] += 1
        deficit = self.f[:, j] * self.seen[j] - self.sent[:, j]
        if up is not None:
            deficit = np.where(up, deficit, -np.inf)
        k = int(np.argmax(deficit))
        self.sent[k, j] += 1
        return k


class RoundRobinRouter:
    def __init__(self, n_replicas: int):
        self.n = n_replicas
        self.i = 0

    def update(self, n_replicas: int) -> None:
        self.n = n_replicas
        self.i = 0

    def route(self, type_id: int, up=None) -> int:
        for _ in range(self.n):
            k = self.i % self.n
            self.i += 1
            if up is None or up[k]:
                return k
        return 0


class LeastLoadedRouter:
    """Route to the replica with the lowest normalized load (queue + running
    work / capacity weight).  `loads` supplied by the caller each decision."""

    def route_from_loads(self, loads: np.ndarray, up=None) -> int:
        loads = np.asarray(loads, dtype=np.float64)
        if up is not None:
            loads = np.where(up, loads, np.inf)
        return int(np.argmin(loads))
