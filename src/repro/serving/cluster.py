"""ClusterRuntime: execute orchestrator span plans on real serving engines.

``docs/architecture.md`` is the narrative guide — the request lifecycle
end to end, the migration ladder and who reuses it, and the failure
model; ``docs/telemetry.md`` explains how to read an exported trace.
This docstring keeps the runtime-policy reference detail.

This is the bridge between the analytical OServe stack (``core.orchestrator``
search + switch planning) and real JAX compute (``serving.engine``): a
``SpanPlan``'s heterogeneous deployment is materialized as N live
``ServingEngine`` replicas partitioning one shared device ``BlockPool`` —
a replica's chip count scales its KV-block quota, its concurrency
(``max_seqs``), and its per-sequence context ceiling, so a 1-chip replica
really is a smaller server than a 4-chip one.

Per span, typed requests are routed through any ``Router`` policy
(``FlowRouter`` realizes the plan's x[k][j] fractions), every replica is
stepped round-robin on the host — *asynchronously*: each tick fires every
replica's fused decode dispatch (``engine.step_async``) before syncing any
tokens back (``engine.finish_step``), so the host never blocks on one
replica's device→host token transfer before dispatching the next — the N
transfers and all host-side scheduling overlap the in-flight device work.
(Replicas sharing one ``BlockPool`` chain their fused calls through the
pool arrays, so their device *compute* itself is still serialized by data
dependency; true compute overlap needs disjoint pools/devices.)  With
``decode_horizon > 1`` each dispatch covers up to that many decode steps
(one transfer per horizon; see ``ServingEngine``).  ``finish_span`` feeds
two observations back to the orchestrator:

  * ``observe_health`` — per-replica achieved/expected throughput (tokens
    emitted per busy slot-tick), so a straggling replica's EWMA health
    shrinks its capacity in the next assignment and traffic routes around
    it;
  * ``observe_rates`` — realized per-type arrival counts, an EWMA the
    driver can blend with (or substitute for) the workload predictor.

At a span boundary, ``apply_plan`` executes the deployment switch for real
instead of simulating its cost: replicas whose ``ReplicaConfig`` changed
(per the plan) stop admitting, run a bounded **drain** window so short
sequences finish in place, **export** the rest as snapshots that keep
ownership of their live KV pages, and are rebuilt under the new
configuration; exported requests are re-routed through the new assignment
(batched per destination replica) and restored through the migration
subsystem (``repro.serving.migration``): because every replica is a view of
the one shared ``BlockPool``, in-flight sequences migrate by **page
handoff** — pure ownership re-registration, zero tokens recomputed, no data
movement — with device page copy and re-prefill as progressively costlier
fallbacks.  Every path is token-for-token identical to an uninterrupted run
under greedy decoding.  Unchanged replicas keep serving throughout, and
``total_prefill_tokens`` exposes the cluster-wide prefill-forward token
count that the zero-recompute guarantee is asserted against.

``finish_span`` additionally reports the in-flight context lengths to
``Orchestrator.observe_inflight`` so the next ``plan_span`` can price the
KV migration a prospective switch would trigger.

``set_throttle`` injects a straggler (a replica that only steps a fraction
of the ticks) for chaos/regression testing of the health feedback loop.

With ``shard=True`` a replica's (tp, pp) is *executed*, not just modeled:
the runtime carves the device set into one contiguous sub-mesh per replica
(``launch.mesh.make_replica_mesh``), shards each replica's params and paged
KV pool per the serve ``ShardingPlan`` (heads/d_ff/vocab over tp, layers
over pp, KV pools along the KV-head axis), and deployment switches rebuild
meshes.  Replicas then hold per-replica pools — a shared pool cannot span
disjoint meshes — so switch-time migrations ride the cross-pool
``reshard_blocks`` path (dense gather, cross-mesh hop, head-sharded
scatter): bytes move, but still zero tokens recomputed.

Failure model
-------------
See the "Failure model" section of ``docs/architecture.md`` for the
narrative (detect / recover / shed, and why zero emitted tokens are
ever lost).  Implementation anchors: ``ReplicaCrash`` and sync-phase
errors kill a replica outright; transient dispatch errors and admission
``MemoryError``s retry with exponential backoff and escalate after
``max_retries``; stalls are caught by the health loop and the
rebalancer's watchdog.  Recovery rides the migration ladder, falling
back to re-prefill from the host-side **request log** (prompt + every
emitted token, updated at each sync) when device state is untrusted
(``lose_pages`` crashes, or host/device length disagreement).
Unplaceable requests land in ``shed_rids``; dead replicas' chips leave
the planning budget via ``Orchestrator.observe_failures``.

Disaggregated roles
-------------------
When a plan carries ``ReplicaConfig.role`` splits (``prefill`` /
``decode``; see ``docs/architecture.md`` for the why), the runtime:
routes new requests to ``prefill``/``mixed`` replicas and decode-phase
work to ``decode``/``mixed`` ones (``_route`` / ``_pick_dst`` /
``_resume_evicted`` all narrow by role but *relax* when no compatible
replica is live — roles are a preference, not a law); sizes decode
replicas for residency (bigger quota and ``max_seqs`` over the same
shared pool — reservations still bound true usage); and every tick
(``_handoff_post``) exports each prefill-role replica's
first-token-ready requests *keeping their pages* and adopts them on a
decode replica via the same-pool handoff — zero bytes, zero recompute.
Handoffs are counted per span (``SpanReport.handoffs`` /
``SpanReport.handoff``) and per engine (``handoff_in``/``handoff_out``
in ``load_stats``); prefill-replica health is measured as
progress-per-work-tick liveness, since token throughput would
under-measure a replica whose sequences leave at first token.

Rebalancing and preemption policy
---------------------------------
With ``rebalance=`` set (a ``RebalanceConfig``, or ``True`` for defaults)
the same migration ladder becomes a *continuously available* scheduling
action instead of a switch/crash-only mechanism (Llumnix-style live
rescheduling).  Every tick, under a per-tick move budget
(``max_moves_per_tick``), the runtime may:

  * **Straggler escape** — a step-loop watchdog counts consecutive ticks
    a replica had work but made no progress (a chaos ``stall``/``slow``,
    a real frozen device).  At ``watchdog_ticks`` the replica is marked
    *degraded*: admission pauses, routing masks it out, and its requests
    drain onto survivors through the cheapest migration path — this runs
    in the async dispatch→sync *overlap window*, which is safe precisely
    because a zero-progress replica has no in-flight dispatch to race
    with.  Only after ``escalate_ticks`` of sustained degradation (the
    drain has had its chance) does the watchdog escalate to
    ``fail_replica`` — a hang becomes graceful degradation, not a
    ``ClusterHangError``.  A degraded replica that dispatches again
    (e.g. the stall window ended) is immediately un-degraded and resumes
    admitting.
  * **Hot-spot relief** — replicas whose queue depth reaches
    ``hot_queue`` or whose free-page fraction falls below
    ``hot_kv_frac`` shed load: queued never-prefilled requests move
    first (a free requeue), then the cheapest resident sequence
    (smallest context) rides a page handoff to the least-loaded live
    replica at or below ``cold_load``.
  * **Priority preemption** — when a high-priority request is queued on
    a replica that cannot admit it, the cost ladder is *relocation >
    eviction > shedding*: the cheapest lower-priority resident victim is
    first migrated to a survivor (zero recompute); failing that it is
    evicted — exported to the host request log, pages freed, resumed
    later by re-prefill on whichever replica has genuine room (zero
    emitted tokens lost); only when neither is possible does anything
    shed.  ``Request.priority`` plumbs through ``submit`` on engine and
    cluster; admission itself is priority-ordered inside the engine.

The two control loops are kept from fighting: every span,
``finish_span`` reports the rebalancer's move count to
``Orchestrator.observe_rebalance``, whose churn EWMA *raises* the
switch-hysteresis bar (exactly as a pending KV-migration stall does) —
a cluster the rebalancer is actively reshaping demands a bigger
predicted win before the planner reshapes it again.  The standing bar
holds on every rebalance path: greedy token parity with an unperturbed
run, zero emitted tokens lost, and zero recompute on handoff-path
moves (``total_prefill_tokens`` is asserted against in tests).

Switch transaction
------------------
``apply_plan`` is transactional (prepare → commit, with rollback).
PREPARE builds every new engine before any live engine is touched, so a
build failure aborts with zero impact.  Then the old replicas drain and
export their in-flight requests *keeping their KV pages*.  COMMIT
installs the new engines, re-routes, and restores the exported requests
per destination.  If a migration fails mid-commit, ROLLBACK re-exports
whatever already landed on new engines (another free page handoff),
rebuilds the old configuration, restores every request onto its origin
replica, and reverts the router and orchestrator state — the switch
reports ``rolled_back=True`` instead of raising, and serving continues
on the old deployment.

Telemetry
---------
Pass ``telemetry=`` (a ``serving.telemetry.Telemetry`` bundle) and the
whole stack instruments itself: every engine is built with the bundle
and its replica index as ``trace_id``, the orchestrator's ``audit``
attribute is pointed at the bundle's ``DecisionAudit`` (joined with the
realized ``SpanReport`` by ``finish_span``), and the cluster emits the
events engines cannot see: ``migrate`` / ``handoff`` (per request, with
src/dst replica and restore path), ``crash`` / ``recovered`` (with the
recovery stall), terminal ``finish_log`` / ``shed`` for requests the
cluster finishes or drops outside any engine, and ``switch_prepare`` /
``switch_commit`` / ``switch_rollback`` begin/end pairs, plus the
``switch_stall_s`` / ``recovery_stall_s`` histograms.  The event schema
lives in ``serving.telemetry``; how to read an exported trace —
tracks, residency slices, flow arrows, the one-terminal-event
invariant, a worked example — is ``docs/telemetry.md``.

``load_stats()`` returns one dict per replica: the engine's FROZEN
``LOAD_STATS_KEYS`` schema (see ``serving.engine``'s docstring table)
plus the cluster-level ``dead`` flag (replica masked out of routing /
stepping until rebuilt).  ``tests/test_telemetry.py`` asserts the exact
key set.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ReplicaConfig
from repro.launch.mesh import make_replica_mesh
from repro.launch.sharding import make_plan, pad_attention_params
from repro.models.config import ModelConfig
from repro.serving.engine import (EngineRequest, InflightSnapshot,
                                  ServingEngine, head_pad_for,
                                  resolve_attn_impl)
from repro.serving.faults import (FaultError, FaultPlan, InjectedOOM,
                                  ReplicaCrash, TransientDispatchError,
                                  error_for)
from repro.serving.kvcache import BlockPool
from repro.serving.migration import (MigrationReport, migrate_batch,
                                     release_snapshot_pages)
from repro.serving.router import FlowRouter, Router
from repro.serving.telemetry import NULL_TELEMETRY


class ClusterHangError(RuntimeError):
    """``run_until_idle`` exhausted its tick budget with requests still
    pending — a hang (wedged replica, starved queue) must surface instead
    of masquerading as completion."""


@dataclasses.dataclass
class RebalanceConfig:
    """Knobs for the live rebalancer (see the module docstring's policy
    section).  Pass ``rebalance=True`` to ``ClusterRuntime`` for these
    defaults; ``None`` (the default) disables mid-span rebalancing
    entirely and preserves the pre-rebalancer behavior."""
    max_moves_per_tick: int = 2   # migration budget per cluster tick
    watchdog_ticks: int = 3       # zero-progress ticks before "degraded"
    escalate_ticks: int = 8       # degraded ticks before fail_replica
    hot_queue: int = 1            # queue depth that flags a hot spot
    hot_kv_frac: float = 0.125    # free-page fraction below which = hot
    cold_load: float = 0.75       # max load of a migration destination
    preempt: bool = True          # enable the priority-preemption ladder


@dataclasses.dataclass
class ReplicaHandle:
    """One live replica: its plan config, engine, and span counters."""
    index: int
    rc: ReplicaConfig
    engine: ServingEngine
    # health accounting (reset each span)
    slot_ticks: int = 0         # sum over ticks of busy slots (expected work)
    emitted_span: int = 0       # tokens actually emitted this span
    completed_span: int = 0     # requests this replica finished this span
    shed_mark: int = 0          # len(engine.shed_rids) at span start
    # straggler injection: step only every `period`-th tick
    period: int = 1
    # failure state: a dead handle stays in ``replicas`` (router indices
    # must remain stable mid-span) but is masked out of routing/stepping
    # until the next apply_plan rebuilds or drops it
    dead: bool = False
    failures: int = 0           # consecutive dispatch failures (retry budget)
    backoff_until: int = 0      # cluster tick the next retry may happen at
    # watchdog state (rebalancer only): consecutive had-work-no-dispatch
    # ticks, and whether/when the replica was marked degraded
    no_progress: int = 0
    degraded: bool = False
    degraded_tick: int = 0
    # liveness accounting (reset each span): ticks the replica had work,
    # and ticks it actually dispatched.  Token throughput under-measures a
    # prefill-role replica (its sequences leave at first token), so its
    # health is scored on progress/work instead of emitted/slot ticks.
    work_ticks: int = 0
    progress_ticks: int = 0


@dataclasses.dataclass
class SwitchReport:
    """What a deployment switch actually did to live requests."""
    changed: list[int]          # replica indices rebuilt
    drained: int                # requests that finished inside the drain window
    migrated: int               # in-flight requests resumed on a new replica
    requeued: int               # queued (never-admitted) requests re-routed
    # restore-path split of `migrated` (see serving.migration)
    handoff: int = 0            # same-pool page-ownership transfers (0 bytes)
    copied: int = 0             # cross-pool device page copies
    reprefilled: int = 0        # re-prefill fallback
    pages_handoff: int = 0
    pages_copied: int = 0
    recompute_tokens: int = 0   # context tokens the fallback re-prefilled
    dropped: int = 0            # exported requests no replica could hold
    # transactional outcome: when a rebuild/migration failed mid-switch the
    # old deployment was restored and the migration counters above describe
    # the *restore* trip back onto it (``failure`` says what went wrong)
    rolled_back: bool = False
    failure: str = ""

    @property
    def moved(self) -> int:
        return self.migrated + self.requeued


@dataclasses.dataclass
class SpanReport:
    """Observed span outcome (also what gets fed back to the orchestrator)."""
    achieved_fraction: list[float]   # per-replica achieved/expected throughput
    tokens: list[int]                # per-replica tokens emitted
    completed: int                   # requests finished this span
    type_counts: np.ndarray          # realized per-type arrivals [J]
    shed: int = 0                    # requests rejected by SLO (TTFT/TPOT)
    # failure accounting for the span
    dead_replicas: list[int] = dataclasses.field(default_factory=list)
    retries: int = 0                 # transient-failure retries (all replicas)
    recovery: MigrationReport = dataclasses.field(
        default_factory=MigrationReport)   # how dead replicas' requests moved
    # prefix-cache accounting (None / zeros when the cache is disabled)
    prefix_hit_rate: np.ndarray | None = None  # per-type token-weighted [J]
    prefix_hits: int = 0             # admissions that reused >= 1 page
    prefix_misses: int = 0           # admissions with no cached prefix
    prefix_evicted_bytes: int = 0    # device -> host tier, this span
    prefix_restored_bytes: int = 0   # host tier -> device, this span
    # live-rebalancer accounting for the span (zeros when disabled)
    rebalanced: int = 0              # sequences moved mid-span (all paths)
    preempted: int = 0               # lower-priority victims preempted
    rebalance: MigrationReport = dataclasses.field(
        default_factory=MigrationReport)   # path split of the moves
    # disaggregated prefill/decode accounting (zeros when every replica
    # is role "mixed"): first-token-ready contexts handed from prefill to
    # decode replicas, the migration-path split of those hops, and the
    # mean achieved fraction of the span's live replicas per role — the
    # decision audit's evidence for scoring the prefill:decode split
    handoffs: int = 0
    handoff: MigrationReport = dataclasses.field(
        default_factory=MigrationReport)
    role_util: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _RequestLog:
    """Host-side record of one request: prompt + every token the cluster
    has synced back for it.  This is the last-resort recovery source — a
    replica whose device state cannot be trusted (crash with pages lost,
    or a failure between dispatch and sync) rebuilds its requests from
    here by re-prefill, losing zero emitted tokens."""
    prompt: np.ndarray
    max_new_tokens: int
    emitted: list
    ttft_deadline: float | None = None
    tpot_deadline: float | None = None
    priority: int = 0


class ClusterRuntime:
    def __init__(self, cfg: ModelConfig, params, orch=None, *,
                 total_chips: int | None = None, blocks_per_chip: int = 32,
                 seqs_per_chip: int = 2, block_size: int = 16,
                 router: Router | None = None, drain_steps: int = 4,
                 decode_mode: str = "paged", attn_impl: str = "auto",
                 dtype=jnp.float32, seed: int = 0,
                 prefill_chunk_tokens: int | None = None,
                 decode_horizon: int = 1,
                 prefix_cache: bool = False,
                 shard: bool = False, devices=None,
                 faults: FaultPlan | None = None, max_retries: int = 3,
                 telemetry=None,
                 rebalance: "RebalanceConfig | bool | None" = None):
        """Args:
          cfg/params: the (one) model every replica serves — heterogeneity
            is in per-replica capacity, not weights.
          orch: optional ``core.orchestrator.Orchestrator``; when present,
            ``finish_span`` feeds it health + realized rates + in-flight
            context lengths (the migration-cost input for switch planning).
          total_chips: pool sizing when no orchestrator is attached.
          blocks_per_chip / seqs_per_chip: how a replica's chip count maps
            to its KV quota and concurrency.
          drain_steps: switch-time drain window (engine steps) before
            in-flight sequences are exported and migrated.
          prefill_chunk_tokens: chunked-prefill size for every replica
            (None = one-shot prefill; see ``ServingEngine``).
          decode_horizon: max fused decode steps per replica dispatch
            (1 = per-step decode; see ``ServingEngine``).
          prefix_cache: enable content-addressed prefix reuse + the host
            KV tier (``serving.prefixcache``).  With the default shared
            ``BlockPool`` every replica shares ONE index — a prefix
            prefilled anywhere warms the whole cluster and survives
            replica death; sharded runtimes get one cache per replica
            pool.  Per-type hit rates flow back through ``finish_span``
            into ``Orchestrator.observe_prefix_hits``.
          shard: execute each replica's (tp, pp) for real — the device set
            (``devices``, default ``jax.devices()``) is carved into one
            contiguous sub-mesh per replica (``launch.mesh
            .make_replica_mesh``), params/KV pools are sharded per the
            serve ``ShardingPlan``, and deployment switches rebuild meshes.
            Replicas then hold *per-replica* pools (a shared pool cannot
            span disjoint meshes), so in-flight migrations ride the
            cross-pool reshard path (``kvcache.reshard_blocks``) instead of
            the free same-pool page handoff — still zero recompute.
          faults: optional ``serving.faults.FaultPlan`` consulted at each
            injection site (dispatch, admission, switch) — the
            deterministic chaos source; see the module docstring's
            failure-model section for what detection/recovery it drives.
          max_retries: consecutive transient dispatch failures a replica
            may accumulate (retried with exponential backoff) before it is
            declared dead and its requests are recovered onto survivors.
          telemetry: optional ``serving.telemetry.Telemetry`` bundle — see
            the module docstring's telemetry section.  The default is the
            disabled ``NULL_TELEMETRY`` (every emit point is a no-op).
          rebalance: enable the live rebalancer (``RebalanceConfig`` or
            ``True`` for defaults) — mid-span straggler drains, hot-spot
            relief, and priority preemption under a per-tick migration
            budget; see the module docstring's policy section.  ``None``
            (default) keeps migration a switch/crash-only mechanism.
        """
        if total_chips is None:
            if orch is None:
                raise ValueError("need total_chips when no orchestrator")
            total_chips = orch.cluster.chips
        self.cfg = cfg
        self.params = params
        self.orch = orch
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        if orch is not None and self.telemetry.enabled:
            # plan_span decisions audit into the same bundle finish_span
            # joins realized SpanReports into (calibration error)
            orch.audit = self.telemetry.audit
        self.total_chips = total_chips
        self.blocks_per_chip = blocks_per_chip
        self.seqs_per_chip = seqs_per_chip
        self.block_size = block_size
        self.drain_steps = drain_steps
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.decode_horizon = decode_horizon
        self.prefix_cache = prefix_cache
        self.decode_mode = decode_mode
        self.attn_impl, _ = resolve_attn_impl(attn_impl)
        self.dtype = dtype
        self.seed = seed
        self.shard = shard
        self.devices = None
        self._replica_devices: dict[int, tuple] = {}
        # (q_heads, kv_heads) -> head-padded params, reused across switches
        self._padded_params: dict[tuple, object] = {}
        if shard:
            if decode_mode != "paged":
                raise ValueError("shard=True needs decode_mode='paged'")
            self.devices = list(devices if devices is not None
                                else jax.devices())
            self.pool = None    # per-replica pools, one per sub-mesh
        else:
            self.pool = BlockPool(cfg, blocks_per_chip * total_chips,
                                  block_size, dtype,
                                  head_pad_for(self.attn_impl))
        self.router: Router = router if router is not None else FlowRouter(
            [[1.0]])
        self.replicas: list[ReplicaHandle] = []
        self.results: dict[int, EngineRequest] = {}   # rid -> finished request
        self.rid_type: dict[int, int] = {}
        self.rid_owner: dict[int, int] = {}
        self.n_types = 1
        self._tick = 0
        self._span_completed = 0
        self._span_type_counts = np.zeros(1)
        # per-type prefix-cache accounting (token-weighted hit rates)
        self._span_hit_tokens = np.zeros(1)
        self._span_ctx_tokens = np.zeros(1)
        self._prefix_mark = (0, 0, 0, 0)      # hits/misses/evicted/restored
        self.switch_reports: list[SwitchReport] = []
        # prefill-forward tokens of replicas already torn down; together
        # with the live engines' counters this is `total_prefill_tokens`
        self._prefill_tokens_retired = 0
        # shed (TTFT-blown) rejections: rids of torn-down replicas are
        # folded in here at switch time, so a caller can always distinguish
        # a shed request from a still-queued one (it never reaches
        # ``results``)
        self.shed_rids: list[int] = []
        self._span_shed_mark = 0
        # fault tolerance
        self.faults = faults
        self.max_retries = max_retries
        self.request_log: dict[int, _RequestLog] = {}
        self.dead_replicas: list[int] = []    # cluster-lifetime death list
        self.repaired_replicas: list[int] = []  # lifetime repair/rejoin list
        self.lost_chips = 0                   # chips on dead replicas
        # device slices of dead sharded replicas, kept for repair_replica
        self._dead_devices: dict[int, tuple] = {}
        self._span_dead: list[int] = []
        self._span_retries = 0
        self._span_recovery = MigrationReport()
        self._switch_count = 0                # apply_plan ordinal (1-based)
        self._switching = False               # mask injection inside switches
        # last successfully applied plan, for rollback restore
        self._applied_fractions: list | None = None
        # live rebalancer (None = disabled, the pre-rebalancer behavior)
        if rebalance is True:
            rebalance = RebalanceConfig()
        self.rebalance: RebalanceConfig | None = rebalance or None
        self._moves_left = 0                  # per-tick migration budget
        # preemption-evicted requests parked in the host log:
        # rid -> the replica index they were evicted from
        self._evicted: dict[int, int] = {}
        self._span_rebalanced = 0
        self._span_preempted = 0
        self._span_rebalance = MigrationReport()
        # disaggregated prefill→decode handoff accounting for the span
        self._span_handoffs = 0
        self._span_handoff = MigrationReport()

    # -- replica materialization ----------------------------------------------

    def _sizing(self, rc: ReplicaConfig) -> tuple[int, int, int]:
        """chips -> (max_seqs, kv_quota, max_blocks_per_seq)."""
        quota = self.blocks_per_chip * rc.chips
        max_seqs = max(1, self.seqs_per_chip * rc.chips)
        if rc.role == "decode":
            # the KV-residency side of a disaggregated pair: a decode
            # replica holds many concurrent contexts but never prefills,
            # so it carries a bigger quota view and much higher
            # concurrency.  With the shared pool this is safe
            # oversubscription — reservations check the pool's real free
            # blocks as well as the view quota.
            quota *= 2
            max_seqs *= 4
        cfg_cap = self.cfg.max_seq_len // self.block_size
        # a small replica also has a smaller per-sequence context ceiling:
        # one sequence may use at most its replica's whole block quota
        max_bps = max(1, min(cfg_cap, quota))
        return max_seqs, quota, max_bps

    def _build_engine(self, rc: ReplicaConfig, devices=None,
                      index: int = 0) -> ServingEngine:
        max_seqs, quota, max_bps = self._sizing(rc)
        common = dict(
            block_size=self.block_size, max_seqs=max_seqs, dtype=self.dtype,
            greedy=True, seed=self.seed, decode_mode=self.decode_mode,
            attn_impl=self.attn_impl, max_blocks_per_seq=max_bps,
            prefill_chunk_tokens=self.prefill_chunk_tokens,
            decode_horizon=self.decode_horizon,
            prefix_cache=self.prefix_cache,
            telemetry=self.telemetry, trace_id=index, role=rc.role)
        if not self.shard:
            return ServingEngine(self.cfg, self.params, pool=self.pool,
                                 kv_quota=quota, **common)
        # real intra-replica parallelism: a sub-mesh of rc.chips devices,
        # the serve-mode sharding plan for (tp, pp), a private head-sharded
        # pool sized to this replica's quota
        mesh = make_replica_mesh(devices, rc.tp, rc.pp)
        plan, run_cfg = make_plan(self.cfg, "serve", False, 1,
                                  tp=rc.tp, pp=rc.pp)
        params = self.params
        if (run_cfg.n_q_heads != self.cfg.n_q_heads
                or run_cfg.n_kv_heads != self.cfg.n_kv_heads):
            # head padding depends only on the padded head counts: cache it
            # so repeated switches don't re-pad the whole pytree inside the
            # switch window
            key = (run_cfg.n_q_heads, run_cfg.n_kv_heads)
            params = self._padded_params.get(key)
            if params is None:
                params = pad_attention_params(self.params, self.cfg, run_cfg)
                self._padded_params[key] = params
        return ServingEngine(run_cfg, params, num_blocks=quota,
                             mesh=mesh, shard_plan=plan, **common)

    def _carve(self, rcs: list[ReplicaConfig]) -> list[tuple]:
        """Contiguous per-replica device slices, in replica-index order."""
        need = sum(rc.chips for rc in rcs)
        if need > len(self.devices):
            raise ValueError(
                f"deployment needs {need} devices but this runtime has "
                f"{len(self.devices)} (pass devices= or shrink the plan)")
        slices, off = [], 0
        for rc in rcs:
            slices.append(tuple(self.devices[off:off + rc.chips]))
            off += rc.chips
        return slices

    def _make_handle(self, k: int, rc: ReplicaConfig,
                     engine: ServingEngine) -> ReplicaHandle:
        h = ReplicaHandle(k, rc, engine)
        self._wire_faults(h)
        return h

    def _wire_faults(self, h: ReplicaHandle) -> None:
        """Point the engine's admission-site fault hook at the plan (the
        dispatch/switch sites are consulted by the cluster directly)."""
        if self.faults is None:
            return

        def hook(site, h=h):
            if self._switching or h.dead:
                return
            spec = self.faults.admit_fault(self._tick, h.index)
            if spec is not None:
                raise InjectedOOM(
                    f"injected pool-reservation OOM on replica "
                    f"{h.index} (tick {self._tick})")

        h.engine.fault_hook = hook

    @property
    def surviving_chips(self) -> int:
        """Chips still in the planning budget (dead replicas' chips left)."""
        return self.total_chips - self.lost_chips

    @property
    def total_prefill_tokens(self) -> int:
        """Tokens that went through a prefill forward anywhere in the
        cluster's lifetime.  A switch whose migrations all ride the page-
        handoff path leaves this unchanged — asserted in tests."""
        return (self._prefill_tokens_retired
                + sum(h.engine.prefill_tokens for h in self.replicas))

    @property
    def all_shed_rids(self) -> list[int]:
        """Every rid rejected cluster-wide because its TTFT budget was
        already blown while still queued (SLO-aware shedding)."""
        return (self.shed_rids
                + [r for h in self.replicas for r in h.engine.shed_rids])

    @property
    def total_shed(self) -> int:
        return len(self.all_shed_rids)

    # -- span plan execution ----------------------------------------------------

    def apply_plan(self, plan) -> SwitchReport:
        """Materialize a span plan (``SpanPlan`` or anything with
        ``.deployment`` + ``.fractions``); executes the deployment switch on
        live engines when the configuration changed.

        Transactional (see the module docstring): new engines are built
        before any live engine is touched, and a failure mid-commit rolls
        the cluster back onto the old deployment — the returned report says
        ``rolled_back=True`` instead of the switch raising half-done."""
        new_rcs = list(plan.deployment.replicas)
        self.n_types = len(plan.fractions[0]) if plan.fractions else 1
        if len(self._span_type_counts) != self.n_types:
            self._span_type_counts = np.zeros(self.n_types)
            self._span_hit_tokens = np.zeros(self.n_types)
            self._span_ctx_tokens = np.zeros(self.n_types)
        old = self.replicas
        # sharded runtimes carve devices contiguously in replica order, so a
        # replica whose config is unchanged must ALSO keep its device slice
        # (an earlier replica growing/shrinking shifts everyone behind it)
        slices = self._carve(new_rcs) if self.shard else None
        old_devices = dict(self._replica_devices)
        # a dead replica always counts as changed: its engine is gone and
        # must be rebuilt (its requests were already recovered at death)
        changed = [k for k in range(len(new_rcs))
                   if k >= len(old) or old[k].rc != new_rcs[k]
                   or old[k].dead
                   or (self.shard
                       and self._replica_devices.get(k) != slices[k])]
        torn_down = [old[k] for k in changed
                     if k < len(old) and not old[k].dead]
        torn_down += [h for h in old[len(new_rcs):] if not h.dead]

        # 0) fail fast, before touching any engine: every request that may
        #    need migration must fit some replica of the new deployment
        #    (heterogeneous context ceilings), or the switch would strand it
        #    mid-way.  Conservative: requests that would finish in the drain
        #    window are counted too.
        ceilings = []
        for rc in new_rcs:
            _, quota, max_bps = self._sizing(rc)
            ceilings.append(min(max_bps, quota))
        stranded = []
        for h in torn_down:
            reqs = list(h.engine.active.values()) + list(h.engine.waiting)
            for r in reqs:
                ctx = len(r.prompt) + len(r.generated)
                remaining = r.max_new_tokens - len(r.generated)
                need = -(-(ctx + remaining - 1) // self.block_size)
                if all(need > c for c in ceilings):
                    stranded.append(r.rid)
        if stranded:
            raise ValueError(
                f"deployment switch would strand requests {stranded}: no "
                f"replica in the new deployment has a context ceiling large "
                f"enough to resume them; re-plan or drain first (no engine "
                f"state was modified)")

        self._switch_count += 1
        self._switching = True
        try:
            return self._apply_txn(plan, new_rcs, old, slices, old_devices,
                                   changed, torn_down)
        finally:
            self._switching = False

    def _apply_txn(self, plan, new_rcs, old, slices, old_devices, changed,
                   torn_down) -> SwitchReport:
        fault = (self.faults.switch_fault(self._switch_count)
                 if self.faults is not None else None)
        tm = self.telemetry
        reconfiguring = bool(changed) or bool(torn_down)
        t_switch = tm.clock() if (tm.enabled and reconfiguring) else None

        # PREPARE: build every new engine before a single live engine is
        # touched — a build failure aborts with the deployment unchanged
        built: dict[int, ServingEngine] = {}
        if tm.enabled and reconfiguring:
            tm.emit("switch_prepare", phase="begin",
                    span=self._switch_count)
        try:
            if fault is not None and fault.kind == "switch_build":
                raise TransientDispatchError(
                    f"injected engine-build failure "
                    f"(switch {self._switch_count})")
            for k in changed:
                built[k] = self._build_engine(
                    new_rcs[k], slices[k] if self.shard else None, index=k)
        except Exception as e:   # noqa: BLE001 — the abort must never wedge
            if tm.enabled and reconfiguring:
                tm.emit("switch_prepare", phase="end",
                        span=self._switch_count)
            report = SwitchReport([], 0, 0, 0, rolled_back=True,
                                  failure=f"prepare: {e}")
            self._revert_orchestrator()
            self.switch_reports.append(report)
            return report
        if tm.enabled and reconfiguring:
            tm.emit("switch_prepare", phase="end", span=self._switch_count)
            tm.emit("switch_commit", phase="begin", span=self._switch_count)

        # 1) drain window: short in-flight sequences finish on their source
        drained = 0
        migrate: list[InflightSnapshot] = []
        origin: dict[int, ReplicaHandle] = {}     # rid -> source handle
        for h in torn_down:
            h.engine.pause_admission()
            for r in h.engine.drain(self.drain_steps):
                self._record_finish(r, owner=h)
                drained += 1
            # 2) snapshot what's left *keeping the pages*: the sequences'
            #    KV stays resident in the shared pool across the rebuild
            snaps = h.engine.export_inflight(release=False)
            for s in snaps:
                self._log_tokens(s.rid, s.generated)
                origin[s.rid] = h
            migrate.extend(snaps)
            self._prefill_tokens_retired += h.engine.prefill_tokens
            self.shed_rids.extend(h.engine.shed_rids)
            h.engine.release_all()

        # COMMIT: 3) install the new handles and routing
        self.replicas = [
            old[k] if k not in changed and k < len(old)
            else self._make_handle(k, new_rcs[k], built[k])
            for k in range(len(new_rcs))
        ]
        if self.shard:
            self._replica_devices = dict(enumerate(slices))
        self.router.reconfigure(plan.fractions)

        # 4) re-route exported requests through the new assignment, batched
        #    per destination replica, and restore them via the migration
        #    subsystem: same-pool page handoff first (zero recompute), then
        #    device copy, then re-prefill.  Routing is capacity-masked: a
        #    snapshot only goes to a replica whose context ceiling can hold
        #    it (heterogeneous replicas differ here).
        mig = MigrationReport()
        src_idx = {rid: hh.index for rid, hh in origin.items()}
        try:
            by_dest, dropped = self._route_snapshots(migrate)
            mig.dropped += len(dropped)
            groups = sorted(by_dest.items())
            inject = fault is not None and fault.kind == "switch_migrate"
            for gi, (k, group) in enumerate(groups):
                if inject and gi == min(1, len(groups) - 1):
                    raise TransientDispatchError(
                        f"injected migration failure mid-switch "
                        f"(switch {self._switch_count})")
                rep_k = migrate_batch(self.replicas[k].engine, group)
                self._emit_migrations(rep_k, k, src_idx)
                mig.merge(rep_k)
            if inject and not groups:
                # the fault is scheduled by apply_plan ordinal: it must fire
                # even on a switch with nothing to migrate, or a seeded plan
                # would silently skip its rollback scenario
                raise TransientDispatchError(
                    f"injected migration failure mid-switch "
                    f"(switch {self._switch_count})")
        except Exception as e:   # noqa: BLE001 — roll back, never wedge
            if tm.enabled and reconfiguring:
                tm.emit("switch_commit", phase="end",
                        span=self._switch_count)
                tm.emit("switch_rollback", phase="begin",
                        span=self._switch_count)
            try:
                return self._rollback_switch(old, old_devices, torn_down,
                                             origin, migrate, drained, e)
            finally:
                if tm.enabled and reconfiguring:
                    tm.emit("switch_rollback", phase="end",
                            span=self._switch_count)
                    tm.metrics.observe("switch_stall_s",
                                       tm.clock() - t_switch)
        report = SwitchReport(
            changed, drained, mig.migrated, mig.requeued,
            handoff=mig.handoff, copied=mig.copied,
            reprefilled=mig.reprefilled, pages_handoff=mig.pages_handoff,
            pages_copied=mig.pages_copied,
            recompute_tokens=mig.recompute_tokens, dropped=mig.dropped)
        self.switch_reports.append(report)
        self._applied_fractions = [list(row) for row in plan.fractions]
        if tm.enabled and reconfiguring:
            tm.emit("switch_commit", phase="end", span=self._switch_count)
            tm.metrics.observe("switch_stall_s", tm.clock() - t_switch)
        return report

    def _rollback_switch(self, old, old_devices, torn_down, origin,
                         exported, drained, err) -> SwitchReport:
        """Undo a failed commit: pull every request back off the new
        engines (their pages ride another free handoff), rebuild the old
        configuration, and restore each request to its origin replica."""
        # 1) re-export whatever already landed on a new engine; unchanged
        #    replicas (also present in `old`) keep serving untouched
        keep = {id(h) for h in old}
        recovered: list[InflightSnapshot] = []
        for h in self.replicas:
            if id(h) in keep:
                continue
            recovered.extend(h.engine.export_inflight(release=False))
            self._prefill_tokens_retired += h.engine.prefill_tokens
            self.shed_rids.extend(h.engine.shed_rids)
            h.engine.release_all()
        # 2) plus everything never restored: exported snapshots whose rid
        #    did not land on a new engine (adopted snapshots were neutered,
        #    so matching by rid avoids double-restoring them)
        got = {s.rid for s in recovered}
        recovered += [s for s in exported if s.rid not in got]
        # 3) rebuild the torn-down replicas under their OLD configs; the
        #    handles (and their span counters) survive, only engines swap
        for h in torn_down:
            h.engine = self._build_engine(
                h.rc, old_devices.get(h.index) if self.shard else None,
                index=h.index)
            self._wire_faults(h)
        self.replicas = list(old)
        if self.shard:
            self._replica_devices = old_devices
        if self._applied_fractions is not None:
            self.router.reconfigure(self._applied_fractions)
        # 4) hand every request back to the replica it came from (pages
        #    were kept throughout, so the return trip is free again)
        rb = MigrationReport()
        by_origin: dict[int, list[InflightSnapshot]] = {}
        index_map = {h.index: h for h in old}
        tm = self.telemetry
        for s in recovered:
            h = origin.get(s.rid)
            if h is None or h.dead:        # no origin to return to: shed
                release_snapshot_pages(s)
                self.shed_rids.append(s.rid)
                rb.dropped += 1
                if tm.enabled:
                    tm.emit("shed", rid=s.rid, reason="capacity")
                    tm.metrics.count("shed_capacity")
                continue
            by_origin.setdefault(h.index, []).append(s)
            self.rid_owner[s.rid] = h.index
        for k, group in sorted(by_origin.items()):
            rep_k = migrate_batch(index_map[k].engine, group)
            self._emit_migrations(rep_k, k, {})
            rb.merge(rep_k)
        self._revert_orchestrator()
        report = SwitchReport([], drained, rb.migrated, rb.requeued,
                              handoff=rb.handoff, copied=rb.copied,
                              reprefilled=rb.reprefilled,
                              pages_handoff=rb.pages_handoff,
                              pages_copied=rb.pages_copied,
                              recompute_tokens=rb.recompute_tokens,
                              dropped=rb.dropped,
                              rolled_back=True, failure=f"commit: {err}")
        self.switch_reports.append(report)
        return report

    def _emit_migrations(self, rep: MigrationReport, dst: int,
                         src_idx: dict[int, int],
                         kind: str = "migrate") -> None:
        """Telemetry: one ``migrate``/``rebalance``/``handoff`` event per
        restored request (``kind`` distinguishes switch/crash migrations
        from mid-span rebalancer moves and disaggregated prefill→decode
        hops; all render as flow arrows).

        ``src_idx`` maps rid -> source replica index; requests without an
        entry (e.g. a rollback return trip of a request that never left)
        fall back to ``dst`` — the trace exporter overrides the source
        with the request's actually-open residency track anyway."""
        tm = self.telemetry
        if not tm.enabled:
            return
        for rid, (path, pages) in rep.paths.items():
            tm.emit(kind, rid=rid, src=src_idx.get(rid, dst),
                    dst=dst, path=path, pages=pages)
            tm.metrics.count(f"{kind}_{path}")

    def _revert_orchestrator(self) -> None:
        """Point the orchestrator's deployment state back at what the
        cluster actually runs after an aborted/rolled-back switch, so the
        next ``plan_span`` prices switches from reality."""
        if self.orch is not None:
            self.orch.on_switch_rollback(
                tuple(h.rc for h in self.replicas if not h.dead))

    # -- request flow -----------------------------------------------------------

    def _route(self, type_id: int, ctx_len: int, new_tokens: int,
               phase: str = "prefill") -> int:
        """Pick a live, admitting replica whose context ceiling fits the
        request; -1 when no replica can ever serve it (router state
        untouched).

        ``phase`` applies the disaggregated-role gate: new (prefill-phase)
        requests avoid ``decode`` replicas and decode-phase snapshots avoid
        ``prefill`` replicas.  The gate is a preference, not a law — when
        no role-compatible replica is up, the base mask wins, so a prefill
        replica's death can still recover its in-flight requests onto
        whatever survives (degrade, never wedge)."""
        up = np.array([not h.dead and h.engine.admitting
                       and h.engine.fits(ctx_len, new_tokens)
                       for h in self.replicas])
        if not up.any():
            return -1
        avoid = "decode" if phase == "prefill" else "prefill"
        preferred = up & np.array(
            [h.rc.role != avoid for h in self.replicas])
        if preferred.any():
            up = preferred
        if self.faults is not None:
            # injected traffic skew: all submissions pile onto one replica
            # while it is up (the hot spot the rebalancer must relieve)
            b = self.faults.route_bias(self._tick)
            if b is not None and b < len(up) and up[b]:
                return b
        self.router.update_loads(
            [h.engine.load_stats()["load"] for h in self.replicas])
        return self.router.route(type_id, up)

    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int,
               type_id: int = 0, ttft_deadline: float | None = None,
               tpot_deadline: float | None = None,
               priority: int = 0) -> int:
        """Route one typed request to a replica; returns the replica index.

        ``ttft_deadline`` (absolute, engine clock) arms SLO-aware shedding:
        the destination replica rejects the request if the deadline passes
        before its prefill starts.  ``tpot_deadline`` (seconds per output
        token) arms the decode-side counterpart: a request whose average
        token pace blows the budget is shed mid-flight.  Both are counted
        in ``load_stats`` / ``finish_span``.  ``priority`` (higher = more
        important) orders admission on the destination engine and — with
        the rebalancer enabled — lets a queued high-priority request
        preempt lower-priority residents instead of shedding."""
        if not self.replicas:
            raise RuntimeError("no deployment applied yet (call apply_plan)")
        k = self._route(type_id, len(prompt), max_new_tokens)
        if k < 0:
            raise ValueError(
                f"request {rid}: context {len(prompt)} + {max_new_tokens} "
                f"new tokens exceeds every replica's context ceiling")
        self.replicas[k].engine.submit(rid, prompt, max_new_tokens,
                                       ttft_deadline=ttft_deadline,
                                       tpot_deadline=tpot_deadline,
                                       type_id=type_id, priority=priority)
        # book-keep only after the engine accepted the request, so rejected
        # submissions don't pollute the observed-rate feedback
        self.rid_type[rid] = type_id
        if type_id < self.n_types:
            self._span_type_counts[type_id] += 1
        self.rid_owner[rid] = k
        self.request_log[rid] = _RequestLog(
            np.asarray(prompt, np.int32), max_new_tokens, [],
            ttft_deadline=ttft_deadline, tpot_deadline=tpot_deadline,
            priority=priority)
        return k

    def _record_finish(self, r: EngineRequest,
                       owner: ReplicaHandle | None = None) -> None:
        self.results[r.rid] = r
        self._span_completed += 1
        if owner is not None:
            owner.completed_span += 1
        self._log_tokens(r.rid, r.generated)

    # -- request log (last-resort recovery source) ------------------------------

    def _log_tokens(self, rid: int, generated: list) -> None:
        lg = self.request_log.get(rid)
        if lg is not None:
            lg.emitted[:] = list(generated)

    def _sync_log(self, eng: ServingEngine) -> None:
        """Top up the host-side token log after a replica's sync phase: the
        log must always hold every token the cluster has seen, because a
        later untrusted-pages failure rebuilds requests purely from it."""
        for r in eng.active.values():
            self._log_tokens(r.rid, r.generated)

    def _snapshot_from_log(self, rid: int) -> InflightSnapshot:
        lg = self.request_log[rid]
        return InflightSnapshot(rid, lg.prompt, list(lg.emitted),
                                lg.max_new_tokens,
                                deadline=lg.ttft_deadline,
                                tpot=lg.tpot_deadline,
                                priority=lg.priority)

    def step(self) -> list[EngineRequest]:
        """One cluster tick: step every replica that has work (round-robin).

        Dispatch-then-sync: phase 1 fires every replica's fused decode
        (``step_async``) without reading anything back; phase 2 syncs each
        pending token block (``finish_step``) and retires.  The host never
        blocks on replica i's device→host transfer before dispatching
        replica i+1, so the transfers and the host-side scheduling overlap
        the queued device work (shared-pool replicas' device compute still
        chains through the pool arrays — see the module docstring).

        Failure handling (see the module docstring's failure model): a
        ``ReplicaCrash`` at dispatch kills the replica and recovers its
        requests onto survivors; other dispatch errors (transient faults,
        admission OOMs) are retried with exponential backoff up to
        ``max_retries`` consecutive failures; ANY sync-phase error kills
        the replica with its pages untrusted — the host ``seq_lens``
        already advanced at dispatch, so a replica that cannot sync is a
        replica whose device state disagrees with the host — and its
        requests rebuild from the request log.
        """
        self._tick += 1
        finished: list[EngineRequest] = []
        pending = []
        dispatched: set[int] = set()
        had_work: dict[int, bool] = {}
        for h in self.replicas:
            if h.dead:
                continue
            eng = h.engine
            busy = len(eng.active)
            h.slot_ticks += busy          # expected: ~1 token / slot / tick
            work = bool(eng.active or (eng.waiting and eng.admitting))
            had_work[h.index] = work
            if not work:
                continue
            h.work_ticks += 1
            if h.period > 1 and self._tick % h.period:
                continue                  # injected straggler skips this tick
            if (self.faults is not None
                    and self.faults.stalled(self._tick, h.index)):
                continue                  # injected stall: frozen, no error
            if self._tick < h.backoff_until:
                # backing off after a failure: intentional non-progress, so
                # the watchdog must not count it
                had_work[h.index] = False
                continue
            try:
                if self.faults is not None:
                    spec = self.faults.dispatch_fault(self._tick, h.index)
                    if spec is not None:
                        raise error_for(spec)
                pend = eng.step_async()
            except ReplicaCrash as e:
                self._fail(h, e, trust_pages=not e.lose_pages)
                continue
            except (FaultError, MemoryError) as e:
                self._transient(h, e)
                continue
            h.failures = 0
            h.progress_ticks += 1
            dispatched.add(h.index)
            pending.append((h, eng.tokens_out, pend))
        if self.rebalance is not None:
            # the async overlap window: every dispatch is in flight, no
            # sync has read anything back.  Draining a zero-progress
            # replica here is safe — it has no pending dispatch to race
            # with, and imports land in destination slots outside any
            # pending decode's captured batch.
            self._moves_left = self.rebalance.max_moves_per_tick
            self._watchdog(dispatched, had_work)
        for h, t0, pend in pending:
            try:
                done = h.engine.finish_step(pend)
            except (FaultError, MemoryError) as e:
                self._fail(h, e, trust_pages=False)
                continue
            for r in done:
                self._record_finish(r, owner=h)
                finished.append(r)
            h.emitted_span += h.engine.tokens_out - t0
            self._sync_log(h.engine)
        self._handoff_post()
        if self.rebalance is not None:
            self._rebalance_post()
        self._drain_prefix_events()
        return finished

    def _handoff_post(self) -> None:
        """Disaggregated prefill→decode handoff, run post-sync each tick.

        Every live ``prefill``-role replica hands its first-token-ready
        sequences (prefill complete, >= 1 token emitted, output remaining)
        to a ``decode`` replica — ``mixed`` as the fallback — through the
        same export / ``migrate_batch`` machinery switches, recovery and
        the rebalancer use.  With the shared pool this is a pure
        page-ownership transfer (zero tokens recomputed, zero bytes
        moved); sharded runtimes pay the cross-pool copy/reshard, still
        zero recompute.  There is deliberately no per-tick budget: a
        prefill replica's whole point is to clear its slots for the next
        prompt, so throttling handoffs would just rebuild the admission
        bottleneck the role split exists to remove.  A sequence with no
        eligible destination keeps decoding in place until one appears."""
        for h in self.replicas:
            if h.dead or h.degraded or h.rc.role != "prefill":
                continue
            eng = h.engine
            ready = [r for _, r in sorted(eng.active.items())
                     if not r.prefilling and r.generated
                     and r.max_new_tokens - len(r.generated) >= 1]
            for r in ready:
                dst = (self._pick_dst(h, r, roles=("decode",))
                       or self._pick_dst(h, r, roles=("mixed",)))
                if dst is None:
                    continue
                snap = eng.export_request(r.rid, release=False)
                if snap is None:
                    continue
                self._log_tokens(snap.rid, snap.generated)
                rep = migrate_batch(dst.engine, [snap])
                self._emit_migrations(rep, dst.index,
                                      {snap.rid: h.index}, kind="handoff")
                self._span_handoff.merge(rep)
                eng.handoff_out += 1
                dst.engine.handoff_in += 1
                self._span_handoffs += 1
                self.rid_owner[snap.rid] = dst.index

    def _drain_prefix_events(self) -> None:
        """Fold every engine's per-admission cache events into the span's
        per-type token accounting (dead engines included — their events may
        predate the death)."""
        for h in self.replicas:
            ev = h.engine.prefix_events
            if not ev:
                continue
            for rid, cached, ctx in ev:
                j = self.rid_type.get(rid, 0)
                if j < self.n_types:
                    self._span_hit_tokens[j] += cached
                    self._span_ctx_tokens[j] += ctx
            h.engine.prefix_events = []

    def _caches(self) -> list:
        """Distinct ``PrefixCache`` objects behind the live engines (one for
        a shared pool, one per replica when sharded)."""
        seen: dict[int, object] = {}
        for h in self.replicas:
            pc = h.engine.prefix_cache
            if pc is not None:
                seen[id(pc)] = pc
        return list(seen.values())

    @property
    def pending(self) -> int:
        return (sum(len(h.engine.waiting) + len(h.engine.active)
                    for h in self.replicas)
                + len(self._evicted))

    def run_until_idle(self, max_ticks: int = 10_000,
                       strict: bool = True) -> list[EngineRequest]:
        """Step until no request is waiting or active anywhere.

        Raises ``ClusterHangError`` if ``max_ticks`` is exhausted with
        requests still pending — a wedged cluster must surface instead of
        masquerading as completion (``strict=False`` restores the old
        return-what-finished behavior for callers that poll)."""
        finished = []
        ticks = 0
        while self.pending and ticks < max_ticks:
            finished.extend(self.step())
            ticks += 1
        if self.pending and strict:
            stats = [(h.index, len(h.engine.waiting), len(h.engine.active),
                      "dead" if h.dead else "live") for h in self.replicas]
            raise ClusterHangError(
                f"run_until_idle exhausted {max_ticks} ticks with "
                f"{self.pending} requests still pending; per-replica "
                f"(index, waiting, active, state): {stats}")
        return finished

    # -- live rebalancing (mid-span migration / preemption) ----------------------

    def _watchdog(self, dispatched: set, had_work: dict) -> None:
        """Straggler escape, run inside the dispatch→sync overlap window.

        Counts consecutive ticks a replica had work but fired no dispatch
        (an injected ``stall``/``slow``, a real frozen device — backoff
        skips are intentional and excluded).  At ``watchdog_ticks`` the
        replica degrades: admission pauses and its requests drain onto
        survivors under the move budget; a later successful dispatch
        un-degrades it.  After ``escalate_ticks`` of sustained
        degradation the replica is failed for real — the export is safe
        (``trust_pages=True``) because nothing was dispatched during the
        freeze, so host and device state agree."""
        rb = self.rebalance
        tm = self.telemetry
        for h in list(self.replicas):
            if h.dead:
                continue
            if h.index in dispatched:
                h.no_progress = 0
                if h.degraded:
                    # progress again (e.g. the stall window ended): rejoin
                    h.degraded = False
                    h.engine.resume_admission()
                continue
            if h.degraded:
                self._drain_degraded(h)
                if self._tick - h.degraded_tick >= rb.escalate_ticks:
                    self._fail(h, RuntimeError(
                        f"watchdog: replica {h.index} made no progress "
                        f"for {self._tick - h.degraded_tick} ticks after "
                        f"degradation"), trust_pages=True)
                continue
            if not had_work.get(h.index):
                continue
            h.no_progress += 1
            if h.no_progress < rb.watchdog_ticks:
                continue
            h.degraded = True
            h.degraded_tick = self._tick
            h.engine.pause_admission()
            if tm.enabled:
                tm.emit("degraded", replica=h.index, ticks=h.no_progress)
                tm.metrics.count("replica_degraded")
            self._drain_degraded(h)

    def _drain_degraded(self, h: ReplicaHandle) -> None:
        """Best-effort drain of a degraded replica under the move budget.

        Queued requests first (they move for free — token state only),
        then residents (page handoff).  Whatever does not fit a survivor
        this tick is retried next tick, and the escalation path recovers
        any leftovers."""
        eng = h.engine
        for r in list(eng.waiting):
            if self._moves_left <= 0:
                return
            self._move_request(h, r)
        for slot in sorted(eng.active):
            if self._moves_left <= 0:
                return
            r = eng.active.get(slot)
            if r is not None:
                self._move_request(h, r)

    def _pick_dst(self, src_h: ReplicaHandle, r: EngineRequest,
                  max_load: float | None = None,
                  roles: tuple | None = None) -> ReplicaHandle | None:
        """Least-loaded live survivor that can hold ``r`` *right now*:
        free slot + page/quota capacity for page-resident sequences
        (pre-checked so a handoff never degrades into a surprise
        re-prefill), just the context-ceiling fit for queued ones.

        ``roles`` restricts candidates to those replica roles (the
        prefill→decode handoff asks for ``("decode",)`` first); when None,
        the phase-compatibility gate applies — a decode-phase request
        never lands on a ``prefill`` replica and a prefill-phase one never
        lands on a ``decode`` replica."""
        eng = src_h.engine
        ctx = len(r.prompt) + len(r.generated)
        remaining = r.max_new_tokens - len(r.generated)
        if remaining < 1:
            return None
        total = ctx + remaining - 1
        resident = not r.prefilling and r.slot in eng.cache.seq_blocks
        n_blocks = n_shared = 0
        if resident:
            n_blocks = len(eng.cache.seq_blocks[r.slot])
            n_shared = eng.cache.seq_shared.get(r.slot, 0)
        decode_phase = not r.prefilling and bool(r.generated)
        best, best_load = None, None
        for h in self.replicas:
            if h is src_h or h.dead or h.degraded:
                continue
            if roles is not None:
                if h.rc.role not in roles:
                    continue
            elif ((h.rc.role == "decode" and not decode_phase)
                  or (h.rc.role == "prefill" and decode_phase)):
                continue
            e = h.engine
            if not e.admitting or not e.fits(ctx, remaining):
                continue
            if resident:
                if len(e.active) >= e.max_seqs:
                    continue
                if e.cache.pool is eng.cache.pool:
                    if not e.cache.can_adopt(n_blocks, total,
                                             n_shared=n_shared):
                        continue
                elif not e.cache.can_admit(ctx, total_tokens=total):
                    continue
            load = e.load_stats()["load"]
            if max_load is not None and load > max_load:
                continue
            if best_load is None or load < best_load:
                best, best_load = h, load
        return best

    def _move_request(self, src_h: ReplicaHandle, r: EngineRequest,
                      max_load: float | None = None) -> bool:
        """Migrate one request off ``src_h`` through the cheapest path;
        returns True (and spends one budget unit) when it moved."""
        dst = self._pick_dst(src_h, r, max_load=max_load)
        if dst is None:
            return False
        snap = src_h.engine.export_request(r.rid, release=False)
        if snap is None:
            return False
        self._log_tokens(snap.rid, snap.generated)
        rep = migrate_batch(dst.engine, [snap])
        self._emit_migrations(rep, dst.index, {snap.rid: src_h.index},
                              kind="rebalance")
        self._span_rebalance.merge(rep)
        src_h.engine.rebalanced_out += 1
        dst.engine.rebalanced_in += 1
        self._span_rebalanced += 1
        self.rid_owner[snap.rid] = dst.index
        self._moves_left -= 1
        return True

    def _rebalance_post(self) -> None:
        """Post-sync rebalancing, under whatever is left of the tick's
        move budget: resume preemption-evicted requests, relieve hot
        spots, then run the priority-preemption ladder."""
        self._resume_evicted()
        self._relieve_hotspots()
        if self.rebalance.preempt:
            for h in list(self.replicas):
                if self._moves_left <= 0:
                    return
                if not h.dead and not h.degraded:
                    self._preempt(h)

    def _relieve_hotspots(self) -> None:
        """Move load off replicas with deep queues or KV pressure, onto
        survivors at or below ``cold_load``.  Queued never-prefilled
        requests move first (free); else the smallest resident sequence
        rides a page handoff."""
        rb = self.rebalance
        for h in list(self.replicas):
            if self._moves_left <= 0:
                return
            if h.dead or h.degraded:
                continue
            eng = h.engine
            cap = eng.cache.quota or eng.cache.num_blocks
            hot = (len(eng.waiting) >= rb.hot_queue
                   or eng.cache.n_free_blocks / max(cap, 1)
                   < rb.hot_kv_frac)
            if not hot:
                continue
            moved = False
            for r in list(eng.waiting):
                if not r.generated:        # free move: nothing computed yet
                    moved = self._move_request(h, r, max_load=rb.cold_load)
                    if moved:
                        break
            if moved:
                continue
            for r in sorted((r for r in eng.active.values()
                             if not r.prefilling
                             and r.max_new_tokens - len(r.generated) >= 1),
                            key=lambda r: len(r.prompt) + len(r.generated)):
                if self._move_request(h, r, max_load=rb.cold_load):
                    break

    def _preempt(self, h: ReplicaHandle) -> None:
        """Relocation > eviction > shedding, for a queued high-priority
        request its replica cannot admit.

        The cheapest lower-priority resident victim is migrated to a
        survivor if one can hold it; otherwise it is *evicted* — exported
        to the host request log with its pages freed, parked in
        ``_evicted``, and resumed later by re-prefill wherever genuine
        room appears (zero emitted tokens lost).  Only if the ladder
        cannot act does the waiter face ordinary SLO shedding."""
        eng = h.engine
        if not eng.waiting:
            return
        waiter = max(eng.waiting, key=lambda r: r.priority)
        if waiter.priority <= 0:
            return
        ctx = len(waiter.prefill_tokens)
        total = ctx + (waiter.max_new_tokens - len(waiter.generated)) - 1
        if (len(eng.active) < eng.max_seqs
                and eng.cache.can_admit(ctx, total_tokens=total)):
            return                      # admission will take it anyway
        victims = [r for r in eng.active.values()
                   if not r.prefilling and r.priority < waiter.priority
                   and r.max_new_tokens - len(r.generated) >= 1]
        if not victims:
            return
        victim = min(victims, key=lambda r: (r.priority,
                                             len(r.prompt)
                                             + len(r.generated)))
        rid = victim.rid
        if self._move_request(h, victim):
            action = "relocate"
        else:
            snap = eng.export_request(rid, release=True)
            if snap is None:
                return
            self._log_tokens(snap.rid, snap.generated)
            self._evicted[rid] = h.index
            self._moves_left -= 1
            action = "evict"
        eng.preempted += 1
        self._span_preempted += 1
        if self.telemetry.enabled:
            self.telemetry.emit("preempt", rid=rid, replica=h.index,
                                action=action, for_rid=waiter.rid)
            self.telemetry.metrics.count(f"preempt_{action}")

    def _resume_evicted(self) -> None:
        """Re-admit preemption-evicted requests from the host log onto
        whichever replica has genuine room (free slot + pages), least
        loaded first.  A request no survivor can ever fit is shed —
        degrade, never wedge; one that just has to wait stays parked."""
        if not self._evicted:
            return
        tm = self.telemetry
        for rid, src in list(self._evicted.items()):
            if self._moves_left <= 0:
                return
            lg = self.request_log[rid]
            ctx = len(lg.prompt) + len(lg.emitted)
            remaining = lg.max_new_tokens - len(lg.emitted)
            if remaining < 1:        # the log already holds the output
                del self._evicted[rid]
                self._record_finish(EngineRequest(
                    rid, lg.prompt, lg.max_new_tokens,
                    generated=list(lg.emitted), done=True))
                if tm.enabled:
                    tm.emit("finish_log", rid=rid, tokens=len(lg.emitted))
                continue
            ever = [h for h in self.replicas if not h.dead
                    and h.engine.fits(ctx, remaining)]
            if not ever:
                del self._evicted[rid]
                self.shed_rids.append(rid)
                if tm.enabled:
                    tm.emit("shed", rid=rid, reason="capacity")
                    tm.metrics.count("shed_capacity")
                continue
            best, best_load = None, None
            total = ctx + remaining - 1
            # role gate as a preference: a phase-incompatible replica is
            # only used when no compatible one has room (degrade > park)
            avoid = "prefill" if lg.emitted else "decode"
            for relax in (False, True):
                for h in ever:
                    e = h.engine
                    if h.degraded or not e.admitting:
                        continue
                    if not relax and h.rc.role == avoid:
                        continue
                    if (len(e.active) >= e.max_seqs
                            or not e.cache.can_admit(ctx,
                                                     total_tokens=total)):
                        continue
                    load = e.load_stats()["load"]
                    if best_load is None or load < best_load:
                        best, best_load = h, load
                if best is not None:
                    break
            if best is None:
                continue             # no room yet: retry next tick
            snap = self._snapshot_from_log(rid)
            del self._evicted[rid]
            rep = migrate_batch(best.engine, [snap])
            self._emit_migrations(rep, best.index, {rid: src},
                                  kind="rebalance")
            self._span_rebalance.merge(rep)
            best.engine.rebalanced_in += 1
            self._span_rebalanced += 1
            self.rid_owner[rid] = best.index
            self._moves_left -= 1

    # -- failure detection & recovery -------------------------------------------

    def _transient(self, h: ReplicaHandle, err: Exception) -> None:
        """Bounded retry-with-backoff for dispatch-phase failures."""
        h.failures += 1
        self._span_retries += 1
        if h.failures > self.max_retries:
            # escalation: repeated failures == dead.  The failures all hit
            # at dispatch (pre-mutation), so the engine state is consistent
            # and the pages remain trustworthy.
            self._fail(h, err, trust_pages=True)
            return
        h.backoff_until = self._tick + (1 << (h.failures - 1))

    def fail_replica(self, k: int, lose_pages: bool = False,
                     reason: str = "operator kill") -> MigrationReport:
        """Declare replica ``k`` dead (ops/chaos entry point) and recover
        its requests onto survivors; returns what the recovery did."""
        return self._fail(self.replicas[k], RuntimeError(reason),
                          trust_pages=not lose_pages)

    def _fail(self, h: ReplicaHandle, err: Exception,
              trust_pages: bool) -> MigrationReport:
        """Declare a replica dead and recover its requests onto survivors.

        ``trust_pages=True`` (the failure hit before dispatch, so engine
        state is consistent): exported snapshots keep their KV pages and
        survivors adopt them via handoff / copy / reshard — zero tokens
        recomputed.  ``trust_pages=False`` (device state lost or out of
        sync with the host): token snapshots rebuild from the cluster's
        request log and survivors re-prefill — zero emitted tokens lost
        either way.  Requests no survivor can hold are shed, never wedged.
        The dead handle stays in ``replicas`` (masked everywhere) until
        the next ``apply_plan`` rebuilds or drops it.
        """
        if h.dead:
            return MigrationReport()
        tm = self.telemetry
        t_fail = tm.clock() if tm.enabled else 0.0
        if tm.enabled:
            tm.emit("crash", replica=h.index, step=self._tick,
                    exc=type(err).__name__)
            tm.metrics.count("replica_crashes")
        h.dead = True
        self._span_dead.append(h.index)
        self.dead_replicas.append(h.index)
        self.lost_chips += h.rc.chips
        eng = h.engine
        if trust_pages:
            snaps = eng.export_inflight(release=False)
            for s in snaps:
                self._log_tokens(s.rid, s.generated)
        else:
            rids = ([r.rid for r in eng.active.values()]
                    + [r.rid for r in eng.waiting])
            # allocator accounting is host-side and still sound: hand every
            # block back, then rebuild purely from the host token log
            eng.release_all()
            snaps = [self._snapshot_from_log(rid) for rid in rids]
        # fold the dead engine's counters into the cluster totals exactly
        # once (the handle stays visible until the next apply_plan)
        self.shed_rids.extend(eng.shed_rids)
        eng.shed_rids = []
        h.shed_mark = 0
        self._prefill_tokens_retired += eng.prefill_tokens
        eng.prefill_tokens = 0
        eng.pause_admission()
        if self.shard:
            slice_ = self._replica_devices.pop(h.index, ())
            gone = set(slice_)
            if gone:
                # keep the slice around: repair_replica re-admits it (the
                # chaos model fails replicas, not the silicon under them)
                self._dead_devices[h.index] = tuple(slice_)
                self.devices = [d for d in self.devices if d not in gone]
        rep = self._recover(snaps, src=h.index)
        self._span_recovery.merge(rep)
        if tm.enabled:
            stall = tm.clock() - t_fail
            tm.metrics.observe("recovery_stall_s", stall)
            tm.emit("recovered", replica=h.index, n=len(snaps),
                    stall_s=stall)
        return rep

    def repair_replica(self, k: int) -> None:
        """Rebuild dead replica ``k`` under its existing config and re-admit
        its chips to the planning budget (ops/rejoin entry point; the
        inverse of ``_fail``).

        The repaired engine starts empty — its old requests were already
        recovered onto survivors at death — but with the shared-pool prefix
        cache it starts *warm*: the index outlived the engine.  When an
        orchestrator is attached, ``observe_rejoin`` restores the chips to
        its ``ClusterSpec`` and inserts a neutral health entry, so the next
        ``plan_span`` re-solves over the recovered capacity.
        """
        h = self.replicas[k]
        if not h.dead:
            return
        devices = None
        if self.shard:
            devices = self._dead_devices.pop(k, None)
            if devices is None:
                raise ValueError(
                    f"replica {k}: no stashed device slice to rejoin "
                    f"(its devices were never recorded at failure)")
            self.devices.extend(devices)
            self._replica_devices[k] = tuple(devices)
        h.engine = self._build_engine(h.rc, devices, index=k)
        self._wire_faults(h)
        h.dead = False
        h.failures = 0
        h.backoff_until = 0
        h.no_progress = 0
        h.degraded = False
        h.degraded_tick = 0
        h.slot_ticks = h.emitted_span = h.completed_span = 0
        h.work_ticks = h.progress_ticks = 0
        h.shed_mark = 0
        self.lost_chips -= h.rc.chips
        self.repaired_replicas.append(k)
        # a same-span death that was repaired before finish_span must not
        # still shrink the planning budget
        if k in self._span_dead:
            self._span_dead.remove(k)
        if self.orch is not None:
            live = tuple(hh.rc for hh in self.replicas if not hh.dead)
            idx = sum(1 for hh in self.replicas[:k] if not hh.dead)
            self.orch.observe_rejoin(live, self.surviving_chips,
                                     health_index=idx)

    def _recover(self, snaps: list[InflightSnapshot],
                 src: int = -1) -> MigrationReport:
        """Restore a dead replica's requests on survivors, cheapest path
        first (the same migration machinery planned switches use).
        ``src`` labels the originating (dead) replica on trace events."""
        rep = MigrationReport()
        if not snaps:
            return rep
        by_dest, dropped = self._route_snapshots(snaps)
        rep.dropped += len(dropped)
        for k, group in sorted(by_dest.items()):
            rep_k = migrate_batch(self.replicas[k].engine, group)
            self._emit_migrations(rep_k, k, {s.rid: src for s in group})
            rep.merge(rep_k)
        return rep

    def _route_snapshots(self, snaps: list[InflightSnapshot]
                         ) -> tuple[dict[int, list[InflightSnapshot]],
                                    list[int]]:
        """Route exported snapshots to live replicas that can hold them,
        grouped per destination; unplaceable ones are released and shed
        (returned as the dropped rid list) — degrade, never wedge."""
        by_dest: dict[int, list[InflightSnapshot]] = {}
        dropped: list[int] = []
        for s in snaps:
            ctx = len(s.prompt) + len(s.generated)
            remaining = s.max_new_tokens - len(s.generated)
            if remaining < 1:
                # the log already holds the full output: finish it here
                release_snapshot_pages(s)
                self._record_finish(EngineRequest(
                    s.rid, np.asarray(s.prompt, np.int32),
                    s.max_new_tokens, generated=list(s.generated),
                    done=True))
                if self.telemetry.enabled:
                    self.telemetry.emit("finish_log", rid=s.rid,
                                        tokens=len(s.generated))
                continue
            k = self._route(self.rid_type.get(s.rid, 0), ctx, remaining,
                            phase="decode" if s.generated else "prefill")
            if k < 0:
                release_snapshot_pages(s)
                self.shed_rids.append(s.rid)
                dropped.append(s.rid)
                if self.telemetry.enabled:
                    self.telemetry.emit("shed", rid=s.rid,
                                        reason="capacity")
                    self.telemetry.metrics.count("shed_capacity")
                continue
            by_dest.setdefault(k, []).append(s)
            self.rid_owner[s.rid] = k
        return by_dest, dropped

    # -- observation / feedback -------------------------------------------------

    def set_throttle(self, k: int, fraction: float) -> None:
        """Make replica ``k`` a straggler: it steps only ``fraction`` of the
        cluster ticks (chaos injection for the health feedback loop)."""
        self.replicas[k].period = max(1, int(round(1.0 / max(fraction, 1e-6))))

    def load_stats(self) -> list[dict]:
        stats = []
        for h in self.replicas:
            d = h.engine.load_stats()
            d["dead"] = h.dead
            stats.append(d)
        return stats

    def finish_span(self) -> SpanReport:
        """Close the span: report achieved/expected throughput per replica
        and realized per-type rates back to the orchestrator.

        Dead replicas score 0.  A live replica that shed requests this
        span (TTFT or TPOT SLO misses) has its achieved fraction scaled by
        completed/(completed+shed): persistent SLO pressure shrinks the
        capacity the next assignment gives it, the same feedback channel a
        straggler's low token throughput uses.  When replicas died this
        span, their chips leave the planning budget via
        ``Orchestrator.observe_failures`` so the next ``plan_span``
        re-solves over the survivors."""
        achieved = []
        for h in self.replicas:
            if h.dead:
                achieved.append(0.0)
                continue
            if h.rc.role == "prefill":
                # token throughput under-measures a prefill replica (its
                # sequences leave at first token); liveness — did it
                # dispatch whenever it had work — is the honest signal,
                # and still degrades a stalled/straggling one
                base = (1.0 if h.work_ticks == 0
                        else min(1.0, h.progress_ticks / h.work_ticks))
            elif h.slot_ticks == 0:
                base = 1.0               # idle replica: no evidence of harm
            else:
                base = min(1.0, h.emitted_span / h.slot_ticks)
            shed_h = len(h.engine.shed_rids) - h.shed_mark
            if shed_h > 0:
                served = h.completed_span
                base *= served / (served + shed_h)
            achieved.append(base)
        span_shed = self.total_shed - self._span_shed_mark
        self._span_shed_mark = self.total_shed
        # prefix-cache span accounting: token-weighted per-type hit rate
        # (NaN = type saw no admissions, the orchestrator keeps its EWMA)
        # plus span deltas of the monotonic byte/hit counters
        self._drain_prefix_events()
        caches = self._caches()
        hit_rate = None
        d_hits = d_miss = d_evict = d_restore = 0
        if caches:
            with np.errstate(invalid="ignore"):
                hit_rate = self._span_hit_tokens / self._span_ctx_tokens
            totals = (sum(c.hits for c in caches),
                      sum(c.misses for c in caches),
                      sum(c.evicted_bytes for c in caches),
                      sum(c.restored_bytes for c in caches))
            d_hits, d_miss, d_evict, d_restore = (
                t - m for t, m in zip(totals, self._prefix_mark))
            self._prefix_mark = totals
        report = SpanReport(achieved, [h.emitted_span for h in self.replicas],
                            self._span_completed,
                            self._span_type_counts.copy(), shed=span_shed,
                            dead_replicas=list(self._span_dead),
                            retries=self._span_retries,
                            recovery=self._span_recovery,
                            prefix_hit_rate=hit_rate,
                            prefix_hits=d_hits, prefix_misses=d_miss,
                            prefix_evicted_bytes=d_evict,
                            prefix_restored_bytes=d_restore,
                            rebalanced=self._span_rebalanced,
                            preempted=self._span_preempted,
                            rebalance=self._span_rebalance,
                            handoffs=self._span_handoffs,
                            handoff=self._span_handoff,
                            role_util={
                                role: float(np.mean(vals))
                                for role in ("mixed", "prefill", "decode")
                                if (vals := [a for h, a in
                                             zip(self.replicas, achieved)
                                             if not h.dead
                                             and h.rc.role == role])})
        if self.telemetry.enabled:
            # join realized span numbers with the matching plan decision
            # (FIFO) so the audit can score prediction calibration
            self.telemetry.audit.record_realized(report)
        if self.orch is not None:
            self.orch.observe_health(achieved)
            self.orch.observe_rates(self._span_type_counts)
            if hit_rate is not None:
                self.orch.observe_prefix_hits(hit_rate)
            if self._span_dead:
                self.orch.observe_failures(self._span_dead,
                                           self.surviving_chips)
            # what a switch decided *now* would have to migrate; with one
            # shared pool migrations ride the free page-handoff path, while
            # per-replica sharded pools pay the page-movement cost
            lens = [c for h in self.replicas if not h.dead
                    for c in h.engine.inflight_context_lens()]
            self.orch.observe_inflight(lens, shared_pool=not self.shard)
            if self.rebalance is not None:
                # churn feedback: mid-span moves raise the planner's
                # switch-hysteresis bar so the two loops don't fight
                self.orch.observe_rebalance(self._span_rebalanced
                                            + self._span_preempted)
        for h in self.replicas:
            h.slot_ticks = 0
            h.emitted_span = 0
            h.completed_span = 0
            h.work_ticks = 0
            h.progress_ticks = 0
            h.shed_mark = len(h.engine.shed_rids)
        self._span_completed = 0
        self._span_type_counts = np.zeros(self.n_types)
        self._span_hit_tokens = np.zeros(self.n_types)
        self._span_ctx_tokens = np.zeros(self.n_types)
        self._span_dead = []
        self._span_retries = 0
        self._span_recovery = MigrationReport()
        self._span_rebalanced = 0
        self._span_preempted = 0
        self._span_rebalance = MigrationReport()
        self._span_handoffs = 0
        self._span_handoff = MigrationReport()
        return report
