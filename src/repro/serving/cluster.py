"""ClusterRuntime: execute orchestrator span plans on real serving engines.

This is the bridge between the analytical OServe stack (``core.orchestrator``
search + switch planning) and real JAX compute (``serving.engine``): a
``SpanPlan``'s heterogeneous deployment is materialized as N live
``ServingEngine`` replicas partitioning one shared device ``BlockPool`` —
a replica's chip count scales its KV-block quota, its concurrency
(``max_seqs``), and its per-sequence context ceiling, so a 1-chip replica
really is a smaller server than a 4-chip one.

Per span, typed requests are routed through any ``Router`` policy
(``FlowRouter`` realizes the plan's x[k][j] fractions), every replica is
stepped round-robin on the host — *asynchronously*: each tick fires every
replica's fused decode dispatch (``engine.step_async``) before syncing any
tokens back (``engine.finish_step``), so the host never blocks on one
replica's device→host token transfer before dispatching the next — the N
transfers and all host-side scheduling overlap the in-flight device work.
(Replicas sharing one ``BlockPool`` chain their fused calls through the
pool arrays, so their device *compute* itself is still serialized by data
dependency; true compute overlap needs disjoint pools/devices.)  With
``decode_horizon > 1`` each dispatch covers up to that many decode steps
(one transfer per horizon; see ``ServingEngine``).  ``finish_span`` feeds
two observations back to the orchestrator:

  * ``observe_health`` — per-replica achieved/expected throughput (tokens
    emitted per busy slot-tick), so a straggling replica's EWMA health
    shrinks its capacity in the next assignment and traffic routes around
    it;
  * ``observe_rates`` — realized per-type arrival counts, an EWMA the
    driver can blend with (or substitute for) the workload predictor.

At a span boundary, ``apply_plan`` executes the deployment switch for real
instead of simulating its cost: replicas whose ``ReplicaConfig`` changed
(per the plan) stop admitting, run a bounded **drain** window so short
sequences finish in place, **export** the rest as snapshots that keep
ownership of their live KV pages, and are rebuilt under the new
configuration; exported requests are re-routed through the new assignment
(batched per destination replica) and restored through the migration
subsystem (``repro.serving.migration``): because every replica is a view of
the one shared ``BlockPool``, in-flight sequences migrate by **page
handoff** — pure ownership re-registration, zero tokens recomputed, no data
movement — with device page copy and re-prefill as progressively costlier
fallbacks.  Every path is token-for-token identical to an uninterrupted run
under greedy decoding.  Unchanged replicas keep serving throughout, and
``total_prefill_tokens`` exposes the cluster-wide prefill-forward token
count that the zero-recompute guarantee is asserted against.

``finish_span`` additionally reports the in-flight context lengths to
``Orchestrator.observe_inflight`` so the next ``plan_span`` can price the
KV migration a prospective switch would trigger.

``set_throttle`` injects a straggler (a replica that only steps a fraction
of the ticks) for chaos/regression testing of the health feedback loop.

With ``shard=True`` a replica's (tp, pp) is *executed*, not just modeled:
the runtime carves the device set into one contiguous sub-mesh per replica
(``launch.mesh.make_replica_mesh``), shards each replica's params and paged
KV pool per the serve ``ShardingPlan`` (heads/d_ff/vocab over tp, layers
over pp, KV pools along the KV-head axis), and deployment switches rebuild
meshes.  Replicas then hold per-replica pools — a shared pool cannot span
disjoint meshes — so switch-time migrations ride the cross-pool
``reshard_blocks`` path (dense gather, cross-mesh hop, head-sharded
scatter): bytes move, but still zero tokens recomputed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ReplicaConfig
from repro.launch.mesh import make_replica_mesh
from repro.launch.sharding import make_plan, pad_attention_params
from repro.models.config import ModelConfig
from repro.serving.engine import (EngineRequest, InflightSnapshot,
                                  ServingEngine, head_pad_for,
                                  resolve_attn_impl)
from repro.serving.kvcache import BlockPool
from repro.serving.migration import MigrationReport, migrate_batch
from repro.serving.router import FlowRouter, Router


@dataclasses.dataclass
class ReplicaHandle:
    """One live replica: its plan config, engine, and span counters."""
    index: int
    rc: ReplicaConfig
    engine: ServingEngine
    # health accounting (reset each span)
    slot_ticks: int = 0         # sum over ticks of busy slots (expected work)
    emitted_span: int = 0       # tokens actually emitted this span
    # straggler injection: step only every `period`-th tick
    period: int = 1


@dataclasses.dataclass
class SwitchReport:
    """What a deployment switch actually did to live requests."""
    changed: list[int]          # replica indices rebuilt
    drained: int                # requests that finished inside the drain window
    migrated: int               # in-flight requests resumed on a new replica
    requeued: int               # queued (never-admitted) requests re-routed
    # restore-path split of `migrated` (see serving.migration)
    handoff: int = 0            # same-pool page-ownership transfers (0 bytes)
    copied: int = 0             # cross-pool device page copies
    reprefilled: int = 0        # re-prefill fallback
    pages_handoff: int = 0
    pages_copied: int = 0
    recompute_tokens: int = 0   # context tokens the fallback re-prefilled

    @property
    def moved(self) -> int:
        return self.migrated + self.requeued


@dataclasses.dataclass
class SpanReport:
    """Observed span outcome (also what gets fed back to the orchestrator)."""
    achieved_fraction: list[float]   # per-replica achieved/expected throughput
    tokens: list[int]                # per-replica tokens emitted
    completed: int                   # requests finished this span
    type_counts: np.ndarray          # realized per-type arrivals [J]
    shed: int = 0                    # waiting requests rejected (TTFT blown)


class ClusterRuntime:
    def __init__(self, cfg: ModelConfig, params, orch=None, *,
                 total_chips: int | None = None, blocks_per_chip: int = 32,
                 seqs_per_chip: int = 2, block_size: int = 16,
                 router: Router | None = None, drain_steps: int = 4,
                 decode_mode: str = "paged", attn_impl: str = "auto",
                 dtype=jnp.float32, seed: int = 0,
                 prefill_chunk_tokens: int | None = None,
                 decode_horizon: int = 1,
                 shard: bool = False, devices=None):
        """Args:
          cfg/params: the (one) model every replica serves — heterogeneity
            is in per-replica capacity, not weights.
          orch: optional ``core.orchestrator.Orchestrator``; when present,
            ``finish_span`` feeds it health + realized rates + in-flight
            context lengths (the migration-cost input for switch planning).
          total_chips: pool sizing when no orchestrator is attached.
          blocks_per_chip / seqs_per_chip: how a replica's chip count maps
            to its KV quota and concurrency.
          drain_steps: switch-time drain window (engine steps) before
            in-flight sequences are exported and migrated.
          prefill_chunk_tokens: chunked-prefill size for every replica
            (None = one-shot prefill; see ``ServingEngine``).
          decode_horizon: max fused decode steps per replica dispatch
            (1 = per-step decode; see ``ServingEngine``).
          shard: execute each replica's (tp, pp) for real — the device set
            (``devices``, default ``jax.devices()``) is carved into one
            contiguous sub-mesh per replica (``launch.mesh
            .make_replica_mesh``), params/KV pools are sharded per the
            serve ``ShardingPlan``, and deployment switches rebuild meshes.
            Replicas then hold *per-replica* pools (a shared pool cannot
            span disjoint meshes), so in-flight migrations ride the
            cross-pool reshard path (``kvcache.reshard_blocks``) instead of
            the free same-pool page handoff — still zero recompute.
        """
        if total_chips is None:
            if orch is None:
                raise ValueError("need total_chips when no orchestrator")
            total_chips = orch.cluster.chips
        self.cfg = cfg
        self.params = params
        self.orch = orch
        self.total_chips = total_chips
        self.blocks_per_chip = blocks_per_chip
        self.seqs_per_chip = seqs_per_chip
        self.block_size = block_size
        self.drain_steps = drain_steps
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.decode_horizon = decode_horizon
        self.decode_mode = decode_mode
        self.attn_impl, _ = resolve_attn_impl(attn_impl)
        self.dtype = dtype
        self.seed = seed
        self.shard = shard
        self.devices = None
        self._replica_devices: dict[int, tuple] = {}
        # (q_heads, kv_heads) -> head-padded params, reused across switches
        self._padded_params: dict[tuple, object] = {}
        if shard:
            if decode_mode != "paged":
                raise ValueError("shard=True needs decode_mode='paged'")
            self.devices = list(devices if devices is not None
                                else jax.devices())
            self.pool = None    # per-replica pools, one per sub-mesh
        else:
            self.pool = BlockPool(cfg, blocks_per_chip * total_chips,
                                  block_size, dtype,
                                  head_pad_for(self.attn_impl))
        self.router: Router = router if router is not None else FlowRouter(
            [[1.0]])
        self.replicas: list[ReplicaHandle] = []
        self.results: dict[int, EngineRequest] = {}   # rid -> finished request
        self.rid_type: dict[int, int] = {}
        self.rid_owner: dict[int, int] = {}
        self.n_types = 1
        self._tick = 0
        self._span_completed = 0
        self._span_type_counts = np.zeros(1)
        self.switch_reports: list[SwitchReport] = []
        # prefill-forward tokens of replicas already torn down; together
        # with the live engines' counters this is `total_prefill_tokens`
        self._prefill_tokens_retired = 0
        # shed (TTFT-blown) rejections: rids of torn-down replicas are
        # folded in here at switch time, so a caller can always distinguish
        # a shed request from a still-queued one (it never reaches
        # ``results``)
        self.shed_rids: list[int] = []
        self._span_shed_mark = 0

    # -- replica materialization ----------------------------------------------

    def _sizing(self, rc: ReplicaConfig) -> tuple[int, int, int]:
        """chips -> (max_seqs, kv_quota, max_blocks_per_seq)."""
        quota = self.blocks_per_chip * rc.chips
        max_seqs = max(1, self.seqs_per_chip * rc.chips)
        cfg_cap = self.cfg.max_seq_len // self.block_size
        # a small replica also has a smaller per-sequence context ceiling:
        # one sequence may use at most its replica's whole block quota
        max_bps = max(1, min(cfg_cap, quota))
        return max_seqs, quota, max_bps

    def _build_engine(self, rc: ReplicaConfig,
                      devices=None) -> ServingEngine:
        max_seqs, quota, max_bps = self._sizing(rc)
        common = dict(
            block_size=self.block_size, max_seqs=max_seqs, dtype=self.dtype,
            greedy=True, seed=self.seed, decode_mode=self.decode_mode,
            attn_impl=self.attn_impl, max_blocks_per_seq=max_bps,
            prefill_chunk_tokens=self.prefill_chunk_tokens,
            decode_horizon=self.decode_horizon)
        if not self.shard:
            return ServingEngine(self.cfg, self.params, pool=self.pool,
                                 kv_quota=quota, **common)
        # real intra-replica parallelism: a sub-mesh of rc.chips devices,
        # the serve-mode sharding plan for (tp, pp), a private head-sharded
        # pool sized to this replica's quota
        mesh = make_replica_mesh(devices, rc.tp, rc.pp)
        plan, run_cfg = make_plan(self.cfg, "serve", False, 1,
                                  tp=rc.tp, pp=rc.pp)
        params = self.params
        if (run_cfg.n_q_heads != self.cfg.n_q_heads
                or run_cfg.n_kv_heads != self.cfg.n_kv_heads):
            # head padding depends only on the padded head counts: cache it
            # so repeated switches don't re-pad the whole pytree inside the
            # switch window
            key = (run_cfg.n_q_heads, run_cfg.n_kv_heads)
            params = self._padded_params.get(key)
            if params is None:
                params = pad_attention_params(self.params, self.cfg, run_cfg)
                self._padded_params[key] = params
        return ServingEngine(run_cfg, params, num_blocks=quota,
                             mesh=mesh, shard_plan=plan, **common)

    def _carve(self, rcs: list[ReplicaConfig]) -> list[tuple]:
        """Contiguous per-replica device slices, in replica-index order."""
        need = sum(rc.chips for rc in rcs)
        if need > len(self.devices):
            raise ValueError(
                f"deployment needs {need} devices but this runtime has "
                f"{len(self.devices)} (pass devices= or shrink the plan)")
        slices, off = [], 0
        for rc in rcs:
            slices.append(tuple(self.devices[off:off + rc.chips]))
            off += rc.chips
        return slices

    @property
    def total_prefill_tokens(self) -> int:
        """Tokens that went through a prefill forward anywhere in the
        cluster's lifetime.  A switch whose migrations all ride the page-
        handoff path leaves this unchanged — asserted in tests."""
        return (self._prefill_tokens_retired
                + sum(h.engine.prefill_tokens for h in self.replicas))

    @property
    def all_shed_rids(self) -> list[int]:
        """Every rid rejected cluster-wide because its TTFT budget was
        already blown while still queued (SLO-aware shedding)."""
        return (self.shed_rids
                + [r for h in self.replicas for r in h.engine.shed_rids])

    @property
    def total_shed(self) -> int:
        return len(self.all_shed_rids)

    # -- span plan execution ----------------------------------------------------

    def apply_plan(self, plan) -> SwitchReport:
        """Materialize a span plan (``SpanPlan`` or anything with
        ``.deployment`` + ``.fractions``); executes the deployment switch on
        live engines when the configuration changed."""
        new_rcs = list(plan.deployment.replicas)
        self.n_types = len(plan.fractions[0]) if plan.fractions else 1
        if len(self._span_type_counts) != self.n_types:
            self._span_type_counts = np.zeros(self.n_types)
        old = self.replicas
        # sharded runtimes carve devices contiguously in replica order, so a
        # replica whose config is unchanged must ALSO keep its device slice
        # (an earlier replica growing/shrinking shifts everyone behind it)
        slices = self._carve(new_rcs) if self.shard else None
        changed = [k for k in range(len(new_rcs))
                   if k >= len(old) or old[k].rc != new_rcs[k]
                   or (self.shard
                       and self._replica_devices.get(k) != slices[k])]
        torn_down = [old[k] for k in changed if k < len(old)]
        torn_down += old[len(new_rcs):]            # shrink: dropped replicas

        # 0) fail fast, before touching any engine: every request that may
        #    need migration must fit some replica of the new deployment
        #    (heterogeneous context ceilings), or the switch would strand it
        #    mid-way.  Conservative: requests that would finish in the drain
        #    window are counted too.
        ceilings = []
        for rc in new_rcs:
            _, quota, max_bps = self._sizing(rc)
            ceilings.append(min(max_bps, quota))
        stranded = []
        for h in torn_down:
            reqs = list(h.engine.active.values()) + list(h.engine.waiting)
            for r in reqs:
                ctx = len(r.prompt) + len(r.generated)
                remaining = r.max_new_tokens - len(r.generated)
                need = -(-(ctx + remaining - 1) // self.block_size)
                if all(need > c for c in ceilings):
                    stranded.append(r.rid)
        if stranded:
            raise ValueError(
                f"deployment switch would strand requests {stranded}: no "
                f"replica in the new deployment has a context ceiling large "
                f"enough to resume them; re-plan or drain first (no engine "
                f"state was modified)")

        # 1) drain window: short in-flight sequences finish on their source
        drained = 0
        migrate: list[InflightSnapshot] = []
        for h in torn_down:
            h.engine.pause_admission()
            for r in h.engine.drain(self.drain_steps):
                self._record_finish(r)
                drained += 1
            # 2) snapshot what's left *keeping the pages*: the sequences'
            #    KV stays resident in the shared pool across the rebuild
            migrate.extend(h.engine.export_inflight(release=False))
            self._prefill_tokens_retired += h.engine.prefill_tokens
            self.shed_rids.extend(h.engine.shed_rids)
            h.engine.release_all()

        # 3) rebuild changed replicas under the new configuration
        self.replicas = [
            old[k] if k not in changed and k < len(old)
            else ReplicaHandle(k, new_rcs[k], self._build_engine(
                new_rcs[k], slices[k] if self.shard else None))
            for k in range(len(new_rcs))
        ]
        if self.shard:
            self._replica_devices = dict(enumerate(slices))
        self.router.reconfigure(plan.fractions)

        # 4) re-route exported requests through the new assignment, batched
        #    per destination replica, and restore them via the migration
        #    subsystem: same-pool page handoff first (zero recompute), then
        #    device copy, then re-prefill.  Routing is capacity-masked: a
        #    snapshot only goes to a replica whose context ceiling can hold
        #    it (heterogeneous replicas differ here).
        by_dest: dict[int, list[InflightSnapshot]] = {}
        for snap in migrate:
            ctx = len(snap.prompt) + len(snap.generated)
            remaining = snap.max_new_tokens - len(snap.generated)
            k = self._route(self.rid_type.get(snap.rid, 0), ctx, remaining)
            if k < 0:   # unreachable: the pre-check above already validated
                raise RuntimeError(
                    f"request {snap.rid} unplaceable despite pre-check")
            by_dest.setdefault(k, []).append(snap)
            self.rid_owner[snap.rid] = k
        mig = MigrationReport()
        for k, group in sorted(by_dest.items()):
            mig.merge(migrate_batch(self.replicas[k].engine, group))
        report = SwitchReport(
            changed, drained, mig.migrated, mig.requeued,
            handoff=mig.handoff, copied=mig.copied,
            reprefilled=mig.reprefilled, pages_handoff=mig.pages_handoff,
            pages_copied=mig.pages_copied,
            recompute_tokens=mig.recompute_tokens)
        self.switch_reports.append(report)
        return report

    # -- request flow -----------------------------------------------------------

    def _route(self, type_id: int, ctx_len: int, new_tokens: int) -> int:
        """Pick an admitting replica whose context ceiling fits the request;
        -1 when no replica can ever serve it (router state untouched)."""
        up = np.array([h.engine.admitting
                       and h.engine.fits(ctx_len, new_tokens)
                       for h in self.replicas])
        if not up.any():
            return -1
        self.router.update_loads(
            [h.engine.load_stats()["load"] for h in self.replicas])
        return self.router.route(type_id, up)

    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int,
               type_id: int = 0, ttft_deadline: float | None = None) -> int:
        """Route one typed request to a replica; returns the replica index.

        ``ttft_deadline`` (absolute, engine clock) arms SLO-aware shedding:
        the destination replica rejects the request if the deadline passes
        before its prefill starts (counted in ``load_stats`` /
        ``finish_span``)."""
        if not self.replicas:
            raise RuntimeError("no deployment applied yet (call apply_plan)")
        k = self._route(type_id, len(prompt), max_new_tokens)
        if k < 0:
            raise ValueError(
                f"request {rid}: context {len(prompt)} + {max_new_tokens} "
                f"new tokens exceeds every replica's context ceiling")
        self.replicas[k].engine.submit(rid, prompt, max_new_tokens,
                                       ttft_deadline=ttft_deadline)
        # book-keep only after the engine accepted the request, so rejected
        # submissions don't pollute the observed-rate feedback
        self.rid_type[rid] = type_id
        if type_id < self.n_types:
            self._span_type_counts[type_id] += 1
        self.rid_owner[rid] = k
        return k

    def _record_finish(self, r: EngineRequest) -> None:
        self.results[r.rid] = r
        self._span_completed += 1

    def step(self) -> list[EngineRequest]:
        """One cluster tick: step every replica that has work (round-robin).

        Dispatch-then-sync: phase 1 fires every replica's fused decode
        (``step_async``) without reading anything back; phase 2 syncs each
        pending token block (``finish_step``) and retires.  The host never
        blocks on replica i's device→host transfer before dispatching
        replica i+1, so the transfers and the host-side scheduling overlap
        the queued device work (shared-pool replicas' device compute still
        chains through the pool arrays — see the module docstring).
        """
        self._tick += 1
        finished: list[EngineRequest] = []
        pending = []
        for h in self.replicas:
            eng = h.engine
            busy = len(eng.active)
            h.slot_ticks += busy          # expected: ~1 token / slot / tick
            if not (eng.active or (eng.waiting and eng.admitting)):
                continue
            if h.period > 1 and self._tick % h.period:
                continue                  # injected straggler skips this tick
            pending.append((h, eng.tokens_out, eng.step_async()))
        for h, t0, pend in pending:
            for r in h.engine.finish_step(pend):
                self._record_finish(r)
                finished.append(r)
            h.emitted_span += h.engine.tokens_out - t0
        return finished

    @property
    def pending(self) -> int:
        return sum(len(h.engine.waiting) + len(h.engine.active)
                   for h in self.replicas)

    def run_until_idle(self, max_ticks: int = 10_000) -> list[EngineRequest]:
        finished = []
        ticks = 0
        while self.pending and ticks < max_ticks:
            finished.extend(self.step())
            ticks += 1
        return finished

    # -- observation / feedback -------------------------------------------------

    def set_throttle(self, k: int, fraction: float) -> None:
        """Make replica ``k`` a straggler: it steps only ``fraction`` of the
        cluster ticks (chaos injection for the health feedback loop)."""
        self.replicas[k].period = max(1, int(round(1.0 / max(fraction, 1e-6))))

    def load_stats(self) -> list[dict]:
        return [h.engine.load_stats() for h in self.replicas]

    def finish_span(self) -> SpanReport:
        """Close the span: report achieved/expected throughput per replica
        and realized per-type rates back to the orchestrator."""
        achieved = []
        for h in self.replicas:
            if h.slot_ticks == 0:
                achieved.append(1.0)     # idle replica: no evidence of harm
            else:
                achieved.append(min(1.0, h.emitted_span / h.slot_ticks))
        span_shed = self.total_shed - self._span_shed_mark
        self._span_shed_mark = self.total_shed
        report = SpanReport(achieved, [h.emitted_span for h in self.replicas],
                            self._span_completed,
                            self._span_type_counts.copy(), shed=span_shed)
        if self.orch is not None:
            self.orch.observe_health(achieved)
            self.orch.observe_rates(self._span_type_counts)
            # what a switch decided *now* would have to migrate; with one
            # shared pool migrations ride the free page-handoff path, while
            # per-replica sharded pools pay the page-movement cost
            lens = [c for h in self.replicas
                    for c in h.engine.inflight_context_lens()]
            self.orch.observe_inflight(lens, shared_pool=not self.shard)
        for h in self.replicas:
            h.slot_ticks = 0
            h.emitted_span = 0
        self._span_completed = 0
        self._span_type_counts = np.zeros(self.n_types)
        return report
