"""Continuous-batching serving engine (one model replica), real JAX compute.

vLLM-style loop: admit prompts while KV blocks remain, run batched prefill,
then step decode over the active set, emitting one token per sequence per
step; finished sequences free their pages immediately.

The decode step gathers pages into a dense view and reuses the model's
``decode_step`` (exact); the Pallas flash-decode kernel consumes the same
block-table layout directly on TPU (``repro.kernels``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import DecodeCache, decode_step, prefill
from repro.models.config import ModelConfig
from repro.serving.kvcache import PagedKVCache


@dataclasses.dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray           # int32 [S]
    max_new_tokens: int
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, num_blocks: int = 512,
                 block_size: int = 16, max_seqs: int = 8,
                 dtype=jnp.float32, greedy: bool = True, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.cache = PagedKVCache.create(
            cfg, num_blocks, block_size, max_seqs,
            max_blocks_per_seq=cfg.max_seq_len // block_size, dtype=dtype)
        self.max_seqs = max_seqs
        self.dtype = dtype
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.waiting: list[EngineRequest] = []
        self.active: dict[int, EngineRequest] = {}    # slot -> request
        self.steps = 0
        self.tokens_out = 0

        self._prefill = jax.jit(
            lambda p, toks: prefill(p, cfg, tokens=toks))
        self._decode = jax.jit(
            lambda p, toks, cache: decode_step(p, cfg, toks, cache))

    # -- submission ------------------------------------------------------------

    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int) -> None:
        self.waiting.append(EngineRequest(rid, np.asarray(prompt, np.int32),
                                          max_new_tokens))

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_seqs) if s not in self.active]

    # -- scheduling ------------------------------------------------------------

    def _admit(self) -> list[EngineRequest]:
        """Move waiting requests into free slots while KV blocks remain."""
        admitted = []
        free = self._free_slots()
        while self.waiting and free:
            req = self.waiting[0]
            if not self.cache.can_admit(len(req.prompt)):
                break
            self.waiting.pop(0)
            req.slot = free.pop(0)
            self.cache.admit(req.slot, len(req.prompt))
            self.active[req.slot] = req
            admitted.append(req)
        return admitted

    def _run_prefill(self, reqs: list[EngineRequest]) -> None:
        # bucket by prompt length: same-length batches need no padding, so
        # RoPE positions stay exact for every sequence
        by_len: dict[int, list[EngineRequest]] = {}
        for r in reqs:
            by_len.setdefault(len(r.prompt), []).append(r)
        for pl, group in by_len.items():
            toks = np.stack([r.prompt for r in group])
            logits, cache = self._prefill(self.params, jnp.asarray(toks))
            for i, r in enumerate(group):
                if self.cfg.has_attn:
                    self.cache.write_prefill(r.slot, cache.k[:, i],
                                             cache.v[:, i])
                if self.cfg.has_ssm:
                    self.cache.ssm = self.cache.ssm.at[:, r.slot].set(
                        cache.ssm[:, i])
                    self.cache.conv = self.cache.conv.at[:, r.slot].set(
                        cache.conv[:, i])
                tok = self._pick(logits[i:i + 1])[0]
                r.generated.append(int(tok))
                self.tokens_out += 1

    def _pick(self, logits: jax.Array) -> np.ndarray:
        from repro.models.sampling import sample
        if self.greedy:
            return np.asarray(sample(logits, self.cfg, self.key))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(sample(logits, self.cfg, sub, temperature=1.0))

    def _run_decode(self) -> None:
        slots = np.array(sorted(self.active), np.int32)
        B = len(slots)
        lens = self.cache.seq_lens[slots].copy()
        max_len = int(lens.max()) + 1
        last = np.array([self.active[s].generated[-1] for s in slots], np.int32)
        if self.cfg.has_attn:
            k, v, _ = self.cache.gather_dense(slots, max_len)
        else:
            k = v = None
        ssm = self.cache.ssm[:, slots] if self.cache.ssm is not None else None
        conv = self.cache.conv[:, slots] if self.cache.conv is not None else None
        dc = DecodeCache(k=k, v=v, ssm=ssm, conv=conv,
                         pos=jnp.asarray(lens, jnp.int32))
        logits, new_cache = self._decode(self.params, jnp.asarray(last), dc)
        toks = self._pick(logits)
        # persist the new KV token + SSM state back into the pool
        for s in slots:
            self.cache.extend(int(s))
        if self.cfg.has_attn:
            bidx = jnp.arange(B)
            k_new = new_cache.k[:, bidx, jnp.asarray(lens)]   # [L, B, H, D]
            v_new = new_cache.v[:, bidx, jnp.asarray(lens)]
            self.cache.write_token(slots, k_new, v_new, lens)
        if self.cfg.has_ssm:
            self.cache.ssm = self.cache.ssm.at[:, slots].set(new_cache.ssm)
            self.cache.conv = self.cache.conv.at[:, slots].set(new_cache.conv)
        for i, s in enumerate(slots):
            r = self.active[int(s)]
            r.generated.append(int(toks[i]))
            self.tokens_out += 1

    def _retire(self) -> list[EngineRequest]:
        done = []
        for s in list(self.active):
            r = self.active[s]
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                self.cache.release_slot(s)
                del self.active[s]
                done.append(r)
        return done

    # -- main loop ---------------------------------------------------------------

    def step(self) -> list[EngineRequest]:
        """One scheduler iteration; returns requests finished this step."""
        self.steps += 1
        admitted = self._admit()
        if admitted:
            self._run_prefill(admitted)
        elif self.active:
            self._run_decode()
        return self._retire()

    def run_to_completion(self, max_steps: int = 100_000
                          ) -> list[EngineRequest]:
        finished = []
        while (self.waiting or self.active) and self.steps < max_steps:
            finished.extend(self.step())
        return finished
