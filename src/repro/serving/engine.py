"""Continuous-batching serving engine (one model replica), real JAX compute.

vLLM-style loop: admit prompts while KV blocks remain, run batched prefill,
then step decode over the active set, emitting one token per sequence per
step; finished sequences free their pages immediately.  Prefill and decode
interleave within a step, so admissions never starve running sequences.

The decode path is device-resident end to end: one jitted fused step
(``decode_step_paged`` + token scatter + sampling) consumes the paged KV
pool directly through the device block table, with no per-sequence host
syncs (a single [B] token transfer per step).  On TPU the Pallas paged
kernel reads pages in place (gather-free); the CPU/jnp fallback still
gathers the table's pages inside the jit, so its win comes from bucketed
shapes and the removed host round-trips, not memory traffic.  Active
batches are padded to power-of-two buckets and the page count to power-of-
two page buckets, so the number of distinct compilations is
O(log max_seqs * log max_pages) instead of one per (batch, length) shape.
The legacy dense-gather path survives as ``decode_mode="dense"`` for A/B
benchmarking (``benchmarks/bench_engine.py``).

Replica lifecycle API (used by ``repro.serving.cluster.ClusterRuntime`` to
execute orchestrator deployment switches on live engines):

  * ``pause_admission()`` / ``resume_admission()`` — gate ``_admit`` so a
    replica slated for reconfiguration stops taking new work while its
    in-flight sequences keep decoding.
  * ``drain(max_steps)`` — run admission-free steps until the active set
    empties (or the budget runs out), finishing short sequences in place.
  * ``export_inflight()`` — snapshot every in-flight and queued request as
    host-side token state (original prompt + tokens generated so far) and
    release their KV blocks back to the pool.  Token state is the whole
    snapshot: KV pages and SSM state are *recomputed* on the target replica.
  * ``import_inflight(snaps)`` — resume migrated requests by re-prefilling
    ``prompt + generated`` as one context; under greedy decoding the next
    token equals what an uninterrupted engine would have produced, so a
    drain/rebuild/restore cycle is token-for-token transparent.
  * ``load_stats()`` — queue depth / occupancy / block headroom for routers
    and the cluster health loop.

Engines can share one device ``BlockPool`` (``pool=`` + ``kv_quota=``): the
cluster partitions a single allocation across heterogeneous replicas
instead of each replica reserving a max-size cache.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (DecodeCache, PagedDecodeState, decode_step,
                          decode_step_paged, prefill)
from repro.models.config import ModelConfig
from repro.models.sampling import sample
from repro.serving.kvcache import BlockPool, PagedKVCache


def resolve_attn_impl(attn_impl: str) -> tuple[str, bool]:
    """Resolve "auto" to the backend's implementation; returns (impl, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    if attn_impl == "auto":
        attn_impl = "kernel" if on_tpu else "jnp"
    return attn_impl, attn_impl == "kernel" and not on_tpu


def head_pad_for(attn_impl: str) -> int:
    """Pool head_dim padding: the Pallas kernel wants lane-aligned heads."""
    return 128 if attn_impl == "kernel" else 1


@dataclasses.dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray           # int32 [S] — the ORIGINAL prompt, always
    max_new_tokens: int
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # resumed (migrated) requests prefill prompt+generated as one context
    ctx: np.ndarray | None = None

    @property
    def prefill_tokens(self) -> np.ndarray:
        return self.ctx if self.ctx is not None else self.prompt


@dataclasses.dataclass
class InflightSnapshot:
    """Host token state of one request, sufficient to resume it anywhere."""
    rid: int
    prompt: np.ndarray
    generated: list
    max_new_tokens: int


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clipped to cap."""
    return min(cap, 1 << max(0, n - 1).bit_length())


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, num_blocks: int = 512,
                 block_size: int = 16, max_seqs: int = 8,
                 dtype=jnp.float32, greedy: bool = True, seed: int = 0,
                 decode_mode: str = "paged", attn_impl: str = "auto",
                 pool: BlockPool | None = None, kv_quota: int | None = None,
                 max_blocks_per_seq: int | None = None):
        self.cfg = cfg
        self.params = params
        if decode_mode not in ("paged", "dense"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        self.decode_mode = decode_mode
        attn_impl, self._interpret = resolve_attn_impl(attn_impl)
        self._attn_impl = attn_impl
        # the kernel path wants lane-aligned head_dim; pad the pool once at
        # allocation rather than re-padding it every decode step
        head_pad = head_pad_for(attn_impl)
        if max_blocks_per_seq is None:
            max_blocks_per_seq = cfg.max_seq_len // block_size
        if pool is not None:
            if pool.block_size != block_size:
                raise ValueError(
                    f"shared pool block_size {pool.block_size} != engine "
                    f"block_size {block_size}")
            if cfg.has_attn and pool.head_pad % head_pad:
                raise ValueError(
                    f"shared pool head_pad {pool.head_pad} incompatible with "
                    f"attn_impl {attn_impl!r} (needs multiple of {head_pad})")
            self.cache = PagedKVCache.from_pool(
                pool, max_seqs, max_blocks_per_seq, quota=kv_quota)
        else:
            self.cache = PagedKVCache.create(
                cfg, num_blocks, block_size, max_seqs,
                max_blocks_per_seq=max_blocks_per_seq, dtype=dtype,
                head_pad=head_pad)
        self.max_seqs = max_seqs
        self.dtype = dtype
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.waiting: list[EngineRequest] = []
        self.active: dict[int, EngineRequest] = {}    # slot -> request
        self.admitting = True
        self.steps = 0
        self.tokens_out = 0

        self._prefill = jax.jit(
            lambda p, toks: prefill(p, cfg, tokens=toks))
        self._decode = jax.jit(
            lambda p, toks, cache: decode_step(p, cfg, toks, cache))
        self._fused = self._build_fused()

    def _build_fused(self):
        """The jitted device-resident decode step.

        Gathers per-slot metadata/state from the full-size device arrays,
        runs the paged decode, samples, and scatters lens/SSM state back —
        tokens are the only thing that crosses back to the host.
        """
        cfg, greedy = self.cfg, self.greedy
        impl, interp = self._attn_impl, self._interpret
        trash = self.cache.trash_slot

        def fused(params, k, v, table_full, lens_full, ssm_full, conv_full,
                  slots, tokens, key, n_pages):
            table = table_full[slots, :n_pages]
            lens = lens_full[slots]
            ssm = ssm_full[:, slots] if ssm_full is not None else None
            conv = conv_full[:, slots] if conv_full is not None else None
            st = PagedDecodeState(k=k, v=v, block_table=table, lens=lens,
                                  ssm=ssm, conv=conv)
            logits, st = decode_step_paged(params, cfg, tokens, st,
                                           attn_impl=impl, interpret=interp)
            toks = sample(logits, cfg, key,
                          temperature=0.0 if greedy else 1.0)
            lens_full = lens_full.at[slots].add(1).at[trash].set(0)
            if ssm_full is not None:
                ssm_full = ssm_full.at[:, slots].set(st.ssm)
                conv_full = conv_full.at[:, slots].set(st.conv)
            return toks, st.k, st.v, lens_full, ssm_full, conv_full

        # donate the pools/state so XLA updates pages in place (no-op on CPU)
        donate = (1, 2, 4, 5, 6) if jax.default_backend() != "cpu" else ()
        return jax.jit(fused, static_argnames=("n_pages",),
                       donate_argnums=donate)

    # -- submission ------------------------------------------------------------

    @property
    def max_context(self) -> int:
        """Tokens one sequence's block table can address."""
        return self.cache.max_blocks_per_seq * self.cache.block_size

    def _capacity_blocks(self) -> int:
        """Blocks one sequence may ever hold on this replica."""
        cap = min(self.cache.max_blocks_per_seq, self.cache.num_blocks)
        if self.cache.quota is not None:
            cap = min(cap, self.cache.quota)
        return cap

    def fits(self, ctx_len: int, new_tokens: int) -> bool:
        """Can this replica *ever* serve a request of this size?  (Same
        bound ``_validate`` enforces; used by routers to mask out replicas
        whose context ceiling is too small.)"""
        if new_tokens < 1:
            return False
        need = ctx_len + new_tokens - 1
        bs = self.cache.block_size
        return (need + bs - 1) // bs <= self._capacity_blocks()

    def _validate(self, ctx_len: int, new_tokens: int, rid: int) -> None:
        if new_tokens < 1:
            raise ValueError(f"request {rid}: max_new_tokens must be >= 1")
        # the final generated token is returned but never written to a page,
        # so lifetime cache footprint is ctx + new - 1 positions
        if not self.fits(ctx_len, new_tokens):
            need = ctx_len + new_tokens - 1
            raise ValueError(
                f"request {rid}: context {ctx_len} + {new_tokens} new tokens "
                f"needs {need} cache positions but this replica's "
                f"per-sequence block capacity is "
                f"{self._capacity_blocks()} x {self.cache.block_size} tokens")

    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int) -> None:
        prompt = np.asarray(prompt, np.int32)
        self._validate(len(prompt), max_new_tokens, rid)
        self.waiting.append(EngineRequest(rid, prompt, max_new_tokens))

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_seqs) if s not in self.active]

    # -- replica lifecycle (cluster runtime) -----------------------------------

    def pause_admission(self) -> None:
        """Stop moving waiting requests into slots (switch in progress)."""
        self.admitting = False

    def resume_admission(self) -> None:
        self.admitting = True

    def drain(self, max_steps: int | None = None) -> list[EngineRequest]:
        """Run admission-free steps until the active set empties.

        Short in-flight sequences finish in place (the paper's drain path);
        whatever is still running after ``max_steps`` is left for
        ``export_inflight``.  Admission stays paused on return.
        """
        self.pause_admission()
        finished: list[EngineRequest] = []
        steps = 0
        while self.active and (max_steps is None or steps < max_steps):
            finished.extend(self.step())
            steps += 1
        return finished

    def export_inflight(self) -> list[InflightSnapshot]:
        """Snapshot and evict every in-flight + queued request.

        Returns host token state only — prompt and generated tokens — and
        releases the KV blocks.  The target replica resumes each request by
        re-prefilling ``prompt + generated`` (see ``import_inflight``).
        """
        snaps: list[InflightSnapshot] = []
        for slot in sorted(self.active):
            r = self.active.pop(slot)
            self.cache.release_slot(slot)
            snaps.append(InflightSnapshot(r.rid, r.prompt,
                                          list(r.generated),
                                          r.max_new_tokens))
        for r in self.waiting:
            snaps.append(InflightSnapshot(r.rid, r.prompt,
                                          list(r.generated),
                                          r.max_new_tokens))
        self.waiting = []
        return snaps

    def import_inflight(self, snaps: list[InflightSnapshot]) -> None:
        """Resume migrated requests (re-prefill of prompt + generated).

        The resumed context re-computes KV pages / SSM state here, and the
        prefill's final-position logits produce exactly the token a decode
        step on the source replica would have produced next (greedy).
        """
        for s in snaps:
            if not s.generated:          # never prefilled: plain submission
                self.submit(s.rid, s.prompt, s.max_new_tokens)
                continue
            remaining = s.max_new_tokens - len(s.generated)
            if remaining < 1:
                raise ValueError(f"request {s.rid}: nothing left to generate")
            ctx = np.concatenate([np.asarray(s.prompt, np.int32),
                                  np.asarray(s.generated, np.int32)])
            self._validate(len(ctx), remaining, s.rid)
            self.waiting.append(EngineRequest(
                s.rid, np.asarray(s.prompt, np.int32), s.max_new_tokens,
                generated=list(s.generated), ctx=ctx))

    def release_all(self) -> None:
        """Teardown: hand every block back to the (shared) pool."""
        self.active = {}
        self.waiting = []
        self.cache.release_all()

    def load_stats(self) -> dict:
        """Occupancy snapshot for routers / the cluster health loop."""
        return {
            "waiting": len(self.waiting),
            "active": len(self.active),
            "max_seqs": self.max_seqs,
            "free_blocks": self.cache.n_free_blocks,
            "tokens_out": self.tokens_out,
            "steps": self.steps,
            "load": (len(self.waiting) + len(self.active)) / self.max_seqs,
        }

    # -- scheduling ------------------------------------------------------------

    def _admit(self) -> list[EngineRequest]:
        """Move waiting requests into free slots while KV blocks remain."""
        admitted = []
        if not self.admitting:
            return admitted
        free = self._free_slots()
        while self.waiting and free:
            req = self.waiting[0]
            ctx = len(req.prefill_tokens)
            # reserve the sequence's lifetime footprint (prompt + remaining
            # decode growth) so later extends can't exhaust the shared pool
            total = ctx + (req.max_new_tokens - len(req.generated)) - 1
            if not self.cache.can_admit(ctx, total_tokens=total):
                break
            self.waiting.pop(0)
            req.slot = free.pop(0)
            self.cache.admit(req.slot, ctx, total_tokens=total)
            self.active[req.slot] = req
            admitted.append(req)
        return admitted

    def _run_prefill(self, reqs: list[EngineRequest]) -> None:
        # bucket by prompt length: same-length batches need no padding, so
        # RoPE positions stay exact for every sequence
        by_len: dict[int, list[EngineRequest]] = {}
        for r in reqs:
            by_len.setdefault(len(r.prefill_tokens), []).append(r)
        for pl, group in by_len.items():
            toks = np.stack([r.prefill_tokens for r in group])
            logits, cache = self._prefill(self.params, jnp.asarray(toks))
            first = self._pick(logits)           # one sync per prefill group
            for i, r in enumerate(group):
                if self.cfg.has_attn:
                    self.cache.write_prefill(r.slot, cache.k[:, i],
                                             cache.v[:, i])
                if self.cfg.has_ssm:
                    self.cache.ssm = self.cache.ssm.at[:, r.slot].set(
                        cache.ssm[:, i])
                    self.cache.conv = self.cache.conv.at[:, r.slot].set(
                        cache.conv[:, i])
                r.generated.append(int(first[i]))
                self.tokens_out += 1

    def _pick(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(sample(logits, self.cfg, self.key))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(sample(logits, self.cfg, sub, temperature=1.0))

    # -- decode paths ----------------------------------------------------------

    def _run_decode(self, slots: list[int]) -> None:
        """Device-resident paged decode over the given slots (gather-free)."""
        slots = sorted(slots)
        for s in slots:                      # page capacity for the new token
            self.cache.extend(s)
        B = len(slots)
        bucket = _pow2_bucket(B, self.max_seqs)
        trash = self.cache.trash_slot
        pad = bucket - B
        slot_arr = np.array(slots + [trash] * pad, np.int32)
        last = np.array([self.active[s].generated[-1] for s in slots]
                        + [0] * pad, np.int32)
        bs = self.cache.block_size
        need = (int(self.cache.seq_lens[slots].max()) + bs - 1) // bs
        n_pages = _pow2_bucket(need, self.cache.max_blocks_per_seq)
        if self.greedy:
            sub = self.key
        else:
            self.key, sub = jax.random.split(self.key)
        toks, k, v, lens_dev, ssm, conv = self._fused(
            self.params, self.cache.k, self.cache.v,
            self.cache.block_table_dev, self.cache.seq_lens_dev,
            self.cache.ssm, self.cache.conv,
            jnp.asarray(slot_arr), jnp.asarray(last), sub, n_pages=n_pages)
        self.cache.k, self.cache.v = k, v
        self.cache.seq_lens_dev = lens_dev
        self.cache.ssm, self.cache.conv = ssm, conv
        toks = np.asarray(toks)              # the one device->host transfer
        for i, s in enumerate(slots):
            r = self.active[s]
            r.generated.append(int(toks[i]))
            self.tokens_out += 1

    def _run_decode_dense(self, slots: list[int]) -> None:
        """Legacy dense-gather decode (A/B baseline for bench_engine)."""
        slots = np.array(sorted(slots), np.int32)
        B = len(slots)
        lens = self.cache.seq_lens[slots].copy()
        max_len = int(lens.max()) + 1
        last = np.array([self.active[s].generated[-1] for s in slots], np.int32)
        if self.cfg.has_attn:
            k, v, _ = self.cache.gather_dense(slots, max_len)
        else:
            k = v = None
        ssm = self.cache.ssm[:, slots] if self.cache.ssm is not None else None
        conv = self.cache.conv[:, slots] if self.cache.conv is not None else None
        dc = DecodeCache(k=k, v=v, ssm=ssm, conv=conv,
                         pos=jnp.asarray(lens, jnp.int32))
        logits, new_cache = self._decode(self.params, jnp.asarray(last), dc)
        toks = self._pick(logits)
        # persist the new KV token + SSM state back into the pool
        for s in slots:
            self.cache.extend(int(s))
        if self.cfg.has_attn:
            bidx = jnp.arange(B)
            k_new = new_cache.k[:, bidx, jnp.asarray(lens)]   # [L, B, H, D]
            v_new = new_cache.v[:, bidx, jnp.asarray(lens)]
            self.cache.write_token(slots, k_new, v_new, lens)
        if self.cfg.has_ssm:
            self.cache.ssm = self.cache.ssm.at[:, slots].set(new_cache.ssm)
            self.cache.conv = self.cache.conv.at[:, slots].set(new_cache.conv)
        for i, s in enumerate(slots):
            r = self.active[int(s)]
            r.generated.append(int(toks[i]))
            self.tokens_out += 1

    def _retire(self) -> list[EngineRequest]:
        done = []
        for s in list(self.active):
            r = self.active[s]
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                self.cache.release_slot(s)
                del self.active[s]
                done.append(r)
        return done

    # -- main loop ---------------------------------------------------------------

    def step(self) -> list[EngineRequest]:
        """One scheduler iteration; returns requests finished this step.

        Prefill and decode interleave: sequences that were already active
        still emit their decode token on a step that admits new prompts
        (newly admitted requests get their first token from prefill itself).
        """
        self.steps += 1
        decode_slots = list(self.active)
        admitted = self._admit()
        if admitted:
            self._run_prefill(admitted)
        if decode_slots:
            if self.decode_mode == "paged":
                self._run_decode(decode_slots)
            else:
                self._run_decode_dense(decode_slots)
        return self._retire()

    def run_to_completion(self, max_steps: int = 100_000
                          ) -> list[EngineRequest]:
        finished = []
        while (self.waiting or self.active) and self.steps < max_steps:
            finished.extend(self.step())
        return finished
