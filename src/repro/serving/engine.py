"""Continuous-batching serving engine (one model replica), real JAX compute.

vLLM-style loop: admit prompts while KV blocks remain, run batched prefill,
then step decode over the active set, emitting one token per sequence per
step; finished sequences free their pages immediately.  Prefill and decode
interleave within a step, so admissions never starve running sequences.

The decode path is device-resident end to end: one jitted fused call
(``models.decode_loop_paged``) scans up to ``decode_horizon`` decode steps
— paged attention, K/V token scatter, SSM update, sampling with per-step
key folding, and the device ``seq_lens_dev`` advance — against the paged
KV pool through the device block table, returning a ``[B, H]`` token block
with **one device→host transfer per horizon** instead of per token.  On
TPU the Pallas paged kernel reads pages in place (gather-free); the
CPU/jnp fallback still gathers the table's pages inside the jit, so its
win comes from bucketed shapes and the removed host round-trips, not
memory traffic.  Active batches are padded to power-of-two buckets, the
page count to power-of-two page buckets, and the horizon to a power-of-two
*floor* of the safe step count, so the number of distinct compilations is
O(log max_seqs * log max_pages * log decode_horizon) instead of one per
(batch, length, steps) shape.  The legacy dense-gather path survives as
``decode_mode="dense"`` for A/B benchmarking (``benchmarks/
bench_engine.py``).

Horizon contract (``decode_horizon > 1``): the host stays authoritative
for admission, retirement, block ownership, and ``seq_lens`` — before each
dispatch it computes the *safe* horizon ``min(decode_horizon, min
remaining max_new_tokens over the batch)``, collapsed to 1 whenever a
scheduling event must interleave per step (a request was admitted this
step, or a chunked prefill is mid-flight), then rounds it DOWN to a power
of two.  Page capacity for the whole horizon is pre-extended against the
sequence's admission-time lifetime reservation (``kvcache.extend_for``),
so the device loop writes new tokens through the block table with no host
allocation; host ``seq_lens`` advances at dispatch and the device mirror
advances inside the loop, so the two re-converge at every sync.  Under
greedy decoding the token stream is identical for every horizon size; with
sampling, per-step key folding (``sampling.step_key``) keeps it identical
too.  ``decode_horizon=1`` (the default) reproduces the per-step engine
exactly.

Dispatch/sync split: ``step_async()`` runs the host-side scheduling and
*fires* the fused decode without reading it back; ``finish_step(pending)``
performs the one device→host token transfer and retirement.  ``step()``
is the synchronous composition.  ``ClusterRuntime.step`` uses the split to
dispatch every replica's fused call before syncing any of them, so the N
device→host transfers and the host-side scheduling overlap the in-flight
device work instead of interleaving N blocking round-trips (shared-pool
replicas' device compute still chains through the pool arrays).

Replica lifecycle API (used by ``repro.serving.cluster.ClusterRuntime`` to
execute orchestrator deployment switches on live engines):

  * ``pause_admission()`` / ``resume_admission()`` — gate ``_admit`` so a
    replica slated for reconfiguration stops taking new work while its
    in-flight sequences keep decoding.
  * ``drain(max_steps)`` — run admission-free steps until the active set
    empties (or the budget runs out), finishing short sequences in place.
  * ``export_inflight(release=...)`` — snapshot every in-flight and queued
    request.  With ``release=True`` the snapshot is host token state only
    (prompt + generated) and the KV blocks return to the pool; with
    ``release=False`` the snapshot additionally *keeps ownership of the
    live KV pages* (plus SSM state rows), so a destination replica can
    resume the sequence without recomputing anything — see
    ``repro.serving.migration``.
  * ``import_by_pages(snaps)`` — adopt migrated sequences directly from
    their KV pages: a same-pool migration re-registers page ownership
    (zero tokens recomputed, no data movement); a cross-pool one runs the
    jitted page copy / relayout.  Returns the snapshots it could not place.
  * ``import_inflight(snaps)`` — the re-prefill fallback: resume migrated
    requests by re-prefilling ``prompt + generated`` as one context; under
    greedy decoding the next token equals what an uninterrupted engine
    would have produced, so either restore path is token-for-token
    transparent.
  * ``load_stats()`` — queue depth / occupancy / block headroom for routers
    and the cluster health loop.

Chunked prefill (``prefill_chunk_tokens=``): prompts longer than the chunk
size run through ``models.prefill_chunk`` one fixed-size chunk per engine
step, with the prefill->page scatter fused into the chunk forward, so a
long prompt (or a migrated context re-prefilling after a cross-pool switch)
never stalls the replica's decode batch.  ``prefill_tokens`` counts every
token that went through a prefill forward — the zero-recompute guarantee of
page-handoff migration is asserted against it in tests.

Engines can share one device ``BlockPool`` (``pool=`` + ``kv_quota=``): the
cluster partitions a single allocation across heterogeneous replicas
instead of each replica reserving a max-size cache.

Telemetry (``telemetry=``, see ``repro.serving.telemetry``): the engine
emits lifecycle events (submit / admit / prefix_hit / prefill_chunk /
first_token / dispatch / sync / retire / shed) and records TTFT / TPOT /
queue-delay histograms, all at host-side scheduling boundaries — never
inside jitted code.  ``clock=`` overrides the time source (defaulting to
the telemetry bundle's clock, itself ``time.monotonic``), so traces and
TTFT/TPOT deadlines share one injectable clock in tests.  The default
``NULL_TELEMETRY`` is disabled end to end: every emit point is a guarded
no-op, keeping the uninstrumented hot path unchanged.

``load_stats()`` schema — FROZEN: these keys are consumed by
``FlowRouter``, ``ClusterRuntime``'s health loop, and the benchmarks;
``tests/test_telemetry.py`` asserts the exact key set, so additions are
fine but renames/removals are breaking:

=======================  ====================================================
key                      meaning
=======================  ====================================================
waiting                  queued requests not yet admitted
active                   requests holding slots (prefilling or decoding)
max_seqs                 slot capacity of this replica
free_blocks              KV pool blocks free right now (this view's quota)
free_blocks_effective    free + cold prefix-cache pages evictable on demand
tokens_out               total tokens emitted since construction
steps                    scheduler iterations since construction
prefill_tokens           tokens run through a prefill forward (see above)
prefix_hits              admissions that reused >= 1 cached page
prefix_misses            admissions with no cached prefix
prefix_hit_tokens        prompt tokens served from the prefix cache
prefix_evicted_bytes     KV bytes moved device -> host tier
prefix_restored_bytes    KV bytes moved host tier -> device
shed                     requests shed for SLO (TTFT queue + TPOT mid-flight)
decode_syncs             fused-decode device->host syncs (one per horizon)
load                     (waiting + active) / max_seqs
rebalanced_in            sequences the cluster rebalancer moved ONTO this
                         replica mid-span (adoption / requeue / re-prefill)
rebalanced_out           sequences the rebalancer moved OFF this replica
preempted                lower-priority sequences preempted here (relocated
                         or evicted) to admit a higher-priority request
fragmentation            internal waste of allocated KV pages: 1 - resident
                         tokens / (held pages * block_size), in [0, 1]
handoff_in               first-token-ready contexts a disaggregated
                         prefill replica handed TO this replica mid-span
handoff_out              contexts this (prefill-role) replica handed off
=======================  ====================================================
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (DecodeCache, PagedDecodeState, decode_loop_paged,
                          decode_step, prefill, prefill_chunk)
from repro.models.config import ModelConfig
from repro.models.sampling import sample
from repro.pshard import sharding_rules
from repro.serving.kvcache import (BlockPool, PagedKVCache, copy_blocks,
                                   relayout_blocks, reshard_blocks)
from repro.serving.prefixcache import PrefixCache
from repro.serving.telemetry import NULL_TELEMETRY

# the frozen load_stats() key set (see the module docstring table);
# ClusterRuntime.load_stats adds "dead" on top of these
LOAD_STATS_KEYS = frozenset({
    "waiting", "active", "max_seqs", "free_blocks",
    "free_blocks_effective", "tokens_out", "steps", "prefill_tokens",
    "prefix_hits", "prefix_misses", "prefix_hit_tokens",
    "prefix_evicted_bytes", "prefix_restored_bytes", "shed",
    "decode_syncs", "load",
    "rebalanced_in", "rebalanced_out", "preempted", "fragmentation",
    "handoff_in", "handoff_out",
})


def resolve_attn_impl(attn_impl: str) -> tuple[str, bool]:
    """Resolve "auto" to the backend's implementation; returns (impl, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    if attn_impl == "auto":
        attn_impl = "kernel" if on_tpu else "jnp"
    return attn_impl, attn_impl == "kernel" and not on_tpu


def head_pad_for(attn_impl: str) -> int:
    """Pool head_dim padding: the Pallas kernel wants lane-aligned heads."""
    return 128 if attn_impl == "kernel" else 1


@dataclasses.dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray           # int32 [S] — the ORIGINAL prompt, always
    max_new_tokens: int
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    # resumed (migrated) requests prefill prompt+generated as one context
    ctx: np.ndarray | None = None
    # chunked prefill: tokens of ``prefill_tokens`` already in pages
    prefill_pos: int = 0
    # SLO shedding: absolute TTFT deadline (engine clock); a waiting request
    # whose deadline has passed is rejected before prefill ever starts
    deadline: float | None = None
    # SLO shedding, decode side: per-token pace budget (seconds/token) and
    # the first-token timestamp it is measured from; a mid-flight request
    # whose average pace exceeds the budget is shed (see ``_shed_slow``)
    tpot_budget: float | None = None
    t_first: float | None = None
    # engine-clock submission time (telemetry: queue delay / TTFT)
    t_submit: float | None = None
    # scheduling priority (higher = more important): orders admission and
    # selects preemption victims in the cluster rebalancer
    priority: int = 0

    @property
    def prefill_tokens(self) -> np.ndarray:
        return self.ctx if self.ctx is not None else self.prompt

    @property
    def prefilling(self) -> bool:
        """The context is not fully in pages yet: excluded from decode
        batches, advanced chunk by chunk.  (Resumed requests re-prefilling
        ``prompt + generated`` are prefilling despite non-empty
        ``generated``; page-adopted ones start with ``prefill_pos`` at the
        end.)"""
        return self.prefill_pos < len(self.prefill_tokens)


@dataclasses.dataclass
class InflightSnapshot:
    """State of one request, sufficient to resume it anywhere.

    The token fields alone (``release=True`` exports) support the re-prefill
    restore path.  A ``release=False`` export additionally carries the live
    KV state — the physical pages (whose allocator refcounts the snapshot
    now owns), the resident length, and the SSM state rows — enabling
    zero-recompute restores via ``import_by_pages``.  Held pages must end in
    exactly one of: adoption by a destination engine, or
    ``migration.release_snapshot_pages``.
    """
    rid: int
    prompt: np.ndarray
    generated: list
    max_new_tokens: int
    # live KV state (page-handoff exports only)
    blocks: list | None = None       # physical page ids, sequence order
    seq_len: int = 0                 # tokens resident in those pages
    n_shared: int = 0                # leading prefix-cache pages (refcounted)
    pool: "BlockPool | None" = None  # the pool the pages live in
    ssm: jax.Array | None = None     # [L, ...] this sequence's SSM state row
    conv: jax.Array | None = None
    deadline: float | None = None    # TTFT deadline, carried across migration
    tpot: float | None = None        # TPOT pace budget, carried likewise
    priority: int = 0                # scheduling priority, carried likewise


@dataclasses.dataclass
class PendingDecode:
    """A dispatched-but-unsynced fused decode horizon.

    Holds the device token block between ``step_async`` and
    ``finish_step`` so cross-replica dispatch can overlap device work; the
    single ``np.asarray(tokens)`` in ``finish_step`` is the horizon's one
    device→host transfer.
    """
    slots: list[int]
    tokens: jax.Array    # [B_bucket, horizon] device-resident token block
    horizon: int


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clipped to cap."""
    return min(cap, 1 << max(0, n - 1).bit_length())


def _pow2_floor(n: int) -> int:
    """Largest power of two <= n (n >= 1)."""
    return 1 << (n.bit_length() - 1)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, num_blocks: int = 512,
                 block_size: int = 16, max_seqs: int = 8,
                 dtype=jnp.float32, greedy: bool = True, seed: int = 0,
                 decode_mode: str = "paged", attn_impl: str = "auto",
                 pool: BlockPool | None = None, kv_quota: int | None = None,
                 max_blocks_per_seq: int | None = None,
                 prefill_chunk_tokens: int | None = None,
                 decode_horizon: int = 1,
                 prefix_cache: bool = False,
                 mesh=None, shard_plan=None,
                 clock=None, telemetry=None, trace_id: int = 0,
                 role: str = "mixed"):
        """``mesh`` + ``shard_plan`` turn on real intra-replica model
        parallelism: params are placed with ``param_pspecs`` shardings, the
        paged K/V pool is sharded along its KV-head (tp) and layer (pp)
        axes (``pool_pspecs``), and every jitted forward is traced under the
        plan's logical-axis rules so GSPMD partitions prefill, the fused
        decode loop, and chunked prefill across the replica's devices.  The
        host scheduler / allocator / block tables are sharding-oblivious.
        ``cfg``/``params`` must already be the plan's run config (head-
        padded when ``shard_plan.attn_mode == "pad"`` — see
        ``launch.sharding.pad_attention_params``).
        """
        self.cfg = cfg
        self.params = params
        if decode_mode not in ("paged", "dense"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        if decode_horizon < 1:
            raise ValueError("decode_horizon must be >= 1")
        if decode_horizon > 1 and decode_mode != "paged":
            raise ValueError("decode_horizon > 1 needs decode_mode='paged'")
        self.decode_mode = decode_mode
        self.decode_horizon = decode_horizon
        attn_impl, self._interpret = resolve_attn_impl(attn_impl)
        self._attn_impl = attn_impl
        self._mesh = mesh
        self._shard_plan = shard_plan
        if mesh is not None:
            if shard_plan is None:
                raise ValueError("a sharded engine needs shard_plan "
                                 "(launch.sharding.make_plan(..., 'serve'))")
            if decode_mode != "paged":
                raise ValueError("sharded engines need decode_mode='paged'")
            if attn_impl == "kernel":
                raise NotImplementedError(
                    "the Pallas kernel path is not shard_map-wired yet; "
                    "sharded engines use attn_impl='jnp'")
            from repro.launch.sharding import named, param_pspecs
            self.params = jax.device_put(
                params, named(mesh, param_pspecs(cfg, shard_plan)))
        # the kernel path wants lane-aligned head_dim; pad the pool once at
        # allocation rather than re-padding it every decode step
        head_pad = head_pad_for(attn_impl)
        if max_blocks_per_seq is None:
            max_blocks_per_seq = cfg.max_seq_len // block_size
        if pool is not None:
            if mesh is not None and pool.mesh != mesh:
                raise ValueError("shared pool lives on a different mesh "
                                 "than this engine")
            if pool.block_size != block_size:
                raise ValueError(
                    f"shared pool block_size {pool.block_size} != engine "
                    f"block_size {block_size}")
            if cfg.has_attn and pool.head_pad % head_pad:
                raise ValueError(
                    f"shared pool head_pad {pool.head_pad} incompatible with "
                    f"attn_impl {attn_impl!r} (needs multiple of {head_pad})")
            self.cache = PagedKVCache.from_pool(
                pool, max_seqs, max_blocks_per_seq, quota=kv_quota)
        else:
            kv_spec = None
            if mesh is not None:
                from repro.launch.sharding import pool_pspecs
                kv_spec = pool_pspecs(cfg, shard_plan)
            self.cache = PagedKVCache.create(
                cfg, num_blocks, block_size, max_seqs,
                max_blocks_per_seq=max_blocks_per_seq, dtype=dtype,
                head_pad=head_pad, mesh=mesh, kv_spec=kv_spec,
                rules=shard_plan.rules if shard_plan else None)
        self.max_seqs = max_seqs
        self.dtype = dtype
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.waiting: list[EngineRequest] = []
        self.active: dict[int, EngineRequest] = {}    # slot -> request
        self.admitting = True
        self.steps = 0
        self.tokens_out = 0
        # tokens that went through a prefill forward (one-shot or chunked);
        # page-handoff migration adds ZERO here — tests assert on it
        self.prefill_tokens = 0
        # global decode-step counter: step t samples with
        # step_key(self.key, t) in BOTH the per-step and horizon paths, so
        # sampled streams are horizon-invariant
        self._sample_step = 0
        # one increment per fused-decode device→host sync (the horizon's
        # single transfer) — benches assert syncs << decode token-steps
        self.decode_syncs = 0
        # dispatched horizon histogram {h: count} + the last dispatched h
        self.horizon_counts: dict[int, int] = {}
        self.last_horizon = 0
        # chunked-prefill round-robin rotation pointer
        self._chunk_rr = 0
        # SLO shedding: rids rejected because their TTFT budget was already
        # blown while still waiting
        self.shed_rids: list[int] = []
        # rebalancer traffic: sequences moved onto/off this replica mid-span
        # and lower-priority sequences preempted here (cluster increments)
        self.rebalanced_in = 0
        self.rebalanced_out = 0
        self.preempted = 0
        # disaggregated serving role ("mixed" | "prefill" | "decode") and
        # its first-token-ready context traffic: the engine itself is
        # role-oblivious (the cluster routes and hands off); the role tag
        # and counters exist for telemetry and the health loop
        self.role = role
        self.handoff_in = 0
        self.handoff_out = 0
        # one time source for deadlines, TPOT pacing, AND trace events:
        # ``clock`` wins, else the telemetry bundle's clock (time.monotonic
        # on the disabled default) — inject a fake via either for
        # deterministic tests
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self.trace_id = trace_id          # replica index on the trace
        self.clock = clock if clock is not None else self.telemetry.clock
        # chaos injection: when set, called as ``fault_hook("admit")`` at
        # the top of the admission path (before any state is mutated) and
        # may raise (e.g. an injected pool-reservation OOM).  The cluster
        # wires this to its ``FaultPlan``; standalone engines leave it None.
        self.fault_hook = None
        # chunked prefill needs per-position resumable state; the SSD scan
        # has none, so SSM/hybrid archs keep the one-shot path
        if prefill_chunk_tokens is not None and cfg.has_ssm:
            prefill_chunk_tokens = None
        self.prefill_chunk_tokens = prefill_chunk_tokens
        # content-addressed prefix reuse: resuming prefill mid-prompt rides
        # the chunked-prefill forward, which SSM archs don't have, and pages
        # carry no SSM state — so the cache is attention-only
        self.prefix_cache = None
        if prefix_cache and cfg.has_attn and not cfg.has_ssm:
            self.prefix_cache = (self.cache.pool.prefix_cache
                                 or PrefixCache(self.cache.pool))
            if self.telemetry.enabled:
                # pool-scoped sink: evict/restore events carry replica=-1
                self.prefix_cache.telemetry = self.telemetry
        # (rid, cached_tokens, ctx_tokens) per admission — the cluster
        # drains these into per-workload-type hit rates for the planner
        self.prefix_events: list[tuple[int, int, int]] = []

        self._prefill = jax.jit(
            lambda p, toks: prefill(p, cfg, tokens=toks))
        self._decode = jax.jit(
            lambda p, toks, cache: decode_step(p, cfg, toks, cache))
        self._fused = self._build_fused()
        trash = self.cache.num_blocks
        donate = (2, 3) if jax.default_backend() != "cpu" else ()
        self._chunk = jax.jit(
            lambda p, t, k, v, tab, s, nv: prefill_chunk(
                p, cfg, t, k, v, tab, s, nv, trash),
            donate_argnums=donate)

    def _rules_ctx(self):
        """Context installing the replica's logical-axis sharding rules.

        The ``logical(...)`` annotations in the model only bind at *trace*
        time, so every jitted call site enters this context — re-traces for
        new shape buckets then pick up the replica's mesh rules; unsharded
        engines get a no-op."""
        if self._mesh is None:
            return contextlib.nullcontext()
        return sharding_rules(self._mesh, self._shard_plan.rules)

    def _local(self, x):
        """Bring a (possibly other-mesh) array onto this engine's devices.

        Migration snapshots carry SSM state rows that live on the *source*
        replica's mesh; mixing them into this engine's arrays needs an
        explicit cross-mesh hop first."""
        if x is None:
            return None
        if self._mesh is not None:
            return jax.device_put(x, NamedSharding(self._mesh, P()))
        return jax.device_put(x, jax.devices()[0])

    def _build_fused(self):
        """The jitted device-resident decode loop (up to ``horizon`` steps).

        Gathers per-slot metadata/state from the full-size device arrays,
        scans ``horizon`` fused decode steps (``models.decode_loop_paged``:
        attention + K/V scatter + SSM update + in-loop sampled key folding
        + device lens advance), and scatters lens/SSM state back — the
        ``[B, horizon]`` token block is the only thing that crosses back to
        the host, once per horizon.
        """
        cfg, greedy = self.cfg, self.greedy
        impl, interp = self._attn_impl, self._interpret
        trash = self.cache.trash_slot

        def fused(params, k, v, table_full, lens_full, ssm_full, conv_full,
                  slots, tokens, key, step0, n_pages, horizon):
            table = table_full[slots, :n_pages]
            lens = lens_full[slots]
            ssm = ssm_full[:, slots] if ssm_full is not None else None
            conv = conv_full[:, slots] if conv_full is not None else None
            st = PagedDecodeState(k=k, v=v, block_table=table, lens=lens,
                                  ssm=ssm, conv=conv)
            toks, st = decode_loop_paged(
                params, cfg, tokens, st, key, step0, horizon,
                attn_impl=impl, interpret=interp,
                temperature=0.0 if greedy else 1.0)
            # padded rows advanced the trash slot's lens inside the loop;
            # pin it back to 0 so the trash row stays inert
            lens_full = lens_full.at[slots].set(st.lens).at[trash].set(0)
            if ssm_full is not None:
                ssm_full = ssm_full.at[:, slots].set(st.ssm)
                conv_full = conv_full.at[:, slots].set(st.conv)
            return toks, st.k, st.v, lens_full, ssm_full, conv_full

        # donate the pools/state so XLA updates pages in place (no-op on CPU)
        donate = (1, 2, 4, 5, 6) if jax.default_backend() != "cpu" else ()
        return jax.jit(fused, static_argnames=("n_pages", "horizon"),
                       donate_argnums=donate)

    # -- submission ------------------------------------------------------------

    @property
    def max_context(self) -> int:
        """Tokens one sequence's block table can address."""
        return self.cache.max_blocks_per_seq * self.cache.block_size

    def _capacity_blocks(self) -> int:
        """Blocks one sequence may ever hold on this replica."""
        cap = min(self.cache.max_blocks_per_seq, self.cache.num_blocks)
        if self.cache.quota is not None:
            cap = min(cap, self.cache.quota)
        return cap

    def fits(self, ctx_len: int, new_tokens: int) -> bool:
        """Can this replica *ever* serve a request of this size?  (Same
        bound ``_validate`` enforces; used by routers to mask out replicas
        whose context ceiling is too small.)"""
        if new_tokens < 1:
            return False
        need = ctx_len + new_tokens - 1
        bs = self.cache.block_size
        return (need + bs - 1) // bs <= self._capacity_blocks()

    def _validate(self, ctx_len: int, new_tokens: int, rid: int) -> None:
        if new_tokens < 1:
            raise ValueError(f"request {rid}: max_new_tokens must be >= 1")
        # the final generated token is returned but never written to a page,
        # so lifetime cache footprint is ctx + new - 1 positions
        if not self.fits(ctx_len, new_tokens):
            need = ctx_len + new_tokens - 1
            raise ValueError(
                f"request {rid}: context {ctx_len} + {new_tokens} new tokens "
                f"needs {need} cache positions but this replica's "
                f"per-sequence block capacity is "
                f"{self._capacity_blocks()} x {self.cache.block_size} tokens")

    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int,
               ttft_deadline: float | None = None,
               tpot_deadline: float | None = None,
               type_id: int = -1, priority: int = 0) -> None:
        """Queue a request.  ``ttft_deadline`` (engine-clock absolute time)
        arms SLO-aware shedding: if the deadline passes while the request is
        still waiting, it is rejected instead of admitted (its TTFT budget
        is already blown, so prefilling it would only waste capacity).
        ``tpot_deadline`` (seconds per output token) arms the decode-side
        counterpart: a request whose average token pace, measured from its
        first token, exceeds the budget is shed mid-flight (its slot and
        pages go to requests that can still meet their SLO).  ``type_id``
        only labels the request's workload type on telemetry events.
        ``priority`` (higher = more important) orders admission — the queue
        is stable-sorted by priority whenever any waiter is non-zero — and
        marks preemption victims for the cluster rebalancer."""
        prompt = np.asarray(prompt, np.int32)
        self._validate(len(prompt), max_new_tokens, rid)
        req = EngineRequest(rid, prompt, max_new_tokens,
                            deadline=ttft_deadline,
                            tpot_budget=tpot_deadline,
                            priority=priority)
        tm = self.telemetry
        if tm.enabled:
            req.t_submit = self.clock()
            tm.emit("submit", rid=rid, replica=self.trace_id,
                    type_id=type_id, prompt_len=len(prompt),
                    max_new=max_new_tokens)
        self.waiting.append(req)

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_seqs) if s not in self.active]

    # -- replica lifecycle (cluster runtime) -----------------------------------

    def pause_admission(self) -> None:
        """Stop moving waiting requests into slots (switch in progress)."""
        self.admitting = False

    def resume_admission(self) -> None:
        self.admitting = True

    def drain(self, max_steps: int | None = None) -> list[EngineRequest]:
        """Run admission-free steps until the active set empties.

        Short in-flight sequences finish in place (the paper's drain path);
        whatever is still running after ``max_steps`` is left for
        ``export_inflight``.  Admission stays paused on return.
        """
        self.pause_admission()
        finished: list[EngineRequest] = []
        steps = 0
        while self.active and (max_steps is None or steps < max_steps):
            finished.extend(self.step())
            steps += 1
        return finished

    def export_inflight(self, release: bool = True) -> list[InflightSnapshot]:
        """Snapshot and evict every in-flight + queued request.

        ``release=True``: host token state only — prompt and generated
        tokens — KV blocks return to the pool and the target replica
        re-prefills ``prompt + generated`` (see ``import_inflight``).

        ``release=False`` (page handoff): snapshots of sequences that hold a
        useful KV prefix (fully prefilled, mid-generation) keep ownership of
        their physical pages and SSM state rows so a destination can adopt
        them via ``import_by_pages`` with zero recompute.  The caller is
        responsible for every held page (adopt or
        ``migration.release_snapshot_pages``).
        """
        snaps: list[InflightSnapshot] = []
        for slot in sorted(self.active):
            r = self.active.pop(slot)
            snaps.append(self._snapshot_slot(slot, r, release))
        for r in self.waiting:
            snaps.append(InflightSnapshot(r.rid, r.prompt,
                                          list(r.generated),
                                          r.max_new_tokens,
                                          deadline=r.deadline,
                                          tpot=r.tpot_budget,
                                          priority=r.priority))
        self.waiting = []
        return snaps

    def _snapshot_slot(self, slot: int, r: EngineRequest,
                       release: bool) -> InflightSnapshot:
        """Snapshot one evicted active request (slot already popped).

        ``release=True`` or mid-prefill: token state only, pages back to
        the pool.  Otherwise a page-handoff snapshot that owns the slot's
        disowned pages and SSM rows (caller must adopt or release them).
        """
        if release or r.prefilling:
            # mid-chunk prefixes are not resumable state: drop the pages
            self.cache.release_slot(slot)
            return InflightSnapshot(r.rid, r.prompt, list(r.generated),
                                    r.max_new_tokens,
                                    deadline=r.deadline,
                                    tpot=r.tpot_budget,
                                    priority=r.priority)
        ssm_row = (self.cache.ssm[:, slot]
                   if self.cache.ssm is not None else None)
        conv_row = (self.cache.conv[:, slot]
                    if self.cache.conv is not None else None)
        n_shared = self.cache.seq_shared.get(slot, 0)
        blocks, seq_len = self.cache.disown_slot(slot)
        return InflightSnapshot(
            r.rid, r.prompt, list(r.generated), r.max_new_tokens,
            blocks=blocks, seq_len=seq_len, n_shared=n_shared,
            pool=self.cache.pool,
            ssm=ssm_row, conv=conv_row, deadline=r.deadline,
            tpot=r.tpot_budget, priority=r.priority)

    def export_request(self, rid: int,
                       release: bool = False) -> InflightSnapshot | None:
        """Evict ONE request mid-span without touching admission.

        The cluster rebalancer's single-sequence primitive: an active
        request comes out as a page-handoff snapshot (unless ``release`` or
        still prefilling — then token-state only, pages freed), a queued
        one as a plain token snapshot.  Returns None if ``rid`` is not
        here.  Unlike ``export_inflight`` this leaves every other request
        and the admission gate untouched, so the engine keeps serving.
        """
        for slot, r in list(self.active.items()):
            if r.rid == rid:
                del self.active[slot]
                return self._snapshot_slot(slot, r, release)
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                self.waiting.pop(i)
                return InflightSnapshot(r.rid, r.prompt, list(r.generated),
                                        r.max_new_tokens,
                                        deadline=r.deadline,
                                        tpot=r.tpot_budget,
                                        priority=r.priority)
        return None

    def import_by_pages(self, snaps: list[InflightSnapshot]
                        ) -> list[InflightSnapshot]:
        """Adopt migrated sequences directly from their live KV pages.

        Same-pool snapshots transfer by re-registering block ownership (no
        data movement, zero tokens recomputed); cross-pool ones run the
        jitted page copy (or the dense relayout when page geometry differs)
        and release the source pages.  Adopted requests join ``active``
        mid-generation — the next ``step`` decodes them.

        Returns the snapshots that could NOT be adopted (no free slot /
        quota / no pages); callers fall back to ``import_inflight``, which
        still owns releasing those snapshots' pages.
        """
        rejected: list[InflightSnapshot] = []
        for s in snaps:
            if s.blocks is None or s.pool is None or not s.generated:
                rejected.append(s)
                continue
            ctx = len(s.prompt) + len(s.generated)
            remaining = s.max_new_tokens - len(s.generated)
            if remaining < 1:
                raise ValueError(f"request {s.rid}: nothing left to generate")
            free = self._free_slots()
            # lifetime positions: resident prefix + tokens still to cache
            total = ctx + remaining - 1
            if not free or not self.fits(ctx, remaining):
                rejected.append(s)
                continue
            same_pool = s.pool is self.cache.pool
            if same_pool:
                if (s.pool.block_size != self.cache.block_size
                        or not self.cache.can_adopt(len(s.blocks), total,
                                                    n_shared=s.n_shared)):
                    rejected.append(s)
                    continue
                slot = free[0]
                self.cache.adopt_slot(slot, s.blocks, s.seq_len,
                                      total_tokens=total,
                                      n_shared=s.n_shared)
            else:
                if not self.cache.can_admit(s.seq_len, total_tokens=total):
                    rejected.append(s)
                    continue
                slot = free[0]
                self.cache.admit(slot, s.seq_len, total_tokens=total)
                dst_blocks = self.cache.seq_blocks[slot]
                same_place = s.pool.placement == self.cache.pool.placement
                same_heads = (s.pool.k is None
                              or s.pool.k.shape[2] == self.cache.k.shape[2])
                if s.pool.k is None:
                    pass      # attn-free arch: state is the SSM rows below
                elif (same_place and same_heads
                        and s.pool.block_size == self.cache.block_size
                        and s.pool.k.shape[2:] == self.cache.k.shape[2:]):
                    copy_blocks(s.pool, self.cache.pool, s.blocks, dst_blocks)
                elif same_place and same_heads:
                    relayout_blocks(s.pool, self.cache.pool, s.blocks,
                                    dst_blocks, s.seq_len)
                else:
                    # pools on different meshes / head shardings / padded
                    # head counts: dense gather + explicit cross-mesh hop +
                    # head fix + re-chunked scatter
                    reshard_blocks(s.pool, self.cache.pool, s.blocks,
                                   dst_blocks, s.seq_len)
                s.pool.allocator.release(s.blocks)
            if s.ssm is not None:
                self.cache.ssm = self.cache.ssm.at[:, slot].set(
                    self._local(s.ssm))
            if s.conv is not None:
                self.cache.conv = self.cache.conv.at[:, slot].set(
                    self._local(s.conv))
            r = EngineRequest(s.rid, np.asarray(s.prompt, np.int32),
                              s.max_new_tokens, slot=slot,
                              generated=list(s.generated),
                              tpot_budget=s.tpot, priority=s.priority)
            r.prefill_pos = len(r.prefill_tokens)   # prefix already in pages
            # the pace clock restarts on the adopting engine: migration
            # stall is accounted to the switch, not to this request's TPOT
            r.t_first = self.clock()
            self.active[slot] = r
            # this engine owns the pages now: neuter the snapshot so a later
            # release cannot double-free them
            s.blocks = None
            s.pool = None
            s.ssm = None
            s.conv = None
        return rejected

    def import_inflight(self, snaps: list[InflightSnapshot]) -> None:
        """Resume migrated requests (re-prefill of prompt + generated).

        The resumed context re-computes KV pages / SSM state here, and the
        prefill's final-position logits produce exactly the token a decode
        step on the source replica would have produced next (greedy).
        """
        for s in snaps:
            if not s.generated:          # never prefilled: plain submission
                self.submit(s.rid, s.prompt, s.max_new_tokens,
                            ttft_deadline=s.deadline,
                            tpot_deadline=s.tpot, priority=s.priority)
                continue
            remaining = s.max_new_tokens - len(s.generated)
            if remaining < 1:
                raise ValueError(f"request {s.rid}: nothing left to generate")
            ctx = np.concatenate([np.asarray(s.prompt, np.int32),
                                  np.asarray(s.generated, np.int32)])
            self._validate(len(ctx), remaining, s.rid)
            self.waiting.append(EngineRequest(
                s.rid, np.asarray(s.prompt, np.int32), s.max_new_tokens,
                generated=list(s.generated), ctx=ctx,
                tpot_budget=s.tpot, priority=s.priority))

    def release_all(self) -> None:
        """Teardown: hand every block back to the (shared) pool."""
        self.active = {}
        self.waiting = []
        self.cache.release_all()

    def load_stats(self) -> dict:
        """Occupancy snapshot for routers / the cluster health loop.

        The key set is FROZEN (``LOAD_STATS_KEYS``): see the module
        docstring table; ``tests/test_telemetry.py`` asserts it."""
        pc = self.prefix_cache
        return {
            "waiting": len(self.waiting),
            "active": len(self.active),
            "max_seqs": self.max_seqs,
            "free_blocks": self.cache.n_free_blocks,
            # hit-rate-adjusted capacity: cold cached pages are evictable on
            # demand, so they count as free for admission planning
            "free_blocks_effective": (self.cache.n_free_blocks
                                      + (pc.cold_blocks() if pc else 0)),
            "tokens_out": self.tokens_out,
            "steps": self.steps,
            "prefill_tokens": self.prefill_tokens,
            "prefix_hits": pc.hits if pc else 0,
            "prefix_misses": pc.misses if pc else 0,
            "prefix_hit_tokens": pc.hit_tokens if pc else 0,
            "prefix_evicted_bytes": pc.evicted_bytes if pc else 0,
            "prefix_restored_bytes": pc.restored_bytes if pc else 0,
            "shed": len(self.shed_rids),
            "decode_syncs": self.decode_syncs,
            "load": (len(self.waiting) + len(self.active)) / self.max_seqs,
            "rebalanced_in": self.rebalanced_in,
            "rebalanced_out": self.rebalanced_out,
            "preempted": self.preempted,
            "fragmentation": self._fragmentation(),
            "handoff_in": self.handoff_in,
            "handoff_out": self.handoff_out,
        }

    def _fragmentation(self) -> float:
        """Internal waste of the pages this replica's sequences hold:
        1 - resident tokens / (held pages * block_size).  High values mean
        many partially-filled tail pages — cheap sequences for the
        rebalancer to relocate, since moving them frees whole pages."""
        held = sum(len(b) for b in self.cache.seq_blocks.values())
        if not held:
            return 0.0
        resident = sum(int(self.cache.seq_lens[s])
                       for s in self.cache.seq_blocks)
        return 1.0 - resident / (held * self.cache.block_size)

    def inflight_context_lens(self) -> list[int]:
        """Context length of every sequence that holds live KV pages (the
        orchestrator's migration-cost input for the next switch decision).

        Queued and mid-prefill requests are excluded: they migrate by free
        requeue, not by moving KV state, so pricing them as byte transfers
        would wrongly inflate the switch-cost term."""
        return [len(r.prompt) + len(r.generated)
                for r in self.active.values() if not r.prefilling]

    # -- scheduling ------------------------------------------------------------

    def _shed_blown(self) -> None:
        """SLO-aware queue shedding: drop waiting requests whose TTFT
        deadline has already passed — prefilling them cannot meet the SLO,
        so the capacity goes to requests that can still make theirs."""
        if not any(r.deadline is not None for r in self.waiting):
            return
        now = self.clock()
        keep = []
        tm = self.telemetry
        for r in self.waiting:
            if r.deadline is not None and now > r.deadline:
                self.shed_rids.append(r.rid)
                if tm.enabled:
                    tm.emit("shed", rid=r.rid, replica=self.trace_id,
                            reason="ttft")
                    tm.metrics.count("shed_ttft")
            else:
                keep.append(r)
        self.waiting = keep

    def _admit(self) -> list[EngineRequest]:
        """Move waiting requests into free slots while KV blocks remain."""
        admitted = []
        if not self.admitting:
            return admitted
        if self.fault_hook is not None:
            self.fault_hook("admit")
        self._shed_blown()
        # priority-aware ordering: stable sort keeps FIFO within a class;
        # the all-default (priority 0) path is left untouched
        if any(r.priority for r in self.waiting):
            self.waiting.sort(key=lambda r: -r.priority)
        free = self._free_slots()
        while self.waiting and free:
            req = self.waiting[0]
            ctx = len(req.prefill_tokens)
            # reserve the sequence's lifetime footprint (prompt + remaining
            # decode growth) so later extends can't exhaust the shared pool
            total = ctx + (req.max_new_tokens - len(req.generated)) - 1
            cached, shared, cow = 0, (), None
            if self.prefix_cache is not None and req.prefill_pos == 0:
                # attach (which restores host-tier pages) must precede the
                # capacity check: a failed restore shrinks the match.  The
                # cap at ctx - 1 keeps at least one token in the prefill
                # forward so its logits produce the first generated token.
                m = self.prefix_cache.match(req.prefill_tokens, ctx - 1)
                cached, shared, cow = self.prefix_cache.attach(m)
            if not self.cache.can_admit(ctx, total_tokens=total,
                                        shared_blocks=shared):
                break
            self.waiting.pop(0)
            req.slot = free.pop(0)
            self.cache.admit(req.slot, ctx, total_tokens=total,
                             shared_blocks=shared, cow_src=cow)
            if cached:
                req.prefill_pos = cached   # prefill starts past the prefix
            if self.prefix_cache is not None:
                self.prefix_events.append((req.rid, cached, ctx))
            tm = self.telemetry
            if tm.enabled:
                now = self.clock()
                delay = (now - req.t_submit
                         if req.t_submit is not None else 0.0)
                tm.emit("admit", rid=req.rid, replica=self.trace_id,
                        reserved_bytes=(self.cache.seq_reserved.get(
                            req.slot, 0) * self.cache.pool.page_nbytes),
                        cached_tokens=cached, queue_delay_s=delay)
                tm.metrics.observe("queue_delay_s", delay)
                if cached:
                    tm.emit("prefix_hit", rid=req.rid,
                            replica=self.trace_id, tokens=cached,
                            pages=len(shared) + (1 if cow is not None
                                                 else 0))
            self.active[req.slot] = req
            admitted.append(req)
        return admitted

    def _publish(self, slot: int, r: EngineRequest) -> None:
        """Hand this sequence's full resident pages to the prefix index so
        later prompts with the same leading tokens attach them by refcount.
        Called whenever the context is fully paged (prefill complete) and
        again at retirement/shedding — by then decode has extended the
        stream, so multi-turn follow-ups hit the generated pages too."""
        if self.prefix_cache is None:
            return
        blocks = self.cache.seq_blocks.get(slot)
        if not blocks:
            return
        resident = int(self.cache.seq_lens[slot])
        stream = np.asarray(r.prompt, np.int32)
        if r.generated:
            stream = np.concatenate(
                [stream, np.asarray(r.generated, np.int32)])
        self.prefix_cache.publish(stream[:resident], blocks)

    def _note_first_token(self, r: EngineRequest, now: float) -> None:
        """Telemetry: a request's FIRST ever token just materialized.

        Callers gate on ``not r.generated`` *before* appending — a migrated
        request re-prefilling ``prompt + generated`` produced its first
        token on its origin replica, so it must not re-enter the TTFT
        histogram here."""
        tm = self.telemetry
        if not tm.enabled:
            return
        ttft = now - r.t_submit if r.t_submit is not None else 0.0
        tm.emit("first_token", rid=r.rid, replica=self.trace_id,
                ttft_s=ttft)
        tm.metrics.observe("ttft_s", ttft)

    def _run_prefill(self, reqs: list[EngineRequest]) -> None:
        # bucket by prompt length: same-length batches need no padding, so
        # RoPE positions stay exact for every sequence
        by_len: dict[int, list[EngineRequest]] = {}
        for r in reqs:
            by_len.setdefault(len(r.prefill_tokens), []).append(r)
        for pl, group in by_len.items():
            toks = np.stack([r.prefill_tokens for r in group])
            with self._rules_ctx():
                logits, cache = self._prefill(self.params, jnp.asarray(toks))
            first = self._pick(logits)           # one sync per prefill group
            self.prefill_tokens += pl * len(group)
            t_first = self.clock()
            for i, r in enumerate(group):
                r.t_first = t_first
                if self.cfg.has_attn:
                    self.cache.write_prefill(r.slot, cache.k[:, i],
                                             cache.v[:, i])
                if self.cfg.has_ssm:
                    self.cache.ssm = self.cache.ssm.at[:, r.slot].set(
                        cache.ssm[:, i])
                    self.cache.conv = self.cache.conv.at[:, r.slot].set(
                        cache.conv[:, i])
                r.prefill_pos = pl
                fresh = not r.generated
                r.generated.append(int(first[i]))
                self.tokens_out += 1
                if fresh:
                    self._note_first_token(r, t_first)
                self._publish(r.slot, r)

    def _resume_prefill(self, reqs: list[EngineRequest]) -> None:
        """One-shot prefill of the *uncached suffix* only.

        Prefix-cache admissions land with ``prefill_pos`` at the first
        uncached token; the suffix runs through the chunked-prefill forward
        (which attends to the cached pages via the block table) in a single
        call, so only ``len(prompt) - prefill_pos`` tokens hit
        ``prefill_tokens``.  Writes start at ``prefill_pos``, whose page is
        always private (fresh or COW), so shared pages stay immutable.
        """
        for r in reqs:
            toks = r.prefill_tokens
            start = r.prefill_pos
            n_valid = len(toks) - start
            cb = 1 << max(0, n_valid - 1).bit_length()
            buf = np.zeros((1, cb), np.int32)
            buf[0, :n_valid] = toks[start:]
            bs = self.cache.block_size
            need = (len(toks) + bs - 1) // bs
            n_pages = _pow2_bucket(need, self.cache.max_blocks_per_seq)
            table = self.cache.block_table_dev[r.slot:r.slot + 1, :n_pages]
            with self._rules_ctx():
                logits, k, v = self._chunk(self.params, jnp.asarray(buf),
                                           self.cache.k, self.cache.v, table,
                                           jnp.int32(start),
                                           jnp.int32(n_valid))
            self.cache.k, self.cache.v = k, v
            self.prefill_tokens += n_valid      # cached tokens cost zero
            r.prefill_pos = len(toks)
            first = self._pick(logits)
            r.t_first = self.clock()
            fresh = not r.generated
            r.generated.append(int(first[0]))
            self.tokens_out += 1
            if fresh:
                self._note_first_token(r, r.t_first)
            self._publish(r.slot, r)

    def _advance_chunked(self) -> None:
        """Spread this step's chunk-token budget over ALL mid-prefill
        sequences.

        One bounded chunk-token budget per engine step (Sarathi-style): the
        prefill->page scatter is fused into each chunk forward, and the
        decode batch for already-running sequences proceeds in the same
        step, so a long prompt never stalls decoding.  The budget is split
        evenly round-robin across every mid-prefill sequence (rotating the
        start slot each step so leftover tokens don't always favor the same
        sequence) instead of dedicating it all to the oldest — two long
        prompts stream in concurrently rather than serializing head-of-line.
        """
        slots = sorted(s for s, r in self.active.items() if r.prefilling)
        if not slots:
            return
        rot = self._chunk_rr % len(slots)
        self._chunk_rr += 1
        order = slots[rot:] + slots[:rot]
        budget = self.prefill_chunk_tokens
        # floor the per-slot share at C/4: a wide mid-prefill set otherwise
        # degenerates into many tiny per-slot forwards whose dispatch
        # overhead eats the fused-chunk win — at most 4 streams advance per
        # step, the rotation rotates who they are
        floor = max(1, self.prefill_chunk_tokens // 4)
        for idx, slot in enumerate(order):
            if budget <= 0:
                break
            # even split over the slots still to be served this step —
            # recomputed each iteration so budget a short prefill leaves on
            # the table flows to the longer ones behind it
            share = max(floor, budget // (len(order) - idx))
            r = self.active[slot]
            toks_all = r.prefill_tokens
            start = r.prefill_pos
            n_valid = min(share, budget, len(toks_all) - start)
            cb = _pow2_bucket(n_valid, self.prefill_chunk_tokens)
            buf = np.zeros((1, cb), np.int32)
            buf[0, :n_valid] = toks_all[start:start + n_valid]
            bs = self.cache.block_size
            need = (start + n_valid + bs - 1) // bs
            n_pages = _pow2_bucket(need, self.cache.max_blocks_per_seq)
            table = self.cache.block_table_dev[slot:slot + 1, :n_pages]
            with self._rules_ctx():
                logits, k, v = self._chunk(self.params, jnp.asarray(buf),
                                           self.cache.k, self.cache.v, table,
                                           jnp.int32(start),
                                           jnp.int32(n_valid))
            self.cache.k, self.cache.v = k, v
            self.prefill_tokens += n_valid
            budget -= n_valid
            r.prefill_pos = start + n_valid
            if self.telemetry.enabled:
                self.telemetry.emit("prefill_chunk", rid=r.rid,
                                    replica=self.trace_id, tokens=n_valid,
                                    pos=r.prefill_pos)
            if r.prefill_pos >= len(toks_all):   # final chunk emits token 1
                first = self._pick(logits)
                r.t_first = self.clock()
                fresh = not r.generated
                r.generated.append(int(first[0]))
                self.tokens_out += 1
                if fresh:
                    self._note_first_token(r, r.t_first)
                self._publish(slot, r)

    def _pick(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(sample(logits, self.cfg, self.key))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(sample(logits, self.cfg, sub, temperature=1.0))

    # -- decode paths ----------------------------------------------------------

    def _safe_horizon(self, slots: list[int], event: bool) -> int:
        """How many decode steps the next fused dispatch may take.

        ``min(decode_horizon, min remaining max_new_tokens over the batch)``
        — so no sequence overshoots its budget and retirement lands exactly
        on a horizon boundary — collapsed to 1 whenever a per-step
        scheduling event must interleave (``event``: a request was admitted
        this step, or a chunked prefill advanced — either way a sequence
        should join the decode batch next step, not a horizon later), then
        floored to a power of two so the horizon adds only
        O(log decode_horizon) compilations.  Any still-waiting request is
        blocked until a retirement frees capacity, and retirements bound
        the horizon already, so the queue itself never shrinks it.
        """
        H = self.decode_horizon
        if H <= 1:
            return 1
        if event:
            return 1
        rem = min(self.active[s].max_new_tokens - len(self.active[s].generated)
                  for s in slots)
        H = min(H, rem)
        return _pow2_floor(H) if H > 1 else 1

    def _dispatch_decode(self, slots: list[int], horizon: int
                         ) -> PendingDecode:
        """Fire the fused decode loop over the given slots; no host sync.

        Pre-extends page capacity for the whole horizon (against the
        admission-time lifetime reservation, so allocation cannot fail for
        in-budget growth) and advances the host ``seq_lens``; the device
        mirror advances inside the loop.
        """
        slots = sorted(slots)
        updates = []                     # page capacity for the whole horizon
        for s in slots:
            upd = self.cache.extend_for(s, horizon, sync_device=False)
            if upd is not None:
                updates.append(upd)
        self.cache.apply_table_updates(updates)   # one scatter for the batch
        B = len(slots)
        bucket = _pow2_bucket(B, self.max_seqs)
        trash = self.cache.trash_slot
        pad = bucket - B
        slot_arr = np.array(slots + [trash] * pad, np.int32)
        last = np.array([self.active[s].generated[-1] for s in slots]
                        + [0] * pad, np.int32)
        bs = self.cache.block_size
        need = (int(self.cache.seq_lens[slots].max()) + bs - 1) // bs
        n_pages = _pow2_bucket(need, self.cache.max_blocks_per_seq)
        step0 = self._sample_step
        self._sample_step += horizon
        self.horizon_counts[horizon] = self.horizon_counts.get(horizon, 0) + 1
        self.last_horizon = horizon
        if self.telemetry.enabled:
            self.telemetry.emit("dispatch", replica=self.trace_id,
                                n=B, h=horizon)
        with self._rules_ctx():
            toks, k, v, lens_dev, ssm, conv = self._fused(
                self.params, self.cache.k, self.cache.v,
                self.cache.block_table_dev, self.cache.seq_lens_dev,
                self.cache.ssm, self.cache.conv,
                jnp.asarray(slot_arr), jnp.asarray(last), self.key,
                jnp.int32(step0), n_pages=n_pages, horizon=horizon)
        self.cache.k, self.cache.v = k, v
        self.cache.seq_lens_dev = lens_dev
        self.cache.ssm, self.cache.conv = ssm, conv
        return PendingDecode(slots, toks, horizon)

    def _finish_decode(self, pending: PendingDecode) -> None:
        """Sync a dispatched horizon: ONE [B, H] device→host transfer."""
        toks = np.asarray(pending.tokens)
        self.decode_syncs += 1
        for i, s in enumerate(pending.slots):
            r = self.active[s]
            r.generated.extend(int(t) for t in toks[i, :pending.horizon])
            self.tokens_out += pending.horizon
        if self.telemetry.enabled:
            self.telemetry.emit("sync", replica=self.trace_id,
                                n=len(pending.slots),
                                tokens=len(pending.slots) * pending.horizon)

    def _run_decode(self, slots: list[int], horizon: int = 1) -> None:
        """Device-resident paged decode over the given slots (gather-free):
        synchronous dispatch + sync."""
        self._finish_decode(self._dispatch_decode(slots, horizon))

    def _run_decode_dense(self, slots: list[int]) -> None:
        """Legacy dense-gather decode (A/B baseline for bench_engine)."""
        slots = np.array(sorted(slots), np.int32)
        B = len(slots)
        lens = self.cache.seq_lens[slots].copy()
        max_len = int(lens.max()) + 1
        last = np.array([self.active[s].generated[-1] for s in slots], np.int32)
        if self.cfg.has_attn:
            k, v, _ = self.cache.gather_dense(slots, max_len)
        else:
            k = v = None
        ssm = self.cache.ssm[:, slots] if self.cache.ssm is not None else None
        conv = self.cache.conv[:, slots] if self.cache.conv is not None else None
        dc = DecodeCache(k=k, v=v, ssm=ssm, conv=conv,
                         pos=jnp.asarray(lens, jnp.int32))
        logits, new_cache = self._decode(self.params, jnp.asarray(last), dc)
        toks = self._pick(logits)
        # persist the new KV token + SSM state back into the pool
        for s in slots:
            self.cache.extend(int(s))
        if self.cfg.has_attn:
            bidx = jnp.arange(B)
            k_new = new_cache.k[:, bidx, jnp.asarray(lens)]   # [L, B, H, D]
            v_new = new_cache.v[:, bidx, jnp.asarray(lens)]
            self.cache.write_token(slots, k_new, v_new, lens)
        if self.cfg.has_ssm:
            self.cache.ssm = self.cache.ssm.at[:, slots].set(new_cache.ssm)
            self.cache.conv = self.cache.conv.at[:, slots].set(new_cache.conv)
        for i, s in enumerate(slots):
            r = self.active[int(s)]
            r.generated.append(int(toks[i]))
            self.tokens_out += 1

    def _retire(self) -> list[EngineRequest]:
        done = []
        tm = self.telemetry
        for s in list(self.active):
            r = self.active[s]
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                self._publish(s, r)   # decode pages join the prefix index
                self.cache.release_slot(s)
                del self.active[s]
                done.append(r)
                if tm.enabled:
                    now = self.clock()
                    tm.emit("retire", rid=r.rid, replica=self.trace_id,
                            tokens=len(r.generated))
                    if r.t_first is not None and len(r.generated) > 1:
                        tm.metrics.observe(
                            "tpot_s", (now - r.t_first)
                            / (len(r.generated) - 1))
        return done

    # -- main loop ---------------------------------------------------------------

    def step_async(self) -> PendingDecode | None:
        """The host half of one scheduler iteration: admission (with SLO
        shedding), prefill, chunked-prefill advance, and the fused decode
        *dispatch* — but NOT the decode sync.  Returns the pending decode
        handle (None when nothing decoded); the caller must pass it to
        ``finish_step``.  ``ClusterRuntime.step`` fires every replica's
        ``step_async`` before finishing any of them, so no replica's
        device→host sync blocks another replica's dispatch.

        Prefill and decode interleave: sequences that were already active
        still emit decode tokens on a step that admits new prompts (newly
        admitted requests get their first token from prefill itself).
        Prompts longer than ``prefill_chunk_tokens`` advance by a round-
        robin-shared chunk budget per step instead of one-shot prefilling,
        so the decode batch keeps emitting while long contexts stream into
        their pages.  With ``decode_horizon > 1`` the decode dispatch runs
        up to that many device-resident steps (see ``_safe_horizon``);
        ``self.steps`` counts scheduler iterations, not tokens.
        """
        self.steps += 1
        decode_slots = [s for s, r in self.active.items() if not r.prefilling]
        admitted = self._admit()
        chunk = self.prefill_chunk_tokens
        # prefix-cache hits (prefill_pos > 0) must not re-run the full
        # prompt: they resume mid-prompt via the chunk forward instead
        oneshot = [r for r in admitted
                   if (chunk is None or len(r.prefill_tokens) <= chunk)
                   and r.prefill_pos == 0]
        if oneshot:
            self._run_prefill(oneshot)
        if chunk is None:
            resumed = [r for r in admitted
                       if 0 < r.prefill_pos < len(r.prefill_tokens)]
            if resumed:
                self._resume_prefill(resumed)
        # chunked engines resume cached admissions in _advance_chunked,
        # which already starts each chunk at prefill_pos
        # capture the chunk event BEFORE advancing: a prefill that completes
        # this very step is still a per-step event (its sequence must join
        # the decode batch next step, not a horizon later)
        chunking = any(r.prefilling for r in self.active.values())
        if chunk is not None:
            self._advance_chunked()      # longer admissions, chunk by chunk
        if decode_slots:
            if self.decode_mode == "paged":
                h = self._safe_horizon(decode_slots,
                                       bool(admitted) or chunking)
                return self._dispatch_decode(decode_slots, h)
            self._run_decode_dense(decode_slots)
        return None

    def _shed_slow(self) -> None:
        """TPOT-aware mid-flight shedding: release active requests whose
        average decode pace (measured from their first token) has blown
        their per-token budget — their SLO is already lost, so the slot and
        pages go to requests that can still meet theirs.  Shed after retire,
        so a request that just produced its final token always completes."""
        if not any(r.tpot_budget is not None for r in self.active.values()):
            return
        now = self.clock()
        for s in list(self.active):
            r = self.active[s]
            if (r.tpot_budget is None or r.t_first is None
                    or len(r.generated) < 2):
                continue
            pace = (now - r.t_first) / (len(r.generated) - 1)
            if pace > r.tpot_budget:
                self.shed_rids.append(r.rid)
                if self.telemetry.enabled:
                    self.telemetry.emit("shed", rid=r.rid,
                                        replica=self.trace_id,
                                        reason="tpot")
                    self.telemetry.metrics.count("shed_tpot")
                self._publish(s, r)   # evicted work still warms the cache
                self.cache.release_slot(s)
                del self.active[s]

    def finish_step(self, pending: PendingDecode | None
                    ) -> list[EngineRequest]:
        """Sync a dispatched step (one device→host token transfer), retire
        finished requests, and shed TPOT-blown ones."""
        if pending is not None:
            self._finish_decode(pending)
        done = self._retire()
        self._shed_slow()
        return done

    def step(self) -> list[EngineRequest]:
        """One synchronous scheduler iteration; returns requests finished
        this step (``finish_step(step_async())``)."""
        return self.finish_step(self.step_async())

    def run_to_completion(self, max_steps: int = 100_000
                          ) -> list[EngineRequest]:
        finished = []
        while (self.waiting or self.active) and self.steps < max_steps:
            finished.extend(self.step())
        return finished
