"""Continuous-batching serving engine (one model replica), real JAX compute.

vLLM-style loop: admit prompts while KV blocks remain, run batched prefill,
then step decode over the active set, emitting one token per sequence per
step; finished sequences free their pages immediately.  Prefill and decode
interleave within a step, so admissions never starve running sequences.

The decode path is device-resident end to end: one jitted fused step
(``decode_step_paged`` + token scatter + sampling) consumes the paged KV
pool directly through the device block table, with no per-sequence host
syncs (a single [B] token transfer per step).  On TPU the Pallas paged
kernel reads pages in place (gather-free); the CPU/jnp fallback still
gathers the table's pages inside the jit, so its win comes from bucketed
shapes and the removed host round-trips, not memory traffic.  Active
batches are padded to power-of-two buckets and the page count to power-of-
two page buckets, so the number of distinct compilations is
O(log max_seqs * log max_pages) instead of one per (batch, length) shape.
The legacy dense-gather path survives as ``decode_mode="dense"`` for A/B
benchmarking (``benchmarks/bench_engine.py``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (DecodeCache, PagedDecodeState, decode_step,
                          decode_step_paged, prefill)
from repro.models.config import ModelConfig
from repro.models.sampling import sample
from repro.serving.kvcache import PagedKVCache


@dataclasses.dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray           # int32 [S]
    max_new_tokens: int
    slot: int = -1
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


def _pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clipped to cap."""
    return min(cap, 1 << max(0, n - 1).bit_length())


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, num_blocks: int = 512,
                 block_size: int = 16, max_seqs: int = 8,
                 dtype=jnp.float32, greedy: bool = True, seed: int = 0,
                 decode_mode: str = "paged", attn_impl: str = "auto"):
        self.cfg = cfg
        self.params = params
        if decode_mode not in ("paged", "dense"):
            raise ValueError(f"unknown decode_mode {decode_mode!r}")
        self.decode_mode = decode_mode
        on_tpu = jax.default_backend() == "tpu"
        if attn_impl == "auto":
            attn_impl = "kernel" if on_tpu else "jnp"
        self._attn_impl = attn_impl
        self._interpret = attn_impl == "kernel" and not on_tpu
        # the kernel path wants lane-aligned head_dim; pad the pool once at
        # allocation rather than re-padding it every decode step
        head_pad = 128 if attn_impl == "kernel" else 1
        self.cache = PagedKVCache.create(
            cfg, num_blocks, block_size, max_seqs,
            max_blocks_per_seq=cfg.max_seq_len // block_size, dtype=dtype,
            head_pad=head_pad)
        self.max_seqs = max_seqs
        self.dtype = dtype
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.waiting: list[EngineRequest] = []
        self.active: dict[int, EngineRequest] = {}    # slot -> request
        self.steps = 0
        self.tokens_out = 0

        self._prefill = jax.jit(
            lambda p, toks: prefill(p, cfg, tokens=toks))
        self._decode = jax.jit(
            lambda p, toks, cache: decode_step(p, cfg, toks, cache))
        self._fused = self._build_fused()

    def _build_fused(self):
        """The jitted device-resident decode step.

        Gathers per-slot metadata/state from the full-size device arrays,
        runs the paged decode, samples, and scatters lens/SSM state back —
        tokens are the only thing that crosses back to the host.
        """
        cfg, greedy = self.cfg, self.greedy
        impl, interp = self._attn_impl, self._interpret
        trash = self.cache.trash_slot

        def fused(params, k, v, table_full, lens_full, ssm_full, conv_full,
                  slots, tokens, key, n_pages):
            table = table_full[slots, :n_pages]
            lens = lens_full[slots]
            ssm = ssm_full[:, slots] if ssm_full is not None else None
            conv = conv_full[:, slots] if conv_full is not None else None
            st = PagedDecodeState(k=k, v=v, block_table=table, lens=lens,
                                  ssm=ssm, conv=conv)
            logits, st = decode_step_paged(params, cfg, tokens, st,
                                           attn_impl=impl, interpret=interp)
            toks = sample(logits, cfg, key,
                          temperature=0.0 if greedy else 1.0)
            lens_full = lens_full.at[slots].add(1).at[trash].set(0)
            if ssm_full is not None:
                ssm_full = ssm_full.at[:, slots].set(st.ssm)
                conv_full = conv_full.at[:, slots].set(st.conv)
            return toks, st.k, st.v, lens_full, ssm_full, conv_full

        # donate the pools/state so XLA updates pages in place (no-op on CPU)
        donate = (1, 2, 4, 5, 6) if jax.default_backend() != "cpu" else ()
        return jax.jit(fused, static_argnames=("n_pages",),
                       donate_argnums=donate)

    # -- submission ------------------------------------------------------------

    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int) -> None:
        self.waiting.append(EngineRequest(rid, np.asarray(prompt, np.int32),
                                          max_new_tokens))

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.max_seqs) if s not in self.active]

    # -- scheduling ------------------------------------------------------------

    def _admit(self) -> list[EngineRequest]:
        """Move waiting requests into free slots while KV blocks remain."""
        admitted = []
        free = self._free_slots()
        while self.waiting and free:
            req = self.waiting[0]
            if not self.cache.can_admit(len(req.prompt)):
                break
            self.waiting.pop(0)
            req.slot = free.pop(0)
            self.cache.admit(req.slot, len(req.prompt))
            self.active[req.slot] = req
            admitted.append(req)
        return admitted

    def _run_prefill(self, reqs: list[EngineRequest]) -> None:
        # bucket by prompt length: same-length batches need no padding, so
        # RoPE positions stay exact for every sequence
        by_len: dict[int, list[EngineRequest]] = {}
        for r in reqs:
            by_len.setdefault(len(r.prompt), []).append(r)
        for pl, group in by_len.items():
            toks = np.stack([r.prompt for r in group])
            logits, cache = self._prefill(self.params, jnp.asarray(toks))
            first = self._pick(logits)           # one sync per prefill group
            for i, r in enumerate(group):
                if self.cfg.has_attn:
                    self.cache.write_prefill(r.slot, cache.k[:, i],
                                             cache.v[:, i])
                if self.cfg.has_ssm:
                    self.cache.ssm = self.cache.ssm.at[:, r.slot].set(
                        cache.ssm[:, i])
                    self.cache.conv = self.cache.conv.at[:, r.slot].set(
                        cache.conv[:, i])
                r.generated.append(int(first[i]))
                self.tokens_out += 1

    def _pick(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(sample(logits, self.cfg, self.key))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(sample(logits, self.cfg, sub, temperature=1.0))

    # -- decode paths ----------------------------------------------------------

    def _run_decode(self, slots: list[int]) -> None:
        """Device-resident paged decode over the given slots (gather-free)."""
        slots = sorted(slots)
        for s in slots:                      # page capacity for the new token
            self.cache.extend(s)
        B = len(slots)
        bucket = _pow2_bucket(B, self.max_seqs)
        trash = self.cache.trash_slot
        pad = bucket - B
        slot_arr = np.array(slots + [trash] * pad, np.int32)
        last = np.array([self.active[s].generated[-1] for s in slots]
                        + [0] * pad, np.int32)
        bs = self.cache.block_size
        need = (int(self.cache.seq_lens[slots].max()) + bs - 1) // bs
        n_pages = _pow2_bucket(need, self.cache.max_blocks_per_seq)
        if self.greedy:
            sub = self.key
        else:
            self.key, sub = jax.random.split(self.key)
        toks, k, v, lens_dev, ssm, conv = self._fused(
            self.params, self.cache.k, self.cache.v,
            self.cache.block_table_dev, self.cache.seq_lens_dev,
            self.cache.ssm, self.cache.conv,
            jnp.asarray(slot_arr), jnp.asarray(last), sub, n_pages=n_pages)
        self.cache.k, self.cache.v = k, v
        self.cache.seq_lens_dev = lens_dev
        self.cache.ssm, self.cache.conv = ssm, conv
        toks = np.asarray(toks)              # the one device->host transfer
        for i, s in enumerate(slots):
            r = self.active[s]
            r.generated.append(int(toks[i]))
            self.tokens_out += 1

    def _run_decode_dense(self, slots: list[int]) -> None:
        """Legacy dense-gather decode (A/B baseline for bench_engine)."""
        slots = np.array(sorted(slots), np.int32)
        B = len(slots)
        lens = self.cache.seq_lens[slots].copy()
        max_len = int(lens.max()) + 1
        last = np.array([self.active[s].generated[-1] for s in slots], np.int32)
        if self.cfg.has_attn:
            k, v, _ = self.cache.gather_dense(slots, max_len)
        else:
            k = v = None
        ssm = self.cache.ssm[:, slots] if self.cache.ssm is not None else None
        conv = self.cache.conv[:, slots] if self.cache.conv is not None else None
        dc = DecodeCache(k=k, v=v, ssm=ssm, conv=conv,
                         pos=jnp.asarray(lens, jnp.int32))
        logits, new_cache = self._decode(self.params, jnp.asarray(last), dc)
        toks = self._pick(logits)
        # persist the new KV token + SSM state back into the pool
        for s in slots:
            self.cache.extend(int(s))
        if self.cfg.has_attn:
            bidx = jnp.arange(B)
            k_new = new_cache.k[:, bidx, jnp.asarray(lens)]   # [L, B, H, D]
            v_new = new_cache.v[:, bidx, jnp.asarray(lens)]
            self.cache.write_token(slots, k_new, v_new, lens)
        if self.cfg.has_ssm:
            self.cache.ssm = self.cache.ssm.at[:, slots].set(new_cache.ssm)
            self.cache.conv = self.cache.conv.at[:, slots].set(new_cache.conv)
        for i, s in enumerate(slots):
            r = self.active[int(s)]
            r.generated.append(int(toks[i]))
            self.tokens_out += 1

    def _retire(self) -> list[EngineRequest]:
        done = []
        for s in list(self.active):
            r = self.active[s]
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
                self.cache.release_slot(s)
                del self.active[s]
                done.append(r)
        return done

    # -- main loop ---------------------------------------------------------------

    def step(self) -> list[EngineRequest]:
        """One scheduler iteration; returns requests finished this step.

        Prefill and decode interleave: sequences that were already active
        still emit their decode token on a step that admits new prompts
        (newly admitted requests get their first token from prefill itself).
        """
        self.steps += 1
        decode_slots = list(self.active)
        admitted = self._admit()
        if admitted:
            self._run_prefill(admitted)
        if decode_slots:
            if self.decode_mode == "paged":
                self._run_decode(decode_slots)
            else:
                self._run_decode_dense(decode_slots)
        return self._retire()

    def run_to_completion(self, max_steps: int = 100_000
                          ) -> list[EngineRequest]:
        finished = []
        while (self.waiting or self.active) and self.steps < max_steps:
            finished.extend(self.step())
        return finished
