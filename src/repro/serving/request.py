"""Requests and Azure-Public-Dataset-like trace synthesis.

The paper evaluates on two traces sampled from real Azure LLM inference logs
(Patel et al., 2024): heterogeneous (input_len, output_len) mixes whose
composition and arrival rate drift over time (Fig. 2/8).  No real traces ship
offline, so ``synthesize_trace`` generates seeded traces with the same
qualitative structure: k workload archetypes with diurnal/shifting mixture
weights and Poisson arrivals, scaled so the cluster is neither over- nor
under-provisioned (the paper's protocol).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float          # seconds
    in_len: int
    out_len: int
    type_id: int = -1       # k-means label, filled by the clusterer
    # per-type SLO budgets (seconds; inf = unconstrained).  TTFT bounds the
    # wait + prefill; TPOT bounds the mean inter-token gap during decode —
    # the goodput / SLO-attainment metrics count only requests within both.
    ttft_budget: float = float("inf")
    tpot_budget: float = float("inf")
    # scheduling priority (higher = more important; 0 = best-effort).
    # Plumbed through ``submit`` on engine and cluster: priority orders
    # admission, and the cluster rebalancer preempts (relocates/evicts)
    # lower-priority sequences before a higher-priority request sheds.
    priority: int = 0
    # bookkeeping (simulator)
    replica: int = -1
    start: float = -1.0
    first_token: float = -1.0
    finish: float = -1.0

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first."""
        return (self.finish - self.first_token) / max(self.out_len - 1, 1)

    @property
    def slo_met(self) -> bool:
        return (self.finish >= 0 and self.first_token >= 0
                and self.ttft <= self.ttft_budget
                and self.tpot <= self.tpot_budget)


def apply_slo_budgets(requests: list["Request"],
                      ttft_base: float = 10.0,
                      ttft_per_token: float = 0.01,
                      tpot_interactive: float = 0.06,
                      tpot_batch: float = 0.12,
                      interactive_out: int = 256) -> list["Request"]:
    """Attach per-type latency budgets (seeds SLO-aware admission).

    TTFT budgets scale with prompt length (prefill is paid inside them);
    TPOT budgets are tighter for short-output (interactive) types than for
    long-generation (batch-ish) ones, mirroring how serving SLOs are
    usually quoted.  Defaults sit near the calibrated simulator's p90s, so
    attainment separates policies instead of saturating at 1.0.  Returns
    the same list for chaining.
    """
    for r in requests:
        r.ttft_budget = ttft_base + ttft_per_token * r.in_len
        r.tpot_budget = (tpot_interactive if r.out_len <= interactive_out
                         else tpot_batch)
    return requests


def assign_priorities(requests: list["Request"], high_frac: float = 0.25,
                      high: int = 1, seed: int = 0) -> list["Request"]:
    """Mark a seeded fraction of requests high-priority (the priority-mix
    trace used by the rebalance benchmarks/tests).  Returns the same list
    for chaining."""
    rng = np.random.RandomState(seed)
    for r in requests:
        r.priority = high if rng.rand() < high_frac else 0
    return requests


# Archetypes roughly matching the paper's taxonomy (S2): chat / extraction
# (short out), summarization (long in, short out), generation (long out),
# reasoning/transformation (long in + long out).
ARCHETYPES = [
    {"in": (128, 0.6), "out": (128, 0.5)},     # chat
    {"in": (1536, 0.5), "out": (96, 0.5)},     # summarize / extract
    {"in": (256, 0.6), "out": (1024, 0.5)},    # generate
    {"in": (1024, 0.5), "out": (1024, 0.5)},   # transform / reason
]


def _mix_over_time(n_spans: int, trace_id: int, rng) -> np.ndarray:
    """[n_spans, K] mixture weights with trace-specific fluctuation trends."""
    t = np.arange(n_spans)
    K = len(ARCHETYPES)
    if trace_id == 1:
        # regime shift (paper Fig. 8, T1): business-hours short-task dominance
        # giving way to evening long-output dominance
        w = np.zeros((n_spans, K))
        half = n_spans // 2
        w[:half] = [0.15, 0.70, 0.05, 0.10]
        w[half:] = [0.10, 0.10, 0.45, 0.35]
        ramp = min(max(n_spans // 8, 2), half)
        for i in range(ramp):
            a = i / ramp
            w[half - ramp // 2 + i] = ((1 - a) * np.array([0.15, 0.7, 0.05, 0.1])
                                       + a * np.array([0.1, 0.1, 0.45, 0.35]))
    elif trace_id == 2:
        # fast alternation between regimes (paper T2)
        w = np.zeros((n_spans, K))
        period = max(n_spans // 5, 4)
        for s in range(n_spans):
            if (s // period) % 2 == 0:
                w[s] = [0.15, 0.65, 0.08, 0.12]
            else:
                w[s] = [0.10, 0.15, 0.40, 0.35]
    else:
        # smooth sinusoidal mixing (stress test for the predictor)
        phases = [0.0, 0.7, np.pi, np.pi + 0.6]
        period = max(n_spans / 2, 30)
        w = np.stack([1.0 + 0.75 * np.sin(2 * np.pi * t / period + ph)
                      for ph in phases], axis=1)
    w = w + 0.05 * rng.randn(n_spans, K)
    w = np.clip(w, 0.02, None)
    return w / w.sum(1, keepdims=True)


def trace_mixes(n_spans: int, trace_id: int, seed: int = 0) -> np.ndarray:
    """[n_spans, K] archetype mixture weights for a trace (deterministic)."""
    rng = np.random.RandomState(seed + 1000 * trace_id)
    return _mix_over_time(n_spans, trace_id, rng)


def synthesize_trace(n_spans: int, mean_rate: float, trace_id: int = 1,
                     seed: int = 0, span_seconds: float = 60.0,
                     rate_per_span: np.ndarray | None = None
                     ) -> list[Request]:
    """Requests over `n_spans` spans.

    ``rate_per_span`` overrides the mean rate per span — the paper scales the
    arrival rate each minute so the cluster stays neither over- nor
    under-utilized as the mix shifts (short-task regimes sustain much higher
    request rates than long-output regimes).
    """
    rng = np.random.RandomState(seed + 1000 * trace_id)
    mix = _mix_over_time(n_spans, trace_id, rng)
    envelope = 1.0 + 0.1 * np.sin(
        2 * np.pi * np.arange(n_spans) / max(n_spans / 3, 20) + trace_id)
    requests: list[Request] = []
    rid = 0
    for s in range(n_spans):
        if rate_per_span is not None:
            lam = float(rate_per_span[s]) * envelope[s]
        else:
            lam = mean_rate * envelope[s]
        n = rng.poisson(lam)
        comp = rng.choice(len(ARCHETYPES), size=n, p=mix[s])
        times = np.sort(rng.uniform(0, span_seconds, size=n))
        for i in range(n):
            a = ARCHETYPES[comp[i]]
            in_len = max(8, int(rng.lognormal(np.log(a["in"][0]), a["in"][1])))
            out_len = max(4, int(rng.lognormal(np.log(a["out"][0]), a["out"][1])))
            requests.append(Request(
                rid=rid, arrival=s * span_seconds + times[i],
                in_len=min(in_len, 8000), out_len=min(out_len, 5000)))
            rid += 1
    return requests


def span_of(req: Request, span_seconds: float = 60.0) -> int:
    return int(req.arrival // span_seconds)


def shared_prefix_prompts(n: int, prefix_len: int, unique_len: int,
                          n_templates: int = 1, vocab: int = 1000,
                          seed: int = 0) -> list[np.ndarray]:
    """Prompt token streams with heavy shared prefixes (system prompts /
    few-shot templates), the traffic shape the prefix cache exists for.

    Each prompt is one of ``n_templates`` fixed template prefixes of
    ``prefix_len`` tokens followed by a per-request unique suffix of
    ``unique_len`` tokens; requests round-robin over the templates.  With a
    warm cache only the suffix (plus the template's first pass) prefills —
    ``benchmarks/bench_prefix.py`` measures exactly that ratio.
    """
    rng = np.random.RandomState(seed)
    templates = [rng.randint(0, vocab, prefix_len).astype(np.int32)
                 for _ in range(n_templates)]
    return [np.concatenate([templates[i % n_templates],
                            rng.randint(0, vocab, unique_len).astype(np.int32)])
            for i in range(n)]
