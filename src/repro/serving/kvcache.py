"""Paged KV cache (vLLM-style) in JAX — device-resident decode metadata.

Storage: per layer-stacked pools ``k/v: [L, num_blocks + 1, Hkv, block,
D]`` in kernel-native layout (the Pallas paged-decode kernel and the jnp
fallback both read ``[page, Hkv, block, D]`` tiles without a transpose).
Physical block ``num_blocks`` is a trash page: padded batch slots scatter
their dummy K/V there, so the fused decode step needs no masking branches.

The host-side ``BlockAllocator`` remains the source of truth for block
ownership; ``block_table``/``seq_lens`` (host numpy) mirror it for the
scheduler.  Device-resident copies ``block_table_dev [max_seqs + 1,
max_blocks_per_seq]`` and ``seq_lens_dev [max_seqs + 1]`` are synced
*incrementally* — one small scatter on admit / page-crossing / release —
never re-uploaded wholesale per step.  Row ``max_seqs`` is the trash slot
(points at the trash page) used to pad decode batches to bucket sizes.

``gather_dense`` survives only for the legacy dense-gather decode path and
parity tests; the serving decode path consumes pages directly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class BlockAllocator:
    """Host-side free-list of physical blocks (+ copy-on-write ready refcounts)."""

    def __init__(self, num_blocks: int):
        self.free = list(range(num_blocks - 1, -1, -1))
        self.refs = np.zeros(num_blocks, np.int32)

    def alloc(self, n: int) -> list[int]:
        if len(self.free) < n:
            raise MemoryError(f"KV pool exhausted (need {n}, "
                              f"have {len(self.free)})")
        out = [self.free.pop() for _ in range(n)]
        for b in out:
            self.refs[b] = 1
        return out

    def release(self, blocks: list[int]) -> None:
        for b in blocks:
            self.refs[b] -= 1
            if self.refs[b] <= 0:
                self.refs[b] = 0
                self.free.append(b)

    def share(self, blocks: list[int]) -> None:
        """Prefix sharing: bump refcounts (copy-on-write on append)."""
        for b in blocks:
            self.refs[b] += 1

    @property
    def n_free(self) -> int:
        return len(self.free)


@dataclasses.dataclass
class PagedKVCache:
    cfg: ModelConfig
    block_size: int
    num_blocks: int
    max_seqs: int
    max_blocks_per_seq: int
    k: jax.Array        # [L, num_blocks + 1, Hkv, block, D] (+1 = trash page)
    v: jax.Array
    ssm: jax.Array | None       # [L, max_seqs + 1, ...] (+1 = trash row)
    conv: jax.Array | None
    block_table: np.ndarray     # host [max_seqs, max_blocks_per_seq] int32
    seq_lens: np.ndarray        # host [max_seqs] int32
    block_table_dev: jax.Array  # device [max_seqs + 1, max_blocks_per_seq]
    seq_lens_dev: jax.Array     # device [max_seqs + 1]
    allocator: BlockAllocator
    seq_blocks: dict            # slot -> list[int]

    @classmethod
    def create(cls, cfg: ModelConfig, num_blocks: int = 256,
               block_size: int = 16, max_seqs: int = 16,
               max_blocks_per_seq: int = 64, dtype=jnp.float32,
               head_pad: int = 1) -> "PagedKVCache":
        L = cfg.n_layers
        k = v = ssm = conv = None
        if cfg.has_attn:
            # head_pad > 1 (the Pallas kernel path) pads head_dim once at
            # allocation so the per-step kernel call never re-pads the pool
            d_pool = -(-cfg.head_dim // head_pad) * head_pad
            shape = (L, num_blocks + 1, cfg.n_kv_heads, block_size, d_pool)
            k = jnp.zeros(shape, dtype)
            v = jnp.zeros(shape, dtype)
        if cfg.has_ssm:
            from repro.models.ssm import conv_channels
            ssm = jnp.zeros((L, max_seqs + 1, cfg.ssm_heads, cfg.ssm_head_dim,
                             cfg.ssm_state), jnp.float32)
            conv = jnp.zeros((L, max_seqs + 1, cfg.ssm_conv_width - 1,
                              conv_channels(cfg)), dtype)
        # device tables start pointing at the trash page so un-admitted /
        # padded rows gather zeros and scatter into the trash page
        table_dev = jnp.full((max_seqs + 1, max_blocks_per_seq), num_blocks,
                             jnp.int32)
        lens_dev = jnp.zeros((max_seqs + 1,), jnp.int32)
        return cls(cfg, block_size, num_blocks, max_seqs, max_blocks_per_seq,
                   k, v, ssm, conv,
                   np.zeros((max_seqs, max_blocks_per_seq), np.int32),
                   np.zeros(max_seqs, np.int32),
                   table_dev, lens_dev,
                   BlockAllocator(num_blocks), {})

    @property
    def trash_slot(self) -> int:
        """Device table/lens row used to pad decode batches to bucket size."""
        return self.max_seqs

    # -- slot lifecycle -------------------------------------------------------

    def admit(self, slot: int, prompt_len: int) -> None:
        n = (prompt_len + self.block_size - 1) // self.block_size
        blocks = self.allocator.alloc(n)
        self.seq_blocks[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, :n] = blocks
        self.seq_lens[slot] = prompt_len
        # incremental device sync: one row scatter per admission
        row = np.full(self.max_blocks_per_seq, self.num_blocks, np.int32)
        row[:n] = blocks
        self.block_table_dev = self.block_table_dev.at[slot].set(
            jnp.asarray(row))
        self.seq_lens_dev = self.seq_lens_dev.at[slot].set(prompt_len)

    def can_admit(self, prompt_len: int, headroom_blocks: int = 2) -> bool:
        n = (prompt_len + self.block_size - 1) // self.block_size
        return self.allocator.n_free >= n + headroom_blocks

    def extend(self, slot: int) -> None:
        """Ensure capacity for one more token.

        The host length advances here; the device ``seq_lens_dev`` row
        advances inside the fused decode step (one scatter-add for the whole
        batch), keeping the two in lockstep without per-sequence transfers.
        """
        new_len = int(self.seq_lens[slot]) + 1
        n_have = len(self.seq_blocks[slot])
        if new_len > n_have * self.block_size:
            if n_have >= self.max_blocks_per_seq:
                raise MemoryError("sequence exceeds max_blocks_per_seq")
            b = self.allocator.alloc(1)[0]
            self.seq_blocks[slot].append(b)
            self.block_table[slot, n_have] = b
            # incremental device sync: single-element scatter on page crossing
            self.block_table_dev = self.block_table_dev.at[slot, n_have].set(b)
        self.seq_lens[slot] = new_len

    def release_slot(self, slot: int) -> None:
        self.allocator.release(self.seq_blocks.pop(slot, []))
        self.seq_lens[slot] = 0
        self.block_table[slot, :] = 0
        self.block_table_dev = self.block_table_dev.at[slot].set(
            self.num_blocks)
        self.seq_lens_dev = self.seq_lens_dev.at[slot].set(0)

    # -- device views ----------------------------------------------------------

    def write_prefill(self, slot: int, k_seq: jax.Array, v_seq: jax.Array
                      ) -> None:
        """k_seq/v_seq: [L, S, Hkv, D] from prefill; scattered into pages."""
        S = k_seq.shape[1]
        bs = self.block_size
        n = (S + bs - 1) // bs
        pad = n * bs - S
        dpad = self.k.shape[-1] - k_seq.shape[-1]
        if pad or dpad:
            k_seq = jnp.pad(k_seq, ((0, 0), (0, pad), (0, 0), (0, dpad)))
            v_seq = jnp.pad(v_seq, ((0, 0), (0, pad), (0, 0), (0, dpad)))
        kb = k_seq.reshape(k_seq.shape[0], n, bs, *k_seq.shape[2:])
        vb = v_seq.reshape(v_seq.shape[0], n, bs, *v_seq.shape[2:])
        kb = jnp.swapaxes(kb, 2, 3)          # [L, n, Hkv, bs, D] native
        vb = jnp.swapaxes(vb, 2, 3)
        idx = jnp.asarray(self.seq_blocks[slot], jnp.int32)
        self.k = self.k.at[:, idx].set(kb.astype(self.k.dtype))
        self.v = self.v.at[:, idx].set(vb.astype(self.v.dtype))

    def write_token(self, slots: np.ndarray, k_new: jax.Array,
                    v_new: jax.Array, positions: np.ndarray) -> None:
        """k_new/v_new: [L, B, Hkv, D] for one token per active slot."""
        blk = self.block_table[slots, positions // self.block_size]
        off = positions % self.block_size
        blk = jnp.asarray(blk)
        off = jnp.asarray(off)
        # pool is [L, P, Hkv, block, D]: non-adjacent advanced indices put
        # the batch dim first, so updates arrive as [B, L, Hkv, D]
        dpad = self.k.shape[-1] - k_new.shape[-1]
        if dpad:
            k_new = jnp.pad(k_new, ((0, 0),) * 3 + ((0, dpad),))
            v_new = jnp.pad(v_new, ((0, 0),) * 3 + ((0, dpad),))
        kv = jnp.moveaxis(k_new, 0, 1).astype(self.k.dtype)
        vv = jnp.moveaxis(v_new, 0, 1).astype(self.v.dtype)
        self.k = self.k.at[:, blk, :, off].set(kv)
        self.v = self.v.at[:, blk, :, off].set(vv)

    def gather_dense(self, slots: np.ndarray, max_len: int
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Materialize [L, B, max_len, Hkv, D] dense caches (legacy
        dense-gather decode path and parity tests only — the serving decode
        path reads pages in place via the block table)."""
        bs = self.block_size
        n_blocks = (max_len + bs - 1) // bs
        table = jnp.asarray(self.block_table[slots, :n_blocks])   # [B, n]
        k = self.k[:, table]          # [L, B, n, Hkv, bs, D]
        v = self.v[:, table]
        L, B = k.shape[0], k.shape[1]
        k = jnp.swapaxes(k, 3, 4)     # [L, B, n, bs, Hkv, D]
        v = jnp.swapaxes(v, 3, 4)
        k = k.reshape(L, B, n_blocks * bs, *k.shape[4:])[:, :, :max_len]
        v = v.reshape(L, B, n_blocks * bs, *v.shape[4:])[:, :, :max_len]
        D = self.cfg.head_dim
        k, v = k[..., :D], v[..., :D]   # drop kernel head_pad columns
        lens = jnp.asarray(self.seq_lens[slots])
        return k, v, lens
