"""Paged KV cache (vLLM-style) in JAX.

Storage: per layer-stacked pools ``k/v: [L, num_blocks, block_size, Hkv, D]``
plus a host-side block allocator.  Sequences own block lists; the device-side
``block_table [max_seqs, max_blocks_per_seq]`` maps slot x logical-block ->
physical block.  The decode path gathers pages (jnp path here; the Pallas
flash-decode kernel consumes the same table layout).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class BlockAllocator:
    """Host-side free-list of physical blocks (+ copy-on-write ready refcounts)."""

    def __init__(self, num_blocks: int):
        self.free = list(range(num_blocks - 1, -1, -1))
        self.refs = np.zeros(num_blocks, np.int32)

    def alloc(self, n: int) -> list[int]:
        if len(self.free) < n:
            raise MemoryError(f"KV pool exhausted (need {n}, "
                              f"have {len(self.free)})")
        out = [self.free.pop() for _ in range(n)]
        for b in out:
            self.refs[b] = 1
        return out

    def release(self, blocks: list[int]) -> None:
        for b in blocks:
            self.refs[b] -= 1
            if self.refs[b] <= 0:
                self.refs[b] = 0
                self.free.append(b)

    def share(self, blocks: list[int]) -> None:
        """Prefix sharing: bump refcounts (copy-on-write on append)."""
        for b in blocks:
            self.refs[b] += 1

    @property
    def n_free(self) -> int:
        return len(self.free)


@dataclasses.dataclass
class PagedKVCache:
    cfg: ModelConfig
    block_size: int
    num_blocks: int
    max_seqs: int
    max_blocks_per_seq: int
    k: jax.Array        # [L, num_blocks, block, Hkv, D]
    v: jax.Array
    ssm: jax.Array | None
    conv: jax.Array | None
    block_table: np.ndarray     # host [max_seqs, max_blocks_per_seq] int32
    seq_lens: np.ndarray        # host [max_seqs] int32
    allocator: BlockAllocator
    seq_blocks: dict            # slot -> list[int]

    @classmethod
    def create(cls, cfg: ModelConfig, num_blocks: int = 256,
               block_size: int = 16, max_seqs: int = 16,
               max_blocks_per_seq: int = 64, dtype=jnp.float32
               ) -> "PagedKVCache":
        L = cfg.n_layers
        k = v = ssm = conv = None
        if cfg.has_attn:
            shape = (L, num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
            k = jnp.zeros(shape, dtype)
            v = jnp.zeros(shape, dtype)
        if cfg.has_ssm:
            from repro.models.ssm import conv_channels
            ssm = jnp.zeros((L, max_seqs, cfg.ssm_heads, cfg.ssm_head_dim,
                             cfg.ssm_state), jnp.float32)
            conv = jnp.zeros((L, max_seqs, cfg.ssm_conv_width - 1,
                              conv_channels(cfg)), dtype)
        return cls(cfg, block_size, num_blocks, max_seqs, max_blocks_per_seq,
                   k, v, ssm, conv,
                   np.zeros((max_seqs, max_blocks_per_seq), np.int32),
                   np.zeros(max_seqs, np.int32),
                   BlockAllocator(num_blocks), {})

    # -- slot lifecycle -------------------------------------------------------

    def admit(self, slot: int, prompt_len: int) -> None:
        n = (prompt_len + self.block_size - 1) // self.block_size
        blocks = self.allocator.alloc(n)
        self.seq_blocks[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, :n] = blocks
        self.seq_lens[slot] = prompt_len

    def can_admit(self, prompt_len: int, headroom_blocks: int = 2) -> bool:
        n = (prompt_len + self.block_size - 1) // self.block_size
        return self.allocator.n_free >= n + headroom_blocks

    def extend(self, slot: int) -> None:
        """Ensure capacity for one more token."""
        new_len = int(self.seq_lens[slot]) + 1
        n_have = len(self.seq_blocks[slot])
        if new_len > n_have * self.block_size:
            if n_have >= self.max_blocks_per_seq:
                raise MemoryError("sequence exceeds max_blocks_per_seq")
            b = self.allocator.alloc(1)[0]
            self.seq_blocks[slot].append(b)
            self.block_table[slot, n_have] = b
        self.seq_lens[slot] = new_len

    def release_slot(self, slot: int) -> None:
        self.allocator.release(self.seq_blocks.pop(slot, []))
        self.seq_lens[slot] = 0
        self.block_table[slot, :] = 0

    # -- device views ----------------------------------------------------------

    def write_prefill(self, slot: int, k_seq: jax.Array, v_seq: jax.Array
                      ) -> None:
        """k_seq/v_seq: [L, S, Hkv, D] from prefill; scattered into pages."""
        S = k_seq.shape[1]
        bs = self.block_size
        n = (S + bs - 1) // bs
        pad = n * bs - S
        if pad:
            k_seq = jnp.pad(k_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_seq = jnp.pad(v_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kb = k_seq.reshape(k_seq.shape[0], n, bs, *k_seq.shape[2:])
        vb = v_seq.reshape(v_seq.shape[0], n, bs, *v_seq.shape[2:])
        idx = jnp.asarray(self.seq_blocks[slot], jnp.int32)
        self.k = self.k.at[:, idx].set(kb.astype(self.k.dtype))
        self.v = self.v.at[:, idx].set(vb.astype(self.v.dtype))

    def write_token(self, slots: np.ndarray, k_new: jax.Array,
                    v_new: jax.Array, positions: np.ndarray) -> None:
        """k_new/v_new: [L, B, Hkv, D] for one token per active slot."""
        blk = self.block_table[slots, positions // self.block_size]
        off = positions % self.block_size
        blk = jnp.asarray(blk)
        off = jnp.asarray(off)
        self.k = self.k.at[:, blk, off].set(k_new.astype(self.k.dtype))
        self.v = self.v.at[:, blk, off].set(v_new.astype(self.v.dtype))

    def gather_dense(self, slots: np.ndarray, max_len: int
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Materialize [L, B, max_len, Hkv, D] dense caches for the jnp decode
        path (the Pallas kernel reads pages directly instead)."""
        bs = self.block_size
        n_blocks = (max_len + bs - 1) // bs
        table = jnp.asarray(self.block_table[slots, :n_blocks])   # [B, n]
        k = self.k[:, table]          # [L, B, n, bs, H, D]
        v = self.v[:, table]
        L, B = k.shape[0], k.shape[1]
        k = k.reshape(L, B, n_blocks * bs, *k.shape[4:])[:, :, :max_len]
        v = v.reshape(L, B, n_blocks * bs, *v.shape[4:])[:, :, :max_len]
        lens = jnp.asarray(self.seq_lens[slots])
        return k, v, lens
