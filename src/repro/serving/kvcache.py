"""Paged KV cache (vLLM-style) in JAX — device-resident decode metadata.

Storage: ``BlockPool`` owns the per layer-stacked pools ``k/v: [L,
num_blocks + 1, Hkv, block, D]`` in kernel-native layout (the Pallas
paged-decode kernel and the jnp fallback both read ``[page, Hkv, block, D]``
tiles without a transpose) plus the host-side ``BlockAllocator``.  Physical
block ``num_blocks`` is a trash page: padded batch slots scatter their dummy
K/V there, so the fused decode step needs no masking branches.

A ``PagedKVCache`` is one replica's *view* of a pool: per-slot block tables,
sequence lengths, and SSM state.  ``PagedKVCache.create`` builds a private
pool (single-replica engines, unchanged seed behavior);
``PagedKVCache.from_pool`` attaches to a shared pool so N replicas of a
``ClusterRuntime`` partition one device allocation instead of each reserving
a max-size cache.  A shared view carries a block ``quota`` — its slice of
the pool — so one replica cannot starve the others.  Enforcement is by
*reservation*: ``admit(slot, prompt_len, total_tokens)`` reserves the
sequence's full lifetime block count (prompt + decode growth) against both
the view quota and the pool, so later ``extend`` calls always draw from
already-reserved capacity and in-quota decode can never exhaust a sibling
replica's share.  The allocator stays the single source of truth for
physical ownership.

The host-side ``BlockAllocator`` remains the source of truth for block
ownership; ``block_table``/``seq_lens`` (host numpy) mirror it for the
scheduler.  Device-resident copies ``block_table_dev [max_seqs + 1,
max_blocks_per_seq]`` and ``seq_lens_dev [max_seqs + 1]`` are synced
*incrementally* — one small scatter on admit / page-crossing / release —
never re-uploaded wholesale per step.  Row ``max_seqs`` is the trash slot
(points at the trash page) used to pad decode batches to bucket sizes.

Migration primitives (``repro.serving.migration`` builds on these):
``disown_slot`` removes a sequence from a view's accounting *without*
returning its blocks to the allocator, so a sibling view over the same pool
can ``adopt_slot`` them — a deployment switch then moves a sequence's KV by
re-registering page ownership instead of copying (zero tokens recomputed).
``copy_blocks`` is the jitted pool-to-pool page gather/scatter for
migrations that cross pools; ``gather_tokens`` + ``scatter_tokens`` re-
layout a sequence between pools whose page geometry differs.

``gather_dense`` survives only for the legacy dense-gather decode path and
parity tests; the serving decode path consumes pages directly.

Prefix sharing (``repro.serving.prefixcache`` builds on these): ``admit``
can attach already-resident pages by refcount (``shared_blocks``) and
copy-on-write a partially-matched page (``cow_src``); such pages are
*counted once* — they never enter a view's reservation or ``used_blocks``,
and every teardown path (``release_slot``/``disown_slot``/migration)
decrefs through ``BlockAllocator.release`` instead of freeing, so a page
survives as long as any sequence or the cache index references it.  A
``PrefixCache`` attached to ``BlockPool.prefix_cache`` is consulted under
allocation pressure (``BlockPool.reclaim``) to evict cold cached pages to
host memory — see the prefixcache module docstring for hashing granularity,
the refcount lifecycle, and the eviction policy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


class BlockAllocator:
    """Host-side free-list of physical blocks (+ copy-on-write ready refcounts)."""

    def __init__(self, num_blocks: int):
        self.free = list(range(num_blocks - 1, -1, -1))
        self.refs = np.zeros(num_blocks, np.int32)
        # blocks held by more than one owner (prefix sharing): they occupy
        # physical capacity outside any single sequence's reservation, so
        # reservation headroom must subtract them — see n_free_blocks
        self.pinned = 0

    def alloc(self, n: int) -> list[int]:
        if len(self.free) < n:
            raise MemoryError(f"KV pool exhausted (need {n}, "
                              f"have {len(self.free)})")
        out = [self.free.pop() for _ in range(n)]
        for b in out:
            self.refs[b] = 1
        return out

    def release(self, blocks: list[int]) -> None:
        for b in blocks:
            self.refs[b] -= 1
            if self.refs[b] == 1:
                self.pinned -= 1
            if self.refs[b] <= 0:
                self.refs[b] = 0
                self.free.append(b)

    def share(self, blocks: list[int]) -> None:
        """Prefix sharing: bump refcounts (copy-on-write on append)."""
        for b in blocks:
            self.refs[b] += 1
            if self.refs[b] == 2:
                self.pinned += 1

    @property
    def n_free(self) -> int:
        return len(self.free)


class BlockPool:
    """Device K/V block pool + allocator, shareable across replica caches.

    Replica caches read and functionally update ``pool.k`` / ``pool.v``
    through their ``PagedKVCache.k`` properties; because a host scheduler
    steps replicas sequentially, every view always sees the latest arrays.
    """

    def __init__(self, cfg: ModelConfig, num_blocks: int,
                 block_size: int = 16, dtype=jnp.float32, head_pad: int = 1,
                 mesh=None, kv_spec=None, rules: dict | None = None):
        """``mesh`` + ``kv_spec`` (a ``PartitionSpec`` over the pool layout
        ``[L, P + 1, Hkv, page, D]`` — see ``launch.sharding.pool_pspecs``)
        place the pool sharded over one serving replica's device mesh:
        KV heads over tp, layers over pp.  Block ids and the allocator are
        untouched — a page is a page whatever its head sharding.  ``rules``
        (the plan's logical-axis rules) additionally shard replica SSM state
        created by ``PagedKVCache.from_pool``."""
        self.cfg = cfg
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.dtype = dtype
        self.head_pad = head_pad
        self.mesh = mesh
        self.kv_spec = kv_spec
        self.rules = rules or {}
        self.k = self.v = None
        if cfg.has_attn:
            # head_pad > 1 (the Pallas kernel path) pads head_dim once at
            # allocation so the per-step kernel call never re-pads the pool
            d_pool = -(-cfg.head_dim // head_pad) * head_pad
            shape = (cfg.n_layers, num_blocks + 1, cfg.n_kv_heads,
                     block_size, d_pool)
            self.k = jnp.zeros(shape, dtype)
            self.v = jnp.zeros(shape, dtype)
            if mesh is not None:
                sh = NamedSharding(mesh, kv_spec if kv_spec is not None
                                   else P())
                self.k = jax.device_put(self.k, sh)
                self.v = jax.device_put(self.v, sh)
        self.allocator = BlockAllocator(num_blocks)
        self.reserved = 0           # blocks promised to admitted sequences
        self.prefix_cache = None    # set by PrefixCache.__init__ when enabled

    def reclaim(self, n: int) -> None:
        """Make room for an ``n``-block allocation by evicting cold cached
        pages to the host tier (no-op without a prefix cache, or when the
        free list already covers the request)."""
        if self.prefix_cache is not None and self.allocator.n_free < n:
            self.prefix_cache.reclaim(n)

    @property
    def trash_page(self) -> int:
        return self.num_blocks

    @property
    def placement(self):
        """Device placement + sharding identity: two pools with equal
        placement can exchange pages by the jitted same-mesh copy; unequal
        placements must go through ``reshard_blocks``."""
        if self.mesh is None:
            return None
        return (self.mesh, self.kv_spec)

    @property
    def page_nbytes(self) -> int:
        """Device bytes one K+V page holds across all layers: one page in
        both k and v is [L, Hkv, block, D] at pool dtype (0 for attn-free
        archs).  Telemetry and the prefix cache's host tier both size
        transfers with this."""
        if self.k is None:
            return 0
        per = int(np.prod(self.k.shape[2:])) * self.k.dtype.itemsize
        return 2 * per * int(self.k.shape[0])


@dataclasses.dataclass
class PagedKVCache:
    cfg: ModelConfig
    block_size: int
    num_blocks: int             # pool-wide physical block count
    max_seqs: int
    max_blocks_per_seq: int
    pool: BlockPool             # owns k/v [L, num_blocks + 1, Hkv, block, D]
    ssm: jax.Array | None       # [L, max_seqs + 1, ...] (+1 = trash row)
    conv: jax.Array | None
    block_table: np.ndarray     # host [max_seqs, max_blocks_per_seq] int32
    seq_lens: np.ndarray        # host [max_seqs] int32
    block_table_dev: jax.Array  # device [max_seqs + 1, max_blocks_per_seq]
    seq_lens_dev: jax.Array     # device [max_seqs + 1]
    seq_blocks: dict            # slot -> list[int]
    quota: int | None = None    # shared pool: this view's block budget
    used_blocks: int = 0
    reserved_blocks: int = 0    # admitted sequences' lifetime reservations
    seq_reserved: dict = dataclasses.field(default_factory=dict)
    seq_shared: dict = dataclasses.field(default_factory=dict)
    # slot -> leading prefix-cache pages attached by refcount (counted once
    # pool-wide: excluded from this view's used/reserved accounting)

    @classmethod
    def create(cls, cfg: ModelConfig, num_blocks: int = 256,
               block_size: int = 16, max_seqs: int = 16,
               max_blocks_per_seq: int = 64, dtype=jnp.float32,
               head_pad: int = 1, mesh=None, kv_spec=None,
               rules: dict | None = None) -> "PagedKVCache":
        """Single-replica cache over a private pool."""
        pool = BlockPool(cfg, num_blocks, block_size, dtype, head_pad,
                         mesh=mesh, kv_spec=kv_spec, rules=rules)
        return cls.from_pool(pool, max_seqs, max_blocks_per_seq, quota=None)

    @classmethod
    def from_pool(cls, pool: BlockPool, max_seqs: int,
                  max_blocks_per_seq: int,
                  quota: int | None = None) -> "PagedKVCache":
        """A replica view over a (possibly shared) pool.

        ``quota`` caps how many pool blocks this view may hold at once; None
        means the whole pool (private-pool behavior).
        """
        cfg = pool.cfg
        L = cfg.n_layers
        ssm = conv = None
        if cfg.has_ssm:
            from repro.models.ssm import conv_channels
            ssm = jnp.zeros((L, max_seqs + 1, cfg.ssm_heads, cfg.ssm_head_dim,
                             cfg.ssm_state), jnp.float32)
            conv = jnp.zeros((L, max_seqs + 1, cfg.ssm_conv_width - 1,
                              conv_channels(cfg)), pool.dtype)
        # device tables start pointing at the trash page so un-admitted /
        # padded rows gather zeros and scatter into the trash page
        table_dev = jnp.full((max_seqs + 1, max_blocks_per_seq),
                             pool.trash_page, jnp.int32)
        lens_dev = jnp.zeros((max_seqs + 1,), jnp.int32)
        if pool.mesh is not None:
            # metadata replicates across the replica mesh; SSM state shards
            # by head (tp) / layer (pp) per the plan rules
            rep = NamedSharding(pool.mesh, P())
            table_dev = jax.device_put(table_dev, rep)
            lens_dev = jax.device_put(lens_dev, rep)
            r = pool.rules
            if ssm is not None:
                ssm = jax.device_put(ssm, NamedSharding(
                    pool.mesh,
                    P(r.get("layers"), None, r.get("ssm_heads"), None, None)))
                conv = jax.device_put(conv, NamedSharding(
                    pool.mesh, P(r.get("layers"), None, None, None)))
        return cls(cfg, pool.block_size, pool.num_blocks, max_seqs,
                   max_blocks_per_seq, pool, ssm, conv,
                   np.zeros((max_seqs, max_blocks_per_seq), np.int32),
                   np.zeros(max_seqs, np.int32),
                   table_dev, lens_dev, {}, quota)

    # -- pool delegation ------------------------------------------------------

    @property
    def k(self) -> jax.Array | None:
        return self.pool.k

    @k.setter
    def k(self, value) -> None:
        self.pool.k = value

    @property
    def v(self) -> jax.Array | None:
        return self.pool.v

    @v.setter
    def v(self, value) -> None:
        self.pool.v = value

    @property
    def allocator(self) -> BlockAllocator:
        return self.pool.allocator

    @property
    def n_free_blocks(self) -> int:
        """Blocks this view may still *reserve* (quota- and pool-limited).

        ``pinned`` blocks (multi-owner shared prefix pages) sit outside
        every sequence reservation but still occupy physical capacity, so
        they come off the pool headroom; *cold* cached pages do not — they
        are evicted on demand (``BlockPool.reclaim``), which is exactly how
        the prefix cache oversubscribes HBM.
        """
        n = (self.pool.num_blocks - self.pool.reserved
             - self.pool.allocator.pinned)
        if self.quota is not None:
            n = min(n, self.quota - self.reserved_blocks)
        return n

    def _blocks(self, tokens: int) -> int:
        return (tokens + self.block_size - 1) // self.block_size

    @property
    def trash_slot(self) -> int:
        """Device table/lens row used to pad decode batches to bucket size."""
        return self.max_seqs

    # -- slot lifecycle -------------------------------------------------------

    def admit(self, slot: int, prompt_len: int,
              total_tokens: int | None = None,
              shared_blocks: tuple | list = (),
              cow_src: int | None = None) -> None:
        """Admit one sequence: allocate its prompt blocks now and *reserve*
        its full lifetime block count (``total_tokens``, defaulting to just
        the prompt) so quota-respecting decode growth can never fail.

        ``shared_blocks`` are prefix-cache pages covering the sequence's
        leading full pages: attached by refcount (``allocator.share``), not
        allocated, and excluded from this view's reservation — a shared page
        costs the pool once no matter how many sequences read it.
        ``cow_src`` names a cached page the sequence diverges *inside*; its
        contents are copied into the first freshly-allocated (private) page
        so writes never touch the shared original.
        """
        n = self._blocks(prompt_len)
        s = len(shared_blocks)
        fresh = n - s
        reserve = max(n, self._blocks(total_tokens or prompt_len)) - s
        self.pool.reclaim(fresh)
        new_blocks = self.allocator.alloc(fresh)
        self.allocator.share(list(shared_blocks))
        if cow_src is not None:
            copy_blocks(self.pool, self.pool, [cow_src], [new_blocks[0]])
        blocks = list(shared_blocks) + new_blocks
        self.used_blocks += fresh
        self.reserved_blocks += reserve
        self.pool.reserved += reserve
        self.seq_reserved[slot] = reserve
        if s:
            self.seq_shared[slot] = s
        self.seq_blocks[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, :n] = blocks
        self.seq_lens[slot] = prompt_len
        # incremental device sync: one row scatter per admission
        row = np.full(self.max_blocks_per_seq, self.num_blocks, np.int32)
        row[:n] = blocks
        self.block_table_dev = self.block_table_dev.at[slot].set(
            jnp.asarray(row))
        self.seq_lens_dev = self.seq_lens_dev.at[slot].set(prompt_len)

    def can_admit(self, prompt_len: int, total_tokens: int | None = None,
                  headroom_blocks: int = 2,
                  shared_blocks: tuple | list = ()) -> bool:
        """With ``total_tokens`` (prompt + expected decode growth) the check
        is a firm reservation; without it, legacy prompt + headroom.

        ``shared_blocks`` (prefix-cache pages the admission would attach)
        are already resident, so they shrink the need — but any of them
        still *cold* (single-ref) leaves the evictable set on attach and
        must be paid for out of headroom once, by its first sharer; without
        that term a pool full of hot shared pages could approve more
        reservations than physical blocks can realize."""
        s = len(shared_blocks)
        refs = self.allocator.refs
        pin = sum(1 for b in shared_blocks if refs[b] == 1)
        if total_tokens is not None:
            need = max(self._blocks(prompt_len),
                       self._blocks(total_tokens)) - s + pin
            return self.n_free_blocks >= need
        return (self.n_free_blocks
                >= self._blocks(prompt_len) - s + pin + headroom_blocks)

    def extend(self, slot: int) -> None:
        """Ensure capacity for one more token (``extend_for(slot, 1)``)."""
        self.extend_for(slot, 1)

    def extend_for(self, slot: int, n_tokens: int,
                   sync_device: bool = True) -> tuple | None:
        """Ensure page capacity for the next ``n_tokens`` decode tokens.

        The horizon pre-extend: before a fused multi-step decode dispatch,
        every block the loop will write through the block table (positions
        ``len .. len + n_tokens - 1``) is allocated here in one host pass,
        so the device loop never needs host allocation mid-horizon.  The
        host length advances here (the dispatch is committed — a horizon
        always completes); the device ``seq_lens_dev`` row advances inside
        the fused loop itself, keeping the two in lockstep without
        per-sequence transfers.

        ``sync_device=True`` scatters the new table entries to the device
        mirror immediately; with ``False`` the pending update
        ``(slot, first_col, new_blocks)`` is returned instead (or None),
        so a batch caller can fuse all slots' syncs into ONE device scatter
        via ``apply_table_updates``.
        """
        new_len = int(self.seq_lens[slot]) + n_tokens
        n_have = len(self.seq_blocks[slot])
        need = (new_len + self.block_size - 1) // self.block_size
        update = None
        if need > n_have:
            if need > self.max_blocks_per_seq:
                raise MemoryError("sequence exceeds max_blocks_per_seq")
            # reservations cover only this sequence's *private* pages —
            # shared prefix pages are counted once pool-wide
            s = self.seq_shared.get(slot, 0)
            short = (need - s) - max(self.seq_reserved.get(slot, 0),
                                     n_have - s)
            if short > 0:
                # growth beyond the admission reservation (legacy
                # prompt-only admits): extend the reservation, but never
                # into another view's quota
                if (self.quota is not None
                        and self.reserved_blocks + short > self.quota):
                    raise MemoryError("replica KV quota exceeded")
                if (self.pool.reserved + self.pool.allocator.pinned + short
                        > self.pool.num_blocks):
                    raise MemoryError("KV pool fully reserved")
                self.reserved_blocks += short
                self.pool.reserved += short
                self.seq_reserved[slot] = need - s
            grow = need - n_have
            self.pool.reclaim(grow)
            new_blocks = self.allocator.alloc(grow)
            self.used_blocks += grow
            self.seq_blocks[slot].extend(new_blocks)
            self.block_table[slot, n_have:need] = new_blocks
            if sync_device:
                # incremental sync: one row-slice scatter per page crossing
                self.block_table_dev = self.block_table_dev.at[
                    slot, n_have:need].set(jnp.asarray(new_blocks, jnp.int32))
            else:
                update = (slot, n_have, new_blocks)
        self.seq_lens[slot] = new_len
        return update

    def apply_table_updates(self, updates: list[tuple]) -> None:
        """Fuse deferred ``extend_for`` device syncs into one scatter: the
        whole decode batch's page crossings cost a single dispatch."""
        if not updates:
            return
        rows, cols, vals = [], [], []
        for slot, start, blocks in updates:
            rows.extend([slot] * len(blocks))
            cols.extend(range(start, start + len(blocks)))
            vals.extend(blocks)
        self.block_table_dev = self.block_table_dev.at[
            jnp.asarray(rows, jnp.int32), jnp.asarray(cols, jnp.int32)].set(
            jnp.asarray(vals, jnp.int32))

    def release_slot(self, slot: int) -> None:
        blocks = self.seq_blocks.pop(slot, [])
        # decref, not free: shared prefix pages (and any page the cache
        # index holds) survive until their last reference drops
        self.allocator.release(blocks)
        s = self.seq_shared.pop(slot, 0)
        self.used_blocks -= len(blocks) - s
        reserve = self.seq_reserved.pop(slot, len(blocks) - s)
        self.reserved_blocks -= reserve
        self.pool.reserved -= reserve
        self.seq_lens[slot] = 0
        self.block_table[slot, :] = 0
        self.block_table_dev = self.block_table_dev.at[slot].set(
            self.num_blocks)
        self.seq_lens_dev = self.seq_lens_dev.at[slot].set(0)

    def release_all(self) -> None:
        """Return every block this view holds to the pool (replica teardown)."""
        for slot in list(self.seq_blocks):
            self.release_slot(slot)

    # -- ownership transfer (page handoff between views) -----------------------

    def disown_slot(self, slot: int) -> tuple[list[int], int]:
        """Remove a sequence from this view's accounting *without* releasing
        its blocks to the allocator.

        Returns ``(blocks, seq_len)``.  The caller now owns the pages (the
        allocator still counts them allocated); they must end in either
        ``adopt_slot`` on a sibling view of the same pool or
        ``release_orphan_blocks``, or the pool leaks.
        """
        blocks = self.seq_blocks.pop(slot)
        seq_len = int(self.seq_lens[slot])
        s = self.seq_shared.pop(slot, 0)
        self.used_blocks -= len(blocks) - s
        reserve = self.seq_reserved.pop(slot, len(blocks) - s)
        self.reserved_blocks -= reserve
        self.pool.reserved -= reserve
        self.seq_lens[slot] = 0
        self.block_table[slot, :] = 0
        self.block_table_dev = self.block_table_dev.at[slot].set(
            self.num_blocks)
        self.seq_lens_dev = self.seq_lens_dev.at[slot].set(0)
        return blocks, seq_len

    def can_adopt(self, n_blocks: int, total_tokens: int,
                  n_shared: int = 0) -> bool:
        return (self.n_free_blocks
                >= max(n_blocks, self._blocks(total_tokens)) - n_shared)

    def adopt_slot(self, slot: int, blocks: list[int], seq_len: int,
                   total_tokens: int | None = None,
                   n_shared: int = 0) -> None:
        """Adopt already-allocated pool blocks into a slot of this view.

        The inverse of ``disown_slot``: block data stays where it is; only
        ownership accounting and the (host + device) block table move.  The
        blocks must belong to this view's pool.  ``n_shared`` leading blocks
        are prefix-cache pages the sequence holds by refcount — counted once
        pool-wide, so they stay out of this view's used/reserved totals.
        """
        n = len(blocks)
        if n > self.max_blocks_per_seq:
            raise MemoryError("adopted sequence exceeds max_blocks_per_seq")
        reserve = max(n, self._blocks(total_tokens or seq_len)) - n_shared
        if not self.can_adopt(n, total_tokens or seq_len, n_shared=n_shared):
            raise MemoryError(
                f"cannot adopt {n} blocks (reserve {reserve}): view has "
                f"{self.n_free_blocks} free")
        self.used_blocks += n - n_shared
        self.reserved_blocks += reserve
        self.pool.reserved += reserve
        self.seq_reserved[slot] = reserve
        if n_shared:
            self.seq_shared[slot] = n_shared
        self.seq_blocks[slot] = list(blocks)
        self.block_table[slot, :] = 0
        self.block_table[slot, :n] = blocks
        self.seq_lens[slot] = seq_len
        row = np.full(self.max_blocks_per_seq, self.num_blocks, np.int32)
        row[:n] = blocks
        self.block_table_dev = self.block_table_dev.at[slot].set(
            jnp.asarray(row))
        self.seq_lens_dev = self.seq_lens_dev.at[slot].set(seq_len)

    # -- device views ----------------------------------------------------------

    def write_prefill(self, slot: int, k_seq: jax.Array, v_seq: jax.Array
                      ) -> None:
        """k_seq/v_seq: [L, S, Hkv, D] from prefill; scattered into pages."""
        S = k_seq.shape[1]
        bs = self.block_size
        n = (S + bs - 1) // bs
        pad = n * bs - S
        dpad = self.k.shape[-1] - k_seq.shape[-1]
        if pad or dpad:
            k_seq = jnp.pad(k_seq, ((0, 0), (0, pad), (0, 0), (0, dpad)))
            v_seq = jnp.pad(v_seq, ((0, 0), (0, pad), (0, 0), (0, dpad)))
        kb = k_seq.reshape(k_seq.shape[0], n, bs, *k_seq.shape[2:])
        vb = v_seq.reshape(v_seq.shape[0], n, bs, *v_seq.shape[2:])
        kb = jnp.swapaxes(kb, 2, 3)          # [L, n, Hkv, bs, D] native
        vb = jnp.swapaxes(vb, 2, 3)
        idx = jnp.asarray(self.seq_blocks[slot], jnp.int32)
        self.k = self.k.at[:, idx].set(kb.astype(self.k.dtype))
        self.v = self.v.at[:, idx].set(vb.astype(self.v.dtype))

    def write_token(self, slots: np.ndarray, k_new: jax.Array,
                    v_new: jax.Array, positions: np.ndarray) -> None:
        """k_new/v_new: [L, B, Hkv, D] for one token per active slot."""
        blk = self.block_table[slots, positions // self.block_size]
        off = positions % self.block_size
        blk = jnp.asarray(blk)
        off = jnp.asarray(off)
        # pool is [L, P, Hkv, block, D]: non-adjacent advanced indices put
        # the batch dim first, so updates arrive as [B, L, Hkv, D]
        dpad = self.k.shape[-1] - k_new.shape[-1]
        if dpad:
            k_new = jnp.pad(k_new, ((0, 0),) * 3 + ((0, dpad),))
            v_new = jnp.pad(v_new, ((0, 0),) * 3 + ((0, dpad),))
        kv = jnp.moveaxis(k_new, 0, 1).astype(self.k.dtype)
        vv = jnp.moveaxis(v_new, 0, 1).astype(self.v.dtype)
        self.k = self.k.at[:, blk, :, off].set(kv)
        self.v = self.v.at[:, blk, :, off].set(vv)

    def gather_dense(self, slots: np.ndarray, max_len: int
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Materialize [L, B, max_len, Hkv, D] dense caches (legacy
        dense-gather decode path and parity tests only — the serving decode
        path reads pages in place via the block table)."""
        bs = self.block_size
        n_blocks = (max_len + bs - 1) // bs
        table = jnp.asarray(self.block_table[slots, :n_blocks])   # [B, n]
        k = self.k[:, table]          # [L, B, n, Hkv, bs, D]
        v = self.v[:, table]
        L, B = k.shape[0], k.shape[1]
        k = jnp.swapaxes(k, 3, 4)     # [L, B, n, bs, Hkv, D]
        v = jnp.swapaxes(v, 3, 4)
        k = k.reshape(L, B, n_blocks * bs, *k.shape[4:])[:, :, :max_len]
        v = v.reshape(L, B, n_blocks * bs, *v.shape[4:])[:, :, :max_len]
        D = self.cfg.head_dim
        k, v = k[..., :D], v[..., :D]   # drop kernel head_pad columns
        lens = jnp.asarray(self.seq_lens[slots])
        return k, v, lens


# --------------------------------------------------------------------------
# Pool-to-pool page movement (cross-pool KV migration).
# --------------------------------------------------------------------------


@jax.jit
def _copy_blocks_dev(src_k, src_v, dst_k, dst_v, src_idx, dst_idx):
    dst_k = dst_k.at[:, dst_idx].set(src_k[:, src_idx])
    dst_v = dst_v.at[:, dst_idx].set(src_v[:, src_idx])
    return dst_k, dst_v


def copy_blocks(src: BlockPool, dst: BlockPool,
                src_blocks: list[int], dst_blocks: list[int]) -> None:
    """Jitted page gather/scatter between two pools of the same geometry.

    The index vectors are padded to a power-of-two length against each
    pool's trash page, so the number of distinct compilations is
    O(log max_blocks), not one per migrated sequence size.
    """
    if (src.block_size != dst.block_size
            or src.k.shape[2:] != dst.k.shape[2:]):
        raise ValueError("copy_blocks needs matching page geometry; use "
                         "relayout_blocks")
    n = len(src_blocks)
    if n != len(dst_blocks):
        raise ValueError("src/dst block lists differ in length")
    if n == 0:
        return
    cap = 1 << max(0, n - 1).bit_length()
    src_idx = np.full(cap, src.trash_page, np.int32)
    dst_idx = np.full(cap, dst.trash_page, np.int32)
    src_idx[:n] = src_blocks
    dst_idx[:n] = dst_blocks
    dst.k, dst.v = _copy_blocks_dev(src.k, src.v, dst.k, dst.v,
                                    jnp.asarray(src_idx), jnp.asarray(dst_idx))


def gather_tokens(pool: BlockPool, blocks: list[int], seq_len: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Materialize one sequence's K/V as dense [L, S, Hkv, D] (head_pad
    columns dropped) — the relayout path between mismatched geometries."""
    idx = jnp.asarray(blocks, jnp.int32)
    k = pool.k[:, idx]                       # [L, n, Hkv, bs, D]
    v = pool.v[:, idx]
    L, n, H, bs, D = k.shape
    k = jnp.swapaxes(k, 2, 3).reshape(L, n * bs, H, D)[:, :seq_len]
    v = jnp.swapaxes(v, 2, 3).reshape(L, n * bs, H, D)[:, :seq_len]
    d = pool.cfg.head_dim
    return k[..., :d], v[..., :d]


def scatter_tokens(pool: BlockPool, blocks: list[int],
                   k_seq: jax.Array, v_seq: jax.Array) -> None:
    """Scatter dense [L, S, Hkv, D] K/V into the given pool pages
    (re-chunking to this pool's page size; pads head_dim to its head_pad)."""
    S = k_seq.shape[1]
    bs = pool.block_size
    n = (S + bs - 1) // bs
    if n != len(blocks):
        raise ValueError(f"{S} tokens need {n} blocks, got {len(blocks)}")
    pad = n * bs - S
    dpad = pool.k.shape[-1] - k_seq.shape[-1]
    if pad or dpad:
        k_seq = jnp.pad(k_seq, ((0, 0), (0, pad), (0, 0), (0, dpad)))
        v_seq = jnp.pad(v_seq, ((0, 0), (0, pad), (0, 0), (0, dpad)))
    kb = jnp.swapaxes(k_seq.reshape(k_seq.shape[0], n, bs, *k_seq.shape[2:]),
                      2, 3)
    vb = jnp.swapaxes(v_seq.reshape(v_seq.shape[0], n, bs, *v_seq.shape[2:]),
                      2, 3)
    idx = jnp.asarray(blocks, jnp.int32)
    pool.k = pool.k.at[:, idx].set(kb.astype(pool.k.dtype))
    pool.v = pool.v.at[:, idx].set(vb.astype(pool.v.dtype))


def relayout_blocks(src: BlockPool, dst: BlockPool,
                    src_blocks: list[int], dst_blocks: list[int],
                    seq_len: int) -> None:
    """Move one sequence between pools whose page geometry differs
    (block_size and/or kernel head_pad): dense gather then re-chunked
    scatter, entirely on device."""
    k, v = gather_tokens(src, src_blocks, seq_len)
    scatter_tokens(dst, dst_blocks, k, v)


def reshard_blocks(src: BlockPool, dst: BlockPool,
                   src_blocks: list[int], dst_blocks: list[int],
                   seq_len: int) -> None:
    """Move one sequence between pools that live on *different meshes /
    head shardings* (per-replica sharded serving) — the migration path a
    deployment switch between replicas of unlike (tp, pp) takes.

    The page data rides the existing relayout route: dense gather on the
    source mesh, an explicit cross-mesh ``device_put`` hop onto the
    destination's devices, a KV-head fix when the two replicas run
    different head-padded configs (a padded source keeps its real heads
    first, so the pad columns slice off; a padded destination's extra heads
    are zero rows only padded q heads ever attend), then the re-chunked
    scatter into the destination's (head-sharded) pages.  Zero tokens are
    recomputed — only bytes move.
    """
    k, v = gather_tokens(src, src_blocks, seq_len)
    src_h, dst_h = k.shape[2], dst.cfg.n_kv_heads
    if src_h > dst_h:
        k, v = k[:, :, :dst_h], v[:, :, :dst_h]
    elif src_h < dst_h:
        hp = ((0, 0), (0, 0), (0, dst_h - src_h), (0, 0))
        k, v = jnp.pad(k, hp), jnp.pad(v, hp)
    if dst.mesh is not None:
        tgt = NamedSharding(dst.mesh, P())
    else:
        tgt = jax.devices()[0]
    k, v = jax.device_put(k, tgt), jax.device_put(v, tgt)
    scatter_tokens(dst, dst_blocks, k, v)
