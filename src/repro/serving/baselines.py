"""Serving policies: OServe + the paper's baselines (S5.1), as simulator
policies emitting per-span SpanDecisions.

  * OServePolicy        — predictor + two-level scheduler + ad hoc switching
  * VLLMStaticPolicy    — best single homogeneous deployment, fixed forever
  * VLLMReloadPolicy    — homogeneous deployments, re-optimized each span,
                          ad hoc switching enabled (the paper's vLLM (reload))
  * LlumnixPolicy       — fixed deployment + dynamic load-aware rebalancing
  * RoundRobinPolicy    — DeepSpeed-MII-style uniform dispatch
  * DynamoPolicy        — KV/load-aware routing, fixed per-worker parallelism

All policies share the cost model (fair comparison: same profiling data).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.assignment import assign_workloads
from repro.core.costmodel import CostModel
from repro.core.deployment import flow_guided_search
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.types import (ClusterSpec, Deployment, WorkloadType,
                              valid_strategies)
from repro.serving.simulator import SpanDecision


def calibrate_rate(cm: CostModel, chips: int, archetypes: list[WorkloadType],
                   mix: np.ndarray, max_tp: int = 8, max_pp: int = 4,
                   utilization: float = 0.8) -> float:
    """Largest request rate (req/span) at which the cluster can serve the
    *proportional mix* (the paper sizes traces so the cluster is neither
    over- nor under-utilized), scaled by the target utilization.

    Binary search over the mixture scale; feasibility = the best deployment's
    max-flow serves >= 99.5% of the offered mix.
    """
    mix = np.asarray(mix, float)
    mix = mix / mix.sum()

    def feasible(total: float) -> bool:
        ws = [a.with_rate(float(total * m)) for a, m in zip(archetypes, mix)]
        sr = flow_guided_search(cm, chips, ws, max_tp=max_tp, max_pp=max_pp,
                                seed=0, patience=10)
        return sr.throughput >= 0.995 * total

    lo, hi = 1.0, 16.0
    while feasible(hi) and hi < 1e6:
        lo, hi = hi, hi * 2
    for _ in range(12):
        mid = 0.5 * (lo + hi)
        if feasible(mid):
            lo = mid
        else:
            hi = mid
    return lo * utilization


def _uniform_deployments(cm: CostModel, chips: int, max_tp: int = 8,
                         max_pp: int = 4) -> list[Deployment]:
    """All homogeneous deployments (identical replicas) filling the cluster."""
    out = []
    for per in range(cm.min_chips(), chips + 1):
        if chips % per:
            continue
        n = chips // per
        for s in valid_strategies(per, max_tp=max_tp, max_pp=max_pp):
            out.append(Deployment(tuple([s] * n)))
    return out


def _balanced_fractions(dep: Deployment, cm: CostModel,
                        workloads: list[WorkloadType]) -> list[list[float]]:
    """Capacity-proportional routing (no flow optimization)."""
    caps = np.array([[cm.capacity(r, w) for w in workloads]
                     for r in dep.replicas], dtype=float)
    col = caps.sum(0, keepdims=True)
    col[col == 0] = 1.0
    return (caps / col).tolist()


def _rates_to_workloads(archetypes: list[WorkloadType],
                        rates: np.ndarray) -> list[WorkloadType]:
    return [w.with_rate(float(r)) for w, r in zip(archetypes, rates)]


@dataclasses.dataclass
class PolicyStats:
    switches: int = 0
    search_seconds: float = 0.0
    switch_seconds_total: float = 0.0


class OServePolicy:
    """The full system: per-type prediction -> scheduler -> ad hoc switching."""

    def __init__(self, cm: CostModel, cluster: ClusterSpec,
                 archetypes: list[WorkloadType], predictor=None,
                 max_tp: int = 8, max_pp: int = 4, naive_reload: bool = False,
                 heterogeneous: bool = True, flow_assignment: bool = True):
        self.cm = cm
        self.orch = Orchestrator(cm, cluster, OrchestratorConfig(
            max_tp=max_tp, max_pp=max_pp))
        self.archetypes = archetypes
        self.predictor = predictor      # None -> oracle (uses observed rates)
        self.naive_reload = naive_reload
        self.heterogeneous = heterogeneous
        self.flow_assignment = flow_assignment
        self.history: list[np.ndarray] = []
        self.stats = PolicyStats()

    def observe(self, achieved: list[float]) -> None:
        """Driver feedback: per-replica achieved/expected service for the
        last span; the orchestrator's EWMA health shifts the next span's
        assignment away from stragglers."""
        self.orch.observe_health(achieved)

    def _predict(self, observed: np.ndarray) -> np.ndarray:
        self.history.append(observed)
        if self.predictor is None:
            return observed
        hist = np.asarray(self.history)
        if len(hist) <= self.predictor.window:
            return observed
        return self.predictor.predict(hist)

    def decide(self, span: int, rates: np.ndarray,
               current: Deployment | None) -> SpanDecision:
        pred = self._predict(rates)
        ws = _rates_to_workloads(self.archetypes, pred)
        if not self.heterogeneous:
            dep, frac = _best_uniform(self.cm, self.orch.cluster.chips, ws)
            if self.flow_assignment:
                frac = assign_workloads(self.cm, dep, ws).fractions
            plan_dep, fractions = dep, frac
            switch = 0.0 if current == dep else (
                self.cm.reload_seconds() if self.naive_reload else 10.0)
            changed = list(range(dep.dp))
            self.orch.current = dep
            return SpanDecision(plan_dep, fractions, switch, changed)
        plan = self.orch.plan_span(ws)
        self.stats.search_seconds += plan.search_time
        if not self.flow_assignment:
            fractions = _balanced_fractions(plan.deployment, self.cm, ws)
        else:
            fractions = plan.fractions
        switch = plan.reload_seconds if self.naive_reload else plan.switch_seconds
        if plan.changed_replicas:
            self.stats.switches += 1
            self.stats.switch_seconds_total += switch
        return SpanDecision(plan.deployment, fractions, switch,
                            plan.changed_replicas)


def _best_uniform(cm: CostModel, chips: int, ws: list[WorkloadType]
                  ) -> tuple[Deployment, list[list[float]]]:
    best = None
    for dep in _uniform_deployments(cm, chips):
        res = assign_workloads(cm, dep, ws)
        key = (res.throughput, -res.latency_proxy())
        if best is None or key > best[0]:
            best = (key, dep, res)
    assert best is not None
    return best[1], best[2].fractions


class VLLMStaticPolicy:
    """Best homogeneous deployment for the *average* workload, fixed forever."""

    def __init__(self, cm: CostModel, cluster: ClusterSpec,
                 archetypes: list[WorkloadType], avg_rates: np.ndarray):
        ws = _rates_to_workloads(archetypes, avg_rates)
        self.dep, _ = _best_uniform(cm, cluster.chips, ws)
        self.cm = cm
        self.archetypes = archetypes

    def decide(self, span, rates, current) -> SpanDecision:
        ws = _rates_to_workloads(self.archetypes, rates)
        frac = _balanced_fractions(self.dep, self.cm, ws)
        return SpanDecision(self.dep, frac, 0.0,
                            None if current else list(range(self.dep.dp)))


class VLLMReloadPolicy(OServePolicy):
    """Homogeneous + adaptive + ad hoc switching (paper's vLLM (reload))."""

    def __init__(self, cm, cluster, archetypes, predictor=None, **kw):
        super().__init__(cm, cluster, archetypes, predictor,
                         heterogeneous=False, flow_assignment=False, **kw)


class RoundRobinPolicy(VLLMStaticPolicy):
    """MII-style: static deployment + uniform dispatch."""

    def decide(self, span, rates, current) -> SpanDecision:
        K, J = self.dep.dp, len(self.archetypes)
        frac = [[1.0 / K] * J for _ in range(K)]
        return SpanDecision(self.dep, frac, 0.0,
                            None if current else list(range(self.dep.dp)))


class LlumnixPolicy(VLLMStaticPolicy):
    """Static deployment, dynamic *load-aware* rebalancing each span.

    Captures Llumnix's request-migration benefit at span granularity: routing
    follows current per-type demand against replica capacity, but deployment
    (resources + parallelism) never changes.
    """

    def decide(self, span, rates, current) -> SpanDecision:
        ws = _rates_to_workloads(self.archetypes, rates)
        res = assign_workloads(self.cm, self.dep, ws)
        return SpanDecision(self.dep, res.fractions, 0.0,
                            None if current else list(range(self.dep.dp)))


class DynamoPolicy:
    """KV-aware routing + autoscaled pools, but fixed per-worker parallelism.

    The deployment is the best homogeneous one for the average workload; each
    span the router re-solves the assignment (KV/load-aware), which is the
    part Dynamo does well — the parallelism-workload interaction is what it
    misses (paper S5.2)."""

    def __init__(self, cm, cluster, archetypes, avg_rates):
        ws = _rates_to_workloads(archetypes, avg_rates)
        self.dep, _ = _best_uniform(cm, cluster.chips, ws)
        self.cm = cm
        self.archetypes = archetypes

    def decide(self, span, rates, current) -> SpanDecision:
        ws = _rates_to_workloads(self.archetypes, rates)
        res = assign_workloads(self.cm, self.dep, ws)
        return SpanDecision(self.dep, res.fractions, 0.0,
                            None if current else list(range(self.dep.dp)))
