"""Deterministic chaos injection for the serving cluster.

At the paper's "millions of users" scale the spatial-temporal machinery
that reshapes deployments on purpose (span switches) must also absorb
*unplanned* reshaping: replica crashes, stalled devices, transient
dispatch errors, pool-reservation OOMs, and switches that die half-way.
This module provides the reproducible fault source for exercising those
paths — no real faults needed, so the whole recovery stack runs in CI.
The failure model the injected faults drive is described in
``docs/architecture.md``; ``docs/telemetry.md`` explains how crashes,
recoveries and shed requests appear in an exported trace.

A ``FaultPlan`` is a list of ``FaultSpec``s consulted by
``ClusterRuntime`` at well-defined injection sites:

  * ``crash`` — the replica raises ``ReplicaCrash`` at its next dispatch
    attempt once the cluster tick reaches ``spec.tick`` (fires once).
    With ``lose_pages=True`` the recovery path must treat the replica's
    device state as gone and rebuild requests from the cluster's
    host-side token log (re-prefill); otherwise the shared/per-replica
    ``BlockPool`` survives the engine and pages are handed off.
  * ``stall`` — the replica silently skips ``steps`` consecutive ticks
    starting at ``spec.tick`` (a straggler / frozen device; no error is
    raised, progress just halts).  With the cluster's rebalancer enabled
    the step-loop watchdog detects the sustained zero progress, drains
    the replica's requests onto survivors, and escalates to
    ``fail_replica`` — a hang becomes graceful degradation; without it
    only the health feedback loop sees the stall.
  * ``slow`` — slow degradation rather than a freeze: for ``steps``
    ticks the replica only makes progress every ``period``-th tick
    (skipping the rest).  Exercises the watchdog's *low*-progress
    detection and the health EWMA without ever fully halting.
  * ``hotspot`` — traffic-skew injection: for ``steps`` ticks every new
    submission routes to ``spec.replica`` (bypassing the router) while
    the replica is up, deterministically building the queue-depth /
    KV-pressure hot spot the rebalancer's load-relief path drains.
  * ``transient`` — the next ``steps`` dispatch attempts at or after
    ``spec.tick`` raise ``TransientDispatchError``; the cluster retries
    with exponential backoff and only declares the replica dead when the
    consecutive-failure budget (``ClusterRuntime.max_retries``) is
    exhausted.
  * ``oom`` — the next ``steps`` admission attempts raise
    ``InjectedOOM`` (a ``MemoryError``) from inside the engine's admit
    path, before any request state is mutated.
  * ``switch_build`` / ``switch_migrate`` — the ``spec.tick``-th
    ``apply_plan`` call (1-based ordinal) fails while building the new
    engines / between per-destination migration batches, exercising the
    transactional abort / rollback paths.

Plans are stateful for one run (each one-shot spec fires once, budgeted
specs count down); build a fresh plan per run.  ``FaultPlan.seeded``
derives a reproducible mixed plan from an integer seed — the CI chaos
matrix is just a handful of seeds.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

FAULT_KINDS = ("crash", "stall", "slow", "transient", "oom", "hotspot",
               "switch_build", "switch_migrate")


class FaultError(RuntimeError):
    """Base class for injected (and injected-like) serving faults."""


class ReplicaCrash(FaultError):
    """The replica process is gone; its engine must not be used again."""

    def __init__(self, msg: str, lose_pages: bool = False):
        super().__init__(msg)
        self.lose_pages = lose_pages


class TransientDispatchError(FaultError):
    """A dispatch failed but the replica may recover (retry with backoff)."""


class InjectedOOM(FaultError, MemoryError):
    """A pool-reservation failure injected at the admission site."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: what, when, where.

    ``tick`` is the cluster tick the fault arms (for ``switch_*`` kinds it
    is the 1-based ``apply_plan`` ordinal instead).  ``steps`` is the
    stall/slow/hotspot length / the number of transient or OOM firings.
    ``replica`` indexes ``ClusterRuntime.replicas``.  ``period`` applies
    to ``slow`` only: the replica progresses on one of every ``period``
    ticks inside the window.
    """
    kind: str
    tick: int
    replica: int = 0
    steps: int = 1
    lose_pages: bool = False
    period: int = 2

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")


class FaultPlan:
    """A deterministic schedule of injected faults for one cluster run."""

    def __init__(self, faults: Sequence[FaultSpec] = ()):
        self.faults = list(faults)
        # remaining firings for budgeted kinds; one-shot kinds use `_fired`
        self._left = {i: f.steps for i, f in enumerate(self.faults)
                      if f.kind in ("transient", "oom")}
        self._fired: set[int] = set()

    @classmethod
    def seeded(cls, seed: int, *, n_replicas: int, horizon_ticks: int = 48,
               crashes: int = 1, stalls: int = 1, transients: int = 0,
               ooms: int = 0, slows: int = 0, hotspots: int = 0,
               lose_pages: bool = False,
               switch_failure: str | None = None,
               switch_ordinal: int = 2) -> "FaultPlan":
        """Derive a reproducible mixed fault plan from an integer seed.

        Fault ticks land in ``[2, horizon_ticks)`` so the cluster is
        mid-decode when they fire; replicas are drawn uniformly.  The same
        (seed, shape) always yields the same plan — the CI chaos matrix
        enumerates seeds, not hand-written schedules.
        """
        rng = np.random.RandomState(seed)

        def draw(kind, n, **kw):
            return [FaultSpec(kind, int(rng.randint(2, horizon_ticks)),
                              int(rng.randint(n_replicas)), **kw)
                    for _ in range(n)]

        specs = draw("crash", crashes, lose_pages=lose_pages)
        specs += draw("stall", stalls, steps=int(rng.randint(2, 7)))
        specs += draw("transient", transients, steps=int(rng.randint(1, 3)))
        specs += draw("oom", ooms, steps=int(rng.randint(1, 3)))
        # new kinds draw AFTER the legacy ones so adding them to a plan
        # shape never shifts the legacy specs of an existing seed
        if slows:
            specs += draw("slow", slows, steps=int(rng.randint(4, 10)),
                          period=int(rng.randint(2, 4)))
        if hotspots:
            specs += draw("hotspot", hotspots,
                          steps=int(rng.randint(4, 10)))
        if switch_failure is not None:
            specs.append(FaultSpec(switch_failure, switch_ordinal))
        return cls(specs)

    # -- queries (one per injection site) ---------------------------------

    def dispatch_fault(self, tick: int, replica: int) -> FaultSpec | None:
        """Crash / transient error to raise before this replica's dispatch."""
        for i, f in enumerate(self.faults):
            if f.replica != replica or tick < f.tick:
                continue
            if f.kind == "crash" and i not in self._fired:
                self._fired.add(i)
                return f
            if f.kind == "transient" and self._left.get(i, 0) > 0:
                self._left[i] -= 1
                return f
        return None

    def stalled(self, tick: int, replica: int) -> bool:
        """Is this replica frozen at this tick (no error, no progress)?

        Covers both ``stall`` (every tick in the window) and ``slow``
        (every tick in the window except each ``period``-th one, where
        the degraded replica still limps forward)."""
        for f in self.faults:
            if f.replica != replica or not f.tick <= tick < f.tick + f.steps:
                continue
            if f.kind == "stall":
                return True
            if f.kind == "slow" and (tick - f.tick) % f.period:
                return True
        return False

    def route_bias(self, tick: int) -> int | None:
        """Replica index a ``hotspot`` injection concentrates all new
        submissions on at this tick (None = no active hotspot)."""
        for f in self.faults:
            if (f.kind == "hotspot"
                    and f.tick <= tick < f.tick + f.steps):
                return f.replica
        return None

    def admit_fault(self, tick: int, replica: int) -> FaultSpec | None:
        """OOM to raise from the engine's admission path at this tick."""
        for i, f in enumerate(self.faults):
            if (f.kind == "oom" and f.replica == replica and tick >= f.tick
                    and self._left.get(i, 0) > 0):
                self._left[i] -= 1
                return f
        return None

    def switch_fault(self, ordinal: int) -> FaultSpec | None:
        """Failure to inject into the ``ordinal``-th apply_plan (1-based)."""
        for i, f in enumerate(self.faults):
            if (f.kind in ("switch_build", "switch_migrate")
                    and f.tick == ordinal and i not in self._fired):
                self._fired.add(i)
                return f
        return None

    def fired(self, kind: str) -> int:
        """How many firings of ``kind`` have happened so far (for tests)."""
        n = sum(1 for i in self._fired if self.faults[i].kind == kind)
        n += sum(self.faults[i].steps - left for i, left in self._left.items()
                 if self.faults[i].kind == kind)
        return n


def error_for(spec: FaultSpec) -> FaultError:
    """The exception a dispatch-site fault spec manifests as."""
    if spec.kind == "crash":
        return ReplicaCrash(
            f"injected crash of replica {spec.replica} (armed tick "
            f"{spec.tick}, lose_pages={spec.lose_pages})",
            lose_pages=spec.lose_pages)
    return TransientDispatchError(
        f"injected transient dispatch failure on replica {spec.replica}")
