"""Serving telemetry: request-lifecycle tracing, metrics, decision audit.

``docs/telemetry.md`` is the narrative guide — how to read an exported
trace end to end, with a worked example; this docstring is the event
schema reference it links back to.

Three cooperating pieces, bundled in :class:`Telemetry` and threaded
through the serving stack (`engine.py`, `cluster.py`, `migration.py`,
`prefixcache.py`, `core/orchestrator.py`):

* :class:`Tracer` — structured per-request lifecycle events into a
  bounded ring buffer.  Events are emitted only at host-side
  dispatch/sync boundaries (never inside jitted code), carry timestamps
  from an injectable monotonic clock (deterministic in tests), and the
  whole path is a true no-op when disabled.
* :class:`Metrics` — a registry of counters, gauges, and log-bucketed
  histograms (TTFT / TPOT / queue delay / switch stall / recovery
  stall) cheap enough to stay on in production.
* :class:`DecisionAudit` — one record per ``Orchestrator.plan_span``
  decision: its inputs (workload mix, health scales, ``cached_frac``
  EWMAs, hysteresis margin, KV-stall price) and the predicted
  per-replica token share, later joined with the realized
  ``SpanReport`` into a calibration-error metric.

Event schema (kind -> required data keys; ``rid`` / ``replica`` are -1
when not applicable):

======================  ======================================================
kind                    data
======================  ======================================================
submit                  type_id, prompt_len, max_new
admit                   reserved_bytes, cached_tokens, queue_delay_s
prefix_hit              tokens, pages
prefill_chunk           tokens, pos
first_token             ttft_s
dispatch                n (batch size), h (horizon)
sync                    n, tokens
retire                  tokens                       [terminal]
shed                    reason ("ttft"|"tpot"|"capacity")  [terminal]
finish_log              tokens                       [terminal; cluster-side]
migrate                 src, dst, path, pages
rebalance               src, dst, path, pages        [mid-span move; same
                                                      flow-arrow render as
                                                      migrate]
handoff                 src, dst, path, pages        [prefill→decode hop of
                                                      a disaggregated
                                                      deployment; same
                                                      flow-arrow render]
preempt                 action ("relocate"|"evict"), for_rid
degraded                ticks (zero-progress count)  [replica-level]
evict                   pages, bytes                 [host tier, replica=-1]
restore                 pages, bytes
crash                   step, kind (fault kind)      [replica-level]
recovered               n (requests moved), stall_s  [replica-level]
plan                    span, switched, margin, kv_stall_s
switch_prepare          phase ("begin"|"end"), span
switch_commit           phase ("begin"|"end"), span
switch_rollback         phase ("begin"|"end"), span
======================  ======================================================

Every submitted request's stream ends in exactly one *terminal* event
(retire / shed / finish_log) — even across crashes and repeated
migrations; ``tests/test_telemetry.py`` enforces this under chaos.

:func:`export_chrome_trace` renders the ring buffer as Chrome
trace-event JSON (chrome://tracing / Perfetto): one track (tid) per
replica plus an orchestrator track, request residency as complete
slices, dispatch->sync windows as nested slices, switch phases as
begin/end pairs, and flow arrows following a request's pages across
migrations.  :func:`validate_chrome_trace` is the CI-side schema check
(``python -m repro.serving.telemetry trace.json``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from collections import deque

TERMINAL_KINDS = frozenset({"retire", "shed", "finish_log"})

# Histogram names recorded by the serving stack (all in seconds).
STANDARD_HISTOGRAMS = ("ttft_s", "tpot_s", "queue_delay_s",
                       "switch_stall_s", "recovery_stall_s")

ORCH_TID = 1000   # trace track for orchestrator / switch events


@dataclasses.dataclass
class Event:
    """One telemetry event.  ``ts`` is seconds on the telemetry clock."""
    __slots__ = ("kind", "ts", "rid", "replica", "data")
    kind: str
    ts: float
    rid: int
    replica: int
    data: dict


class Tracer:
    """Bounded ring buffer of lifecycle events.

    ``emit`` returns immediately when disabled — callers may still guard
    with ``if tracer.enabled`` to skip argument construction.
    """

    def __init__(self, clock=None, capacity: int = 65536,
                 enabled: bool = True):
        self.clock = clock if clock is not None else time.monotonic
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.events: deque[Event] = deque(maxlen=self.capacity)
        self.dropped = 0        # events evicted by the ring bound

    def emit(self, kind: str, rid: int = -1, replica: int = -1,
             **data) -> None:
        if not self.enabled:
            return
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(Event(kind, self.clock(), rid, replica, data))

    def by_request(self) -> dict[int, list[Event]]:
        """Events grouped per request id (rid >= 0), in emission order."""
        out: dict[int, list[Event]] = {}
        for e in self.events:
            if e.rid >= 0:
                out.setdefault(e.rid, []).append(e)
        return out

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0


class Histogram:
    """Log-bucketed histogram: O(1) record, ~5% quantile resolution.

    Buckets are powers of ``base`` (default 1.1); values <= 0 land in a
    dedicated underflow bucket.  Exact min/max/sum are tracked so mean
    and range are precise even though quantiles are bucketed.
    """

    def __init__(self, base: float = 1.1):
        self._log_base = math.log(base)
        self._base = base
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, value: float) -> None:
        v = float(value)
        idx = (math.floor(math.log(v) / self._log_base)
               if v > 0.0 else -(10 ** 6))
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100]; returns a bucket-representative value clamped
        to the exact observed [min, max]."""
        if not self.count:
            return 0.0
        target = max(1, math.ceil(self.count * p / 100.0))
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= target:
                if idx <= -(10 ** 6):
                    return max(0.0, self.min)
                rep = self._base ** (idx + 0.5)   # geometric bucket center
                return min(max(rep, self.min), self.max)
        return self.max

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class Metrics:
    """Registry of counters, gauges, and histograms.

    All mutators are no-ops when disabled; readers always work (they
    just see empty state).
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}

    def count(self, name: str, inc: float = 1.0) -> None:
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0.0) + inc

    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.record(value)

    def summary_table(self) -> str:
        """Fixed-width histogram summary (bench_e2e / --trace output)."""
        rows = [f"{'histogram':<18}{'count':>7}{'mean':>12}"
                f"{'p50':>12}{'p95':>12}{'p99':>12}"]
        names = [n for n in STANDARD_HISTOGRAMS if n in self.histograms]
        names += sorted(set(self.histograms) - set(STANDARD_HISTOGRAMS))
        for name in names:
            s = self.histograms[name].summary()
            rows.append(f"{name:<18}{s['count']:>7d}{s['mean']:>12.6f}"
                        f"{s['p50']:>12.6f}{s['p95']:>12.6f}"
                        f"{s['p99']:>12.6f}")
        for name in sorted(self.counters):
            rows.append(f"{name:<18}{self.counters[name]:>19g}")
        return "\n".join(rows)


@dataclasses.dataclass
class DecisionRecord:
    """One ``plan_span`` decision and (once joined) its realized outcome."""
    span: int
    rates: list[float]                # per-type arrival rates planned for
    out_lens: list[int]               # per-type decode lengths
    cached_frac: list[float]          # per-type EWMA the cost model saw
    health: list[float] | None        # per-replica EWMA capacity scales
    hysteresis_margin: float          # gain bar the switch had to clear
    kv_stall_s: float                 # priced KV-migration stall
    switched: bool
    predicted_share: list[float]      # per-replica token share from the plan
    predicted_throughput: float       # cost-model req/s
    realized_share: list[float] | None = None
    realized_tokens: int = 0
    realized_completed: int = 0

    @property
    def joined(self) -> bool:
        return self.realized_share is not None

    @property
    def share_l1(self) -> float:
        """L1 distance predicted vs realized per-replica token share."""
        if not self.joined:
            return math.nan
        if len(self.realized_share) != len(self.predicted_share):
            return 2.0     # replica set changed mid-span (death): max error
        return float(sum(abs(p - a) for p, a in
                         zip(self.predicted_share, self.realized_share)))


class DecisionAudit:
    """Joins orchestrator predictions with realized span outcomes.

    ``record_plan`` is called by ``Orchestrator.plan_span`` (via the
    ``audit`` attribute the runtime sets); ``record_realized`` by
    ``ClusterRuntime.finish_span``.  Joining is FIFO — the first
    un-joined record takes the next report — which holds because spans
    are strictly sequential.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.records: list[DecisionRecord] = []

    def record_plan(self, plan, workloads, health=None,
                    hysteresis_margin: float = 0.0,
                    kv_stall_s: float = 0.0,
                    switched: bool = False) -> None:
        if not self.enabled:
            return
        rates = [float(w.rate) for w in workloads]
        outs = [int(w.out_len) for w in workloads]
        # Predicted per-replica *token* share: the plan routes request
        # fractions; weight by each type's rate x decode length (same
        # scoring as serving.validation).
        loads = []
        for frac_row in plan.fractions:
            loads.append(sum(f * r * o
                             for f, r, o in zip(frac_row, rates, outs)))
        tot = max(sum(loads), 1e-9)
        self.records.append(DecisionRecord(
            span=len(self.records),
            rates=rates, out_lens=outs,
            cached_frac=[float(w.cached_frac) for w in workloads],
            health=None if health is None else [float(h) for h in health],
            hysteresis_margin=float(hysteresis_margin),
            kv_stall_s=float(kv_stall_s), switched=bool(switched),
            predicted_share=[ld / tot for ld in loads],
            predicted_throughput=float(plan.throughput)))

    def record_realized(self, report) -> None:
        """Join a ``SpanReport`` with the oldest un-joined decision."""
        if not self.enabled:
            return
        rec = next((r for r in self.records if not r.joined), None)
        if rec is None:
            return
        tokens = [int(t) for t in report.tokens]
        tot = max(sum(tokens), 1)
        rec.realized_share = [t / tot for t in tokens]
        rec.realized_tokens = sum(tokens)
        rec.realized_completed = int(report.completed)

    def calibration_error(self) -> float:
        """Mean L1 share error over joined decisions (NaN if none)."""
        errs = [r.share_l1 for r in self.records
                if r.joined and not math.isnan(r.share_l1)]
        return sum(errs) / len(errs) if errs else math.nan


class Telemetry:
    """The bundle the serving stack passes around.

    One shared clock feeds the tracer, TTFT/TPOT deadlines, and every
    engine in a cluster, so fake-clock tests get deterministic traces.
    ``NULL_TELEMETRY`` is the module-wide disabled instance used as the
    default everywhere — its clock is still real ``time.monotonic`` so
    un-instrumented engines keep their previous timing behaviour.
    """

    def __init__(self, clock=None, enabled: bool = True,
                 capacity: int = 65536):
        self.clock = clock if clock is not None else time.monotonic
        self.enabled = bool(enabled)
        self.tracer = Tracer(clock=self.clock, capacity=capacity,
                             enabled=enabled)
        self.metrics = Metrics(enabled=enabled)
        self.audit = DecisionAudit(enabled=enabled)

    def emit(self, kind: str, rid: int = -1, replica: int = -1,
             **data) -> None:
        self.tracer.emit(kind, rid, replica, **data)


NULL_TELEMETRY = Telemetry(enabled=False)


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

def _us(ts: float, t0: float) -> int:
    return int(round((ts - t0) * 1e6))


def export_chrome_trace(telemetry: Telemetry, path: str | None = None
                        ) -> dict:
    """Render the tracer ring buffer as Chrome trace-event JSON.

    Track layout: pid 0; tid k = replica k's timeline; tid ``ORCH_TID``
    = orchestrator (plan + switch phases).  Per track:

    * request residency — one ``X`` (complete) slice per stay of a
      request on a replica, opened at admit / migrate-in and closed at
      retire / shed / migrate-out / crash (dangling stays are closed at
      the trace end, so slices always balance);
    * ``dispatch -> sync`` horizon windows as short ``X`` slices;
    * instants (``i``) for submit / first_token / prefill_chunk /
      prefix_hit / shed / evict / restore / crash;
    * switch phases as ``B``/``E`` pairs on the orchestrator track;
    * migrations as flow arrows (``s`` on the source slice end, ``f`` on
      the destination slice start) so Perfetto draws the request's hop.
    """
    events = sorted(telemetry.tracer.events, key=lambda e: e.ts)
    trace: list[dict] = []
    if not events:
        out = {"traceEvents": [], "displayTimeUnit": "ms"}
        if path:
            with open(path, "w") as f:
                json.dump(out, f)
        return out
    t0 = events[0].ts
    t_end = events[-1].ts
    tids: set[int] = set()

    def ev(ph, name, ts, tid, **kw):
        d = {"ph": ph, "name": name, "ts": _us(ts, t0), "pid": 0,
             "tid": tid, "cat": "serving"}
        d.update(kw)
        trace.append(d)
        tids.add(tid)

    # rid -> (replica, ts) for the currently-open residency slice
    open_res: dict[int, tuple[int, float]] = {}
    # replica -> (ts, data) for the currently-open dispatch window
    open_disp: dict[int, tuple[float, dict]] = {}
    flow_id = 0

    def close_res(rid, ts):
        if rid in open_res:
            rep, ts_in = open_res.pop(rid)
            ev("X", f"req {rid}", ts_in, rep,
               dur=max(_us(ts, t0) - _us(ts_in, t0), 0),
               args={"rid": rid})
            return rep, ts_in
        return None

    for e in events:
        k = e.kind
        if k == "submit":
            ev("i", f"submit {e.rid}", e.ts, max(e.replica, 0), s="t",
               args=dict(e.data, rid=e.rid))
        elif k == "admit":
            open_res[e.rid] = (e.replica, e.ts)
            ev("i", f"admit {e.rid}", e.ts, e.replica, s="t",
               args=dict(e.data, rid=e.rid))
        elif k in ("prefill_chunk", "prefix_hit", "first_token"):
            ev("i", f"{k} {e.rid}", e.ts, e.replica, s="t",
               args=dict(e.data, rid=e.rid))
        elif k == "dispatch":
            open_disp[e.replica] = (e.ts, dict(e.data))
        elif k == "sync":
            if e.replica in open_disp:
                ts_in, d = open_disp.pop(e.replica)
                d.update(e.data)
                ev("X", "horizon", ts_in, e.replica,
                   dur=max(_us(e.ts, t0) - _us(ts_in, t0), 0), args=d)
        elif k in ("retire", "shed", "finish_log"):
            close_res(e.rid, e.ts)
            ev("i", f"{k} {e.rid}", e.ts,
               e.replica if e.replica >= 0 else ORCH_TID, s="t",
               args=dict(e.data, rid=e.rid))
        elif k in ("migrate", "rebalance", "handoff"):
            src = int(e.data.get("src", e.replica))
            dst = int(e.data.get("dst", e.replica))
            closed = close_res(e.rid, e.ts)
            if closed is not None:
                src = closed[0]
            fid = f"mig-{e.rid}-{flow_id}"
            flow_id += 1
            ev("s", f"{k} {e.rid}", e.ts, src, id=fid,
               args=dict(e.data, rid=e.rid))
            ev("f", f"{k} {e.rid}", e.ts, dst, id=fid, bp="e",
               args=dict(e.data, rid=e.rid))
            open_res[e.rid] = (dst, e.ts)
        elif k == "preempt":
            # eviction sends the victim back to the host log: its residency
            # on the source replica ends here (a later rebalance/admit
            # re-opens it); relocation leaves the close to the rebalance
            # flow arrow that follows
            if e.data.get("action") == "evict":
                close_res(e.rid, e.ts)
            ev("i", f"preempt {e.rid}", e.ts, e.replica, s="t",
               args=dict(e.data, rid=e.rid))
        elif k == "crash":
            # the replica died: its open dispatch window and resident
            # requests end here (recovery re-opens them via migrate)
            if e.replica in open_disp:
                ts_in, d = open_disp.pop(e.replica)
                d["crashed"] = True
                ev("X", "horizon", ts_in, e.replica,
                   dur=max(_us(e.ts, t0) - _us(ts_in, t0), 0), args=d)
            for rid, (rep, _ts) in list(open_res.items()):
                if rep == e.replica:
                    close_res(rid, e.ts)
            ev("i", "crash", e.ts, e.replica, s="t", args=dict(e.data))
        elif k in ("evict", "restore", "recovered", "plan"):
            tid = e.replica if e.replica >= 0 else ORCH_TID
            ev("i", k, e.ts, tid, s="t", args=dict(e.data))
        elif k.startswith("switch_"):
            ph = "B" if e.data.get("phase") == "begin" else "E"
            args = {kk: v for kk, v in e.data.items() if kk != "phase"}
            if ph == "B":
                ev("B", k, e.ts, ORCH_TID, args=args)
            else:
                ev("E", k, e.ts, ORCH_TID)
        else:                       # unknown kinds stay visible as instants
            tid = e.replica if e.replica >= 0 else ORCH_TID
            ev("i", k, e.ts, tid, s="t", args=dict(e.data, rid=e.rid))

    # close dangling state so the trace is balanced no matter where the
    # run stopped
    for rep, (ts_in, d) in list(open_disp.items()):
        d["dangling"] = True
        ev("X", "horizon", ts_in, rep,
           dur=max(_us(t_end, t0) - _us(ts_in, t0), 0), args=d)
    for rid in list(open_res):
        close_res(rid, t_end)

    trace.sort(key=lambda d: d["ts"])
    meta = [{"ph": "M", "pid": 0, "tid": tid, "ts": 0,
             "name": "thread_name",
             "args": {"name": ("orchestrator" if tid == ORCH_TID
                               else f"replica {tid}")}}
            for tid in sorted(tids)]
    out = {"traceEvents": meta + trace, "displayTimeUnit": "ms"}
    if path:
        with open(path, "w") as f:
            json.dump(out, f)
    return out


def validate_chrome_trace(obj) -> dict:
    """Schema-check an exported trace; raises ``ValueError`` on problems.

    Checks: JSON shape, required keys per event, non-negative and
    non-decreasing timestamps, non-negative ``X`` durations, balanced
    ``B``/``E`` pairs per track, and every flow-start ``s`` paired with
    a flow-finish ``f`` of the same id.  Returns summary counts
    (events / tracks / slices / flows / be_pairs / instants).
    """
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        raise ValueError("trace must be a dict with a traceEvents list")
    events = obj["traceEvents"]
    stacks: dict[tuple, list[str]] = {}
    flows_s: dict[str, int] = {}
    flows_f: dict[str, int] = {}
    last_ts: dict[tuple, int] = {}
    counts = {"events": 0, "slices": 0, "flows": 0, "be_pairs": 0,
              "instants": 0}
    tids = set()
    for i, d in enumerate(events):
        if not isinstance(d, dict):
            raise ValueError(f"event {i} is not an object")
        for key in ("ph", "name", "pid", "tid"):
            if key not in d:
                raise ValueError(f"event {i} missing '{key}'")
        ph = d["ph"]
        if ph == "M":
            continue
        counts["events"] += 1
        tids.add((d["pid"], d["tid"]))
        ts = d.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} has bad ts {ts!r}")
        track = (d["pid"], d["tid"])
        if ph in ("B", "E"):
            # B/E pair up per track; ts ordering is checked per track
            if ts < last_ts.get(track, 0):
                raise ValueError(
                    f"event {i} ts {ts} decreases on track {track}")
            last_ts[track] = ts
            stack = stacks.setdefault(track, [])
            if ph == "B":
                stack.append(d["name"])
            else:
                if not stack:
                    raise ValueError(
                        f"event {i}: E '{d['name']}' with empty stack "
                        f"on track {track}")
                top = stack.pop()
                if top != d["name"]:
                    raise ValueError(
                        f"event {i}: E '{d['name']}' closes '{top}'")
                counts["be_pairs"] += 1
        elif ph == "X":
            if not isinstance(d.get("dur"), (int, float)) or d["dur"] < 0:
                raise ValueError(f"event {i} X has bad dur")
            counts["slices"] += 1
        elif ph == "s":
            flows_s[d.get("id")] = flows_s.get(d.get("id"), 0) + 1
        elif ph == "f":
            flows_f[d.get("id")] = flows_f.get(d.get("id"), 0) + 1
        elif ph == "i":
            counts["instants"] += 1
    for track, stack in stacks.items():
        if stack:
            raise ValueError(f"unclosed B events on track {track}: {stack}")
    if set(flows_s) != set(flows_f):
        raise ValueError(
            f"unpaired flows: starts {sorted(set(flows_s) - set(flows_f))} "
            f"finishes {sorted(set(flows_f) - set(flows_s))}")
    counts["flows"] = len(flows_s)
    counts["tracks"] = len(tids)
    return counts


def _main(argv) -> int:
    if len(argv) != 1:
        print("usage: python -m repro.serving.telemetry <trace.json>")
        return 2
    with open(argv[0]) as f:
        obj = json.load(f)
    try:
        counts = validate_chrome_trace(obj)
    except ValueError as e:
        print(f"INVALID trace: {e}")
        return 1
    print("valid chrome trace: "
          + " ".join(f"{k}={v}" for k, v in sorted(counts.items())))
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(_main(sys.argv[1:]))
