"""CI check: every intra-repo markdown link in README.md / docs/ resolves.

External (http/mailto) links are skipped; ``#anchor`` fragments are
stripped; relative targets resolve against the linking file's directory.
Exits non-zero listing every broken link.
"""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")


def main() -> int:
    bad = []
    for md in [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]:
        for target in LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if path and not (md.parent / path).exists():
                bad.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    print("\n".join(bad) if bad else
          "docs link check: all intra-repo links resolve")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
