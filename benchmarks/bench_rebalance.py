"""Live rebalancing under a straggler + hot-spot + priority-mix trace.

Runs the same seeded perturbed trace twice — rebalancer off vs on — and
reports what the rebalancer buys:

  * a ``hotspot`` injection concentrates every early submission on
    replica 0, which then ``stall``s for 6 ticks (the straggler);
  * every request carries a per-token pace budget, so anything left
    sitting on the straggler blows its TPOT SLO when the replica resumes
    and is shed;
  * a seeded quarter of the requests are high-priority, exercising the
    preemption ladder when queues deepen.

With rebalancing ON the watchdog drains the straggler through the free
same-pool handoff path (pace clocks restart on the destination), hot-spot
relief spreads the queue, and preemption relocates instead of shedding —
so ``total_shed`` must drop and TTFT must not regress.

The whole run is driven on a *virtual* clock (one unit per cluster tick)
threaded through ``Telemetry``, so every number here — shed counts,
TTFT/TPOT p95 in tick units, move counters — is deterministic and
machine-independent: ``check_regression.py`` gates them exactly against
the committed ``BENCH_rebalance.json``.

Emits the standard CSV rows and writes ``BENCH_rebalance.json`` at the
repo root.  Acceptance: rebalance-on sheds strictly fewer requests than
off, drains ride the free same-pool handoff path (any recompute in the
report comes only from preemption-eviction resumes, never from drains),
and on-mode TTFT p95 stays at or under off-mode.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params

BENCH_JSON = (pathlib.Path(__file__).resolve().parents[1]
              / "BENCH_rebalance.json")
BLOCK = 8
TPOT_BUDGET = 3.0               # virtual seconds (= ticks) per output token
HIGH_FRAC = 0.25


class _Plan:
    def __init__(self, rcs, fractions):
        from repro.core.types import Deployment
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions


class _TickClock:
    """Virtual time: the driver advances one unit per cluster tick."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _jobs(cfg, n: int, seed: int):
    rng = np.random.RandomState(seed)
    jobs = [(rng.randint(0, cfg.vocab_size, 6 + (i % 4) * 2)
             .astype(np.int32), 6 + (i % 4)) for i in range(n)]
    pri = (np.random.RandomState(seed + 1).rand(n)
           < HIGH_FRAC).astype(int).tolist()
    return jobs, pri


def _run_mode(cfg, params, on: bool, n_requests: int, seed: int) -> dict:
    import jax.numpy as jnp

    from repro.core.types import ReplicaConfig
    from repro.serving.cluster import ClusterRuntime, RebalanceConfig
    from repro.serving.faults import FaultPlan, FaultSpec
    from repro.serving.router import FlowRouter
    from repro.serving.telemetry import Telemetry

    # fresh fault plan per mode: hotspot piles the early batch onto
    # replica 0, which then freezes for 6 ticks
    faults = FaultPlan([FaultSpec("hotspot", 0, replica=0, steps=2),
                        FaultSpec("stall", 2, replica=0, steps=6)])
    clock = _TickClock()
    tm = Telemetry(clock=clock)
    rt = ClusterRuntime(
        cfg, params, total_chips=4, blocks_per_chip=32,
        seqs_per_chip=8, block_size=BLOCK, drain_steps=1,
        router=FlowRouter([[0.5], [0.5]]), faults=faults, telemetry=tm,
        rebalance=RebalanceConfig(max_moves_per_tick=4) if on else None,
        dtype=jnp.float32)
    rt.apply_plan(_Plan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                        [[0.5], [0.5]]))
    jobs, pri = _jobs(cfg, n_requests, seed)
    upfront = n_requests // 2
    for rid in range(upfront):      # the hot-spot batch, all onto replica 0
        p, n = jobs[rid]
        rt.submit(rid, p, n, tpot_deadline=TPOT_BUDGET, priority=pri[rid])
    ticks = 0
    next_rid = upfront
    while (rt.pending or next_rid < n_requests) and ticks < 200:
        if next_rid < n_requests:   # trickle the rest in mid-perturbation
            p, n = jobs[next_rid]
            rt.submit(next_rid, p, n, tpot_deadline=TPOT_BUDGET,
                      priority=pri[next_rid])
            next_rid += 1
        rt.step()
        clock.t += 1.0
        ticks += 1
    assert rt.pending == 0, "trace did not drain inside the tick budget"
    rep = rt.finish_span()
    ttft = tm.metrics.histograms["ttft_s"].summary()
    tpot = tm.metrics.histograms["tpot_s"].summary()
    return {"mode": "on" if on else "off",
            "n_requests": n_requests,
            "total_shed": len(rt.all_shed_rids),
            "completed": len(rt.results),
            "ticks": ticks,
            "ttft_p95_ticks": ttft["p95"],
            "tpot_p95_ticks": tpot["p95"],
            "rebalanced": rep.rebalanced,
            "preempted": rep.preempted,
            "handoff": rep.rebalance.handoff,
            "requeued": rep.rebalance.requeued,
            "recompute_tokens": rep.rebalance.recompute_tokens}


def main(fast: bool = True) -> list[str]:
    n_requests = 16 if fast else 32
    seed = 9
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = [_run_mode(cfg, params, on, n_requests, seed)
               for on in (False, True)]
    rows = []
    for r in results:
        rows.append(f"rebalance/{r['mode']}/n{n_requests},"
                    f"{r['total_shed']},"
                    f"shed={r['total_shed']}"
                    f";ttft_p95={r['ttft_p95_ticks']:.2f}"
                    f";tpot_p95={r['tpot_p95_ticks']:.2f}"
                    f";moved={r['rebalanced']}"
                    f";preempted={r['preempted']}")
    off, on = results
    # regression guards (CI runs this): the rebalancer must strictly cut
    # shedding on this trace, ride the zero-recompute drain path, and not
    # regress time-to-first-token while doing it
    assert off["total_shed"] >= 1, \
        "perturbed trace shed nothing with rebalance off — bar is vacuous"
    assert on["total_shed"] < off["total_shed"], \
        f"rebalance-on shed {on['total_shed']} >= off {off['total_shed']}"
    assert on["rebalanced"] >= 1 and on["handoff"] >= 1, \
        "straggler drains must ride the free same-pool handoff path"
    assert on["preempted"] >= 1, \
        "the priority mix must exercise the preemption ladder"
    assert off["rebalanced"] == 0 and off["preempted"] == 0
    assert on["ttft_p95_ticks"] <= off["ttft_p95_ticks"], \
        "rebalancing must not regress TTFT p95 on the straggler trace"
    rows.append(f"rebalance/gain/n{n_requests},0,"
                f"shed_off={off['total_shed']};shed_on={on['total_shed']}")
    BENCH_JSON.write_text(json.dumps({
        "bench": "rebalance",
        "model": cfg.name,
        "backend": jax.default_backend(),
        "n_requests": n_requests,
        "tpot_budget_ticks": TPOT_BUDGET,
        "results": results,
        "shed_off": off["total_shed"],
        "shed_on": on["total_shed"],
    }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in main(fast=True):
        print(row)
