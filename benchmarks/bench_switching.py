"""Paper Fig. 13 + S5.3 switching-cost study.

Two parts:
  1. micro: greedy ad hoc switch-plan transfer time vs naive model reload,
     across representative deployment transitions (the paper: ~10s vs >50s);
  2. macro: end-to-end P99 with OServe using ad hoc switching vs naive
     reloading on the fast-fluctuation trace.
"""
from __future__ import annotations

from benchmarks.common import Bench
from repro.configs import get_config
from repro.core.costmodel import CostModel
from repro.core.switching import place_deployment, plan_switch, plan_kv_migration
from repro.core.types import (ClusterSpec, Deployment, H100_SPEC,
                              ReplicaConfig, TPU_V5E_SPEC)
from repro.serving.baselines import OServePolicy


TRANSITIONS = [
    ("consolidate", Deployment((ReplicaConfig(2),) * 8),
     Deployment((ReplicaConfig(8), ReplicaConfig(8)))),
    ("split", Deployment((ReplicaConfig(8, 2),)),
     Deployment((ReplicaConfig(4, 2), ReplicaConfig(4, 2)))),
    ("reshape", Deployment((ReplicaConfig(8), ReplicaConfig(4),
                            ReplicaConfig(4))),
     Deployment((ReplicaConfig(4, 2), ReplicaConfig(4, 2)))),
]


def micro(model: str = "opt-66b") -> list[str]:
    rows = []
    for hw_name, hw in [("h100", H100_SPEC), ("tpu", TPU_V5E_SPEC)]:
        cfg = get_config(model)
        cm = CostModel(cfg.profile(), hw=hw)
        cluster = ClusterSpec(16, hw=hw)
        reload_s = cm.reload_seconds()
        for name, src, dst in TRANSITIONS:
            placed_src = place_deployment(src, cluster)
            placed_dst = place_deployment(dst, cluster)
            plan = plan_switch(placed_src, placed_dst, cm, hw)
            t = plan.estimate_seconds(hw)
            kv = plan_kv_migration(cm, {i: 4096 for i in range(8)})
            rows.append(
                f"switch/{model}/{hw_name}/{name},{t*1e6:.0f},"
                f"adhoc={t:.2f}s;reload={reload_s:.1f}s;"
                f"speedup={reload_s/max(t,1e-9):.1f}x;"
                f"moved={plan.moved_bytes()/1e9:.1f}GB;"
                f"local={plan.local_bytes/1e9:.1f}GB;"
                f"kv_migrate={kv.estimate_seconds(hw):.2f}s")
    return rows


def macro(model: str = "opt-30b", chips: int = 16) -> list[str]:
    rows = []
    bench = Bench(model=model, chips=chips, n_spans=40, trace_id=2)
    for name, naive in [("adhoc", False), ("naive-reload", True)]:
        pol = OServePolicy(bench.cm, bench.cluster, bench.archetypes,
                           naive_reload=naive)
        res, m = bench.run(pol)
        rows.append(f"switch-e2e/{model}/{name},{m['sim_seconds']*1e6:.0f},"
                    f"p99={m.get('p99', 0):.1f}s;avg={m.get('avg_latency', 0):.1f}s;"
                    f"drop={m['dropped']};switches={res.switch_spans}")
    return rows


def main(fast: bool = True) -> list[str]:
    return micro() + macro()


if __name__ == "__main__":
    for r in main():
        print(r)
