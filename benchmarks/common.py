"""Shared benchmark harness: trace setup, calibration, policy table."""
from __future__ import annotations

import copy
import time

import numpy as np

from repro.configs import get_config
from repro.core.costmodel import CostModel
from repro.core.predictor import WorkloadClusterer, count_series
from repro.core.types import (H100_SPEC, TPU_V5E_SPEC, ClusterSpec,
                              WorkloadType)

HW = {"h100": H100_SPEC, "tpu": TPU_V5E_SPEC}
from repro.serving.baselines import calibrate_rate
from repro.serving.request import apply_slo_budgets, synthesize_trace, span_of


class Bench:
    """One calibrated (model, cluster, trace) experiment context."""

    def __init__(self, model: str = "opt-30b", chips: int = 16,
                 n_spans: int = 40, trace_id: int = 1, k_types: int = 4,
                 utilization: float = 0.95, seed: int = 0, hw: str = "h100"):
        # Default hw: the paper's H100 cluster (paper-fidelity results);
        # hw="tpu" runs the v5e adaptation (use ~4-8x the chips: 16 GB HBM).
        self.cfg = get_config(model)
        self.cm = CostModel(self.cfg.profile(), hw=HW[hw])
        self.cluster = ClusterSpec(chips, hw=HW[hw])
        self.n_spans = n_spans
        self.trace_id = trace_id

        probe = synthesize_trace(n_spans, 100, trace_id, seed)
        il = np.array([r.in_len for r in probe])
        ol = np.array([r.out_len for r in probe])
        self.clusterer, labels = WorkloadClusterer.fit(il, ol, k_types, seed)
        self.archetypes = [WorkloadType(int(c[0]), int(c[1]))
                           for c in self.clusterer.raw_centroids]
        # Paper protocol: per-span arrival rates track the mix-dependent
        # cluster capacity (neither over- nor under-utilized at any time).
        probe_spans = np.array([span_of(r) for r in probe])
        probe_labels = self.clusterer.assign(il, ol)
        pc = count_series(probe_labels, probe_spans, k_types, n_spans)
        mixes = pc / np.maximum(pc.sum(1, keepdims=True), 1)
        # calibrate capacity on a handful of anchor mixes, interpolate by
        # nearest anchor (searches are the expensive part)
        anchors = [0, n_spans // 4, n_spans // 2, 3 * n_spans // 4,
                   n_spans - 1]
        caps = {}
        for a in anchors:
            caps[a] = calibrate_rate(self.cm, chips, self.archetypes,
                                     mixes[a], utilization=utilization)
        rate_per_span = np.array([
            caps[min(anchors, key=lambda a: np.abs(mixes[a] - mixes[s]).sum())]
            for s in range(n_spans)])
        self.rate = float(rate_per_span.mean())
        self.requests = synthesize_trace(n_spans, self.rate, trace_id, seed,
                                         rate_per_span=rate_per_span)
        il = np.array([r.in_len for r in self.requests])
        ol = np.array([r.out_len for r in self.requests])
        self.labels = self.clusterer.assign(il, ol)
        self.counts = count_series(
            self.labels, np.array([span_of(r) for r in self.requests]),
            k_types, n_spans)
        self.avg_rates = self.counts.mean(0)

    def tagged_requests(self):
        rs = copy.deepcopy(self.requests)
        for r, l in zip(rs, self.labels):
            r.type_id = int(l)
        return apply_slo_budgets(rs)

    def run(self, policy, queue_cap: float = 240.0):
        from repro.serving.simulator import simulate
        t0 = time.time()
        res = simulate(self.tagged_requests(), policy, self.cm,
                       self.archetypes, self.n_spans,
                       queue_cap_seconds=queue_cap)
        m = res.metrics()
        m["sim_seconds"] = time.time() - t0
        return res, m


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
