"""Content-addressed prefix cache: prefill savings and TTFT, cache on/off.

Replays a shared-prefix trace (every request = one common template prefix
+ a short unique tail, the agent-loop / few-shot-prompt shape) through one
``ServingEngine`` twice — prefix cache off, then on — and measures what the
cache actually buys:

  * ``prefill_tokens`` — tokens that went through a prefill forward.  With
    the cache on, every request after the first attaches the template's
    pages by refcount and prefills only its unique tail, so the count must
    collapse by ``(prefix + tail) / tail`` (>= 5x gated here and in CI).
  * ``ttft_ms`` — submit until the first generated token is on the host,
    per request.  Skipping the template's prefill forward is the whole
    point: mean TTFT with the cache on must come in under cache-off.

Requests run one at a time (submit -> first token -> drain) so TTFT is a
clean per-request number and later requests always see earlier pages
published.  Several rounds on one engine per mode: round 1 warms every jit
shape (full-prompt prefill for off/first-miss, tail-only for on); the best
post-warmup round is reported.  Tails are unique across rounds, so the
cache-on steady state keeps re-matching the template while still doing
real tail prefills.  Greedy outputs must be identical across modes.

Emits the standard CSV rows and writes ``BENCH_prefix.json`` at the repo
root.  Acceptance: >= 5x fewer prefill-forward tokens and lower mean TTFT
with the cache on, at exact greedy token parity.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params

BENCH_JSON = (pathlib.Path(__file__).resolve().parents[1]
              / "BENCH_prefix.json")
BLOCK = 8
PREFIX_LEN = 192        # 24 full pages of shared template
TAIL_LEN = 8            # unique per-request suffix (one page)
NEW_TOKENS = 8
SAVINGS_MIN = 5.0       # CI gate: prefill-token collapse with cache on


def _trace(cfg, n_requests: int, rounds: int) -> list[list[np.ndarray]]:
    """One template, ``n_requests * rounds`` unique tails: round r replays
    the same template with fresh tails, so a warm cache still hits."""
    from repro.serving.request import shared_prefix_prompts
    prompts = shared_prefix_prompts(n_requests * rounds, PREFIX_LEN,
                                    TAIL_LEN, vocab=cfg.vocab_size, seed=3)
    return [prompts[r * n_requests:(r + 1) * n_requests]
            for r in range(rounds)]


def _run_round(eng, prompts, rid0: int) -> tuple[list[float], dict]:
    """Submit -> first token (TTFT) -> drain, one request at a time."""
    ttfts: list[float] = []
    outs: dict[int, list[int]] = {}
    for i, prompt in enumerate(prompts):
        rid = rid0 + i
        eng.submit(rid, prompt, NEW_TOKENS)
        t0 = time.perf_counter()
        first = None
        while rid not in outs:
            done = eng.step()
            if first is None and any(
                    r.rid == rid and r.generated
                    for r in list(eng.active.values()) + done):
                first = time.perf_counter() - t0   # token int is on host
            for r in done:
                outs[r.rid] = list(r.generated)
        ttfts.append(first)
    return ttfts, outs


def _measure_mode(cfg, params, cache: bool, n_requests: int,
                  rounds: int) -> dict:
    import jax.numpy as jnp

    from repro.serving.engine import ServingEngine

    eng = ServingEngine(cfg, params, num_blocks=96, block_size=BLOCK,
                        max_seqs=2, prefix_cache=cache, dtype=jnp.float32)
    per_round = []
    outs_all: list[dict] = []
    for r, prompts in enumerate(_trace(cfg, n_requests, rounds)):
        mark = eng.prefill_tokens
        ttfts, outs = _run_round(eng, prompts, rid0=r * n_requests)
        per_round.append({"prefill_tokens": eng.prefill_tokens - mark,
                          "mean_ttft_ms": float(np.mean(ttfts)) * 1e3})
        outs_all.append(outs)
    best = min(per_round[1:], key=lambda d: d["mean_ttft_ms"])
    out = {"mode": "on" if cache else "off", "n_requests": n_requests,
           "prefill_tokens": best["prefill_tokens"],
           "mean_ttft_ms": best["mean_ttft_ms"],
           "outs": outs_all}
    if cache:
        pc = eng.prefix_cache
        out.update(hits=pc.hits, misses=pc.misses,
                   hit_tokens=pc.hit_tokens,
                   evicted_bytes=pc.evicted_bytes,
                   restored_bytes=pc.restored_bytes)
    return out


def main(fast: bool = True) -> list[str]:
    n_requests = 6 if fast else 12
    rounds = 3
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = []
    rows = []
    for cache in (False, True):
        r = _measure_mode(cfg, params, cache, n_requests, rounds)
        results.append(r)
        rows.append(f"prefix/{r['mode']}/n{n_requests},"
                    f"{r['prefill_tokens']},"
                    f"prefill_tok={r['prefill_tokens']}"
                    f";ttft_ms={r['mean_ttft_ms']:.2f}")
    by = {r["mode"]: r for r in results}
    # greedy parity: the cache must be invisible in the tokens, every round
    assert by["on"].pop("outs") == by["off"].pop("outs"), \
        "prefix cache changed greedy output"
    savings = (by["off"]["prefill_tokens"]
               / max(by["on"]["prefill_tokens"], 1))
    ttft_x = by["off"]["mean_ttft_ms"] / max(by["on"]["mean_ttft_ms"], 1e-9)
    # regression guards (CI runs this): every post-warmup request must hit
    # the template, collapse prefill >= 5x, and actually shave TTFT
    assert by["on"]["hits"] >= (rounds - 1) * n_requests, \
        "warm rounds missed the cached template"
    assert savings >= SAVINGS_MIN, \
        f"cache only cut prefill tokens {savings:.1f}x (needs >= " \
        f"{SAVINGS_MIN}x)"
    assert ttft_x > 1.0, \
        f"cache-on TTFT {by['on']['mean_ttft_ms']:.2f}ms not under " \
        f"cache-off {by['off']['mean_ttft_ms']:.2f}ms"
    rows.append(f"prefix/gain/n{n_requests},0,"
                f"prefill_savings_x={savings:.1f};ttft_x={ttft_x:.2f}")
    BENCH_JSON.write_text(json.dumps({
        "bench": "prefix_cache",
        "model": cfg.name,
        "backend": jax.default_backend(),
        "prefix_len": PREFIX_LEN,
        "tail_len": TAIL_LEN,
        "new_tokens": NEW_TOKENS,
        "rounds": rounds,
        "results": results,
        "prefill_savings_x": savings,
        "ttft_speedup_x": ttft_x,
    }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in main(fast=True):
        print(row)
