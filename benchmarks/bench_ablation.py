"""Paper Fig. 14 ablation: vLLM(reload) -> +heterogeneous deployment ->
+optimal (flow) workload assignment.

Reported at the scheduler level (the paper's Appendix-D completion-time
story): for fixed demand mixes, the max-utilization (makespan proxy) and
served throughput of
  (a) best homogeneous deployment + capacity-proportional routing,
  (b) heterogeneous deployment + proportional routing,
  (c) heterogeneous deployment + max-flow assignment (full OServe),
plus the Appendix-D worked example as an exact check.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.assignment import assign_workloads
from repro.core.costmodel import CostModel
from repro.core.deployment import flow_guided_search
from repro.core.flownet import WorkloadFlowNetwork
from repro.core.types import H100_SPEC, WorkloadType
from repro.serving.baselines import _balanced_fractions, _best_uniform


def appendix_d() -> list[str]:
    """The paper's worked example: 20s -> 16.67s -> 13.67s completion."""
    rows = []
    lam = [100.0, 50.0]
    # case 1: two identical replicas, type 1 -> r1, type 2 -> r2
    t1 = max(100 / 10.0, 50 / 5.0)
    # case 2: split type 2 across two small replicas
    t2 = max(100 / 10.0, 25 / 3.0, 25 / 3.0)
    t2 = max(100 / 10.0, (50 / 2) / 3.0)
    # case 3: solved by the flow network (balance fractions)
    horizon = 13.67
    net = WorkloadFlowNetwork(
        lam, [[10 * horizon, 5 * horizon],
              [5 * horizon, 3 * horizon],
              [5 * horizon, 3 * horizon]])
    sol = net.balance(net.solve())
    served = sol.throughput
    rows.append(f"ablation/appendix-d,0,case1=20.0s;case2={t2:.2f}s;"
                f"case3_served={served:.1f}/150@13.67s;"
                f"util={max(sol.utilization):.3f}")
    return rows


def main(fast: bool = True) -> list[str]:
    rows = appendix_d()
    cfg = get_config("opt-66b")
    cm = CostModel(cfg.profile(), hw=H100_SPEC)
    archetypes = [WorkloadType(1275, 287), WorkloadType(139, 133),
                  WorkloadType(1181, 1824), WorkloadType(282, 1121)]
    mixes = {"P1-short": [0.20, 0.60, 0.05, 0.15],
             "P6-long": [0.10, 0.15, 0.45, 0.30]}
    for name, mix in mixes.items():
        # saturating demand exposes capacity differences
        ws = [a.with_rate(4000.0 * m) for a, m in zip(archetypes, mix)]
        dep_u, _ = _best_uniform(cm, 16, ws)
        res_a = assign_workloads(cm, dep_u, ws, balance=False)
        fr = np.array(_balanced_fractions(dep_u, cm, ws))
        rates = np.array([w.rate for w in ws])
        x_prop = fr * rates[None, :]
        util_prop = max(
            sum(x_prop[k][j] / res_a.n_cap[k][j]
                for j in range(len(ws)) if res_a.n_cap[k][j] > 0)
            for k in range(dep_u.dp))
        het = flow_guided_search(cm, 16, ws, max_tp=8, max_pp=4, seed=0)
        res_c = het.assignment
        rows.append(
            f"ablation/{name}/a_homo+prop,0,"
            f"thr={min(x_prop.sum(), res_a.throughput):.0f};util={util_prop:.3f};dep={dep_u}")
        res_b = assign_workloads(cm, het.deployment, ws)
        rows.append(
            f"ablation/{name}/b_hetero+prop,0,"
            f"thr={res_b.throughput:.0f};util={res_b.latency_proxy():.3f};"
            f"dep={het.deployment}")
        rows.append(
            f"ablation/{name}/c_hetero+flow,0,"
            f"thr={res_c.throughput:.0f};util={res_c.latency_proxy():.3f}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
