"""Paper Figures 9-12: end-to-end latency & throughput, OServe vs baselines.

One row per (model x chips x trace x policy): P99/avg latency, throughput,
drops, switch count.  `--chips 32` reproduces the 32-GPU scaling study
(Fig. 12); per-span P1-P6 slices reproduce Fig. 9.

``real_validation`` closes the loop on the simulator itself: the same
orchestrator plans are executed on real JAX engines (``ClusterRuntime``,
smoke-scale model) and the planner's predicted per-replica traffic shares
are compared against the shares the engines actually served — the
``e2e-real`` rows report the L1 share error plus live-switch counters
(drained / migrated requests).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench
from repro.serving.baselines import (DynamoPolicy, LlumnixPolicy,
                                     OServePolicy, RoundRobinPolicy,
                                     VLLMReloadPolicy, VLLMStaticPolicy)


def policies(bench: Bench) -> dict:
    cm, cl, arch, avg = (bench.cm, bench.cluster, bench.archetypes,
                         bench.avg_rates)
    return {
        "oserve": lambda: OServePolicy(cm, cl, arch),
        "vllm-static": lambda: VLLMStaticPolicy(cm, cl, arch, avg),
        "vllm-reload": lambda: VLLMReloadPolicy(cm, cl, arch),
        "llumnix": lambda: LlumnixPolicy(cm, cl, arch, avg),
        "round-robin": lambda: RoundRobinPolicy(cm, cl, arch, avg),
        "dynamo": lambda: DynamoPolicy(cm, cl, arch, avg),
    }


def run(model: str = "opt-30b", chips: int = 16, trace_id: int = 1,
        n_spans: int = 40, spans_detail: bool = False,
        hw: str = "h100") -> list[str]:
    bench = Bench(model=model, chips=chips, n_spans=n_spans,
                  trace_id=trace_id, hw=hw)
    rows = []
    base = {}
    for name, mk in policies(bench).items():
        res, m = bench.run(mk())
        base[name] = m
        rows.append(
            f"e2e/{model}/{chips}c/{hw}/t{trace_id}/{name},"
            f"{m['sim_seconds']*1e6:.0f},"
            f"p99={m.get('p99', float('inf')):.1f}s"
            f";avg={m.get('avg_latency', float('inf')):.1f}s"
            f";thr={m['throughput_rps']:.2f}rps"
            f";good={m['goodput_rps']:.2f}rps"
            f";slo={m['slo_attainment']:.2f}"
            f";drop={m['dropped']};switch={res.switch_spans}")
        if spans_detail and name in ("oserve", "vllm-static"):
            picks = np.linspace(1, bench.n_spans - 1, 6).astype(int)  # P1-P6
            for pi, s in enumerate(picks):
                sm = res.span_metrics(int(s))
                rows.append(f"e2e/{model}/{chips}c/t{trace_id}/{name}/P{pi+1},"
                            f"0,p99={sm['p99']:.1f}s;n={sm['n']}")
    if "oserve" in base and "vllm-static" in base:
        o, v = base["oserve"], base["vllm-static"]
        gain_p99 = v.get("p99", 1) / max(o.get("p99", 1e-9), 1e-9)
        gain_thr = o["throughput_rps"] / max(v["throughput_rps"], 1e-9)
        gain_good = o["goodput_rps"] / max(v["goodput_rps"], 1e-9)
        rows.append(f"e2e/{model}/{chips}c/t{trace_id}/gain,0,"
                    f"p99_x={gain_p99:.2f};thr_x={gain_thr:.2f}"
                    f";good_x={gain_good:.2f}")
    return rows


def real_validation(model: str = "opt-30b", chips: int = 6,
                    n_spans: int = 2, requests_per_span: int = 6,
                    seed: int = 0) -> list[str]:
    """Execute orchestrator plans on real engines; score plan vs reality.

    Runs with the telemetry layer enabled, so beyond the per-span share
    rows it reports the measured request-latency distributions (TTFT /
    TPOT / queue delay p50/p95/p99 from ``Metrics``) and the decision
    audit's prediction calibration error (mean L1 between each
    ``plan_span``'s predicted replica token share and the share the
    engines realized).
    """
    from repro.serving.telemetry import Telemetry
    from repro.serving.validation import run_real_spans

    telemetry = Telemetry()
    outcomes, runtime = run_real_spans(
        model=model, chips=chips, n_spans=n_spans,
        requests_per_span=requests_per_span, seed=seed,
        telemetry=telemetry)
    rows = []
    for o in outcomes:
        rows.append(
            f"e2e-real/{model}/{chips}c/span{o.span},"
            f"{o.seconds * 1e6:.0f},"
            f"dep={o.plan.deployment};share_l1={o.share_l1:.2f}"
            f";drained={o.switch.drained};migrated={o.switch.migrated}"
            f";handoff={o.switch.handoff}"
            f";recompute={o.switch.recompute_tokens}"
            f";completed={o.report.completed}")
    done = sum(1 for r in runtime.results.values() if r.done)
    rows.append(f"e2e-real/{model}/{chips}c/total,0,"
                f"completed={done}/{n_spans * requests_per_span};switches="
                f"{sum(1 for r in runtime.switch_reports[1:] if r.changed)}")
    for name in ("ttft_s", "tpot_s", "queue_delay_s"):
        h = telemetry.metrics.histograms.get(name)
        if h is None:
            continue
        s = h.summary()
        rows.append(f"e2e-real/{model}/{chips}c/{name},0,"
                    f"n={s['count']};p50={s['p50'] * 1e3:.1f}ms"
                    f";p95={s['p95'] * 1e3:.1f}ms"
                    f";p99={s['p99'] * 1e3:.1f}ms")
    calib = telemetry.audit.calibration_error()
    if calib is not None:
        joined = sum(1 for r in telemetry.audit.records if r.joined)
        rows.append(f"e2e-real/{model}/{chips}c/calibration,0,"
                    f"share_l1={calib:.3f};decisions={joined}")
    return rows


def main(fast: bool = True) -> list[str]:
    rows = []
    combos = ([("opt-30b", 16, 1), ("opt-30b", 16, 2)] if fast else
              [("opt-30b", 16, 1), ("opt-30b", 16, 2),
               ("opt-66b", 16, 1), ("llama2-70b", 16, 1),
               ("llama2-70b", 32, 1), ("llama-30b", 8, 2)])
    for model, chips, trace in combos:
        rows.extend(run(model, chips, trace, spans_detail=True))
    rows.extend(real_validation(n_spans=2 if fast else 4))
    return rows


if __name__ == "__main__":
    for r in main(fast=False):
        print(r)
