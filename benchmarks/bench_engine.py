"""Engine decode throughput: device-resident paged path vs dense gather.

One replica, greedy decode on the CPU smoke model: tokens/sec and per-step
wall time vs batch size {1, 2, 4, 8} for the fused paged decode step vs the
legacy dense-gather path (``decode_mode="dense"``).  The dense path pays a
full KV materialization plus a fresh XLA compile per step (the cache shape
grows every token); the paged path is one bucketed jitted step.  Emits the
standard CSV rows and writes ``BENCH_engine.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params

PROMPT_LEN = 16
BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_engine.json"


def _time_mode(cfg, params, mode: str, batch: int, new_tokens: int) -> dict:
    import jax.numpy as jnp

    from repro.serving.engine import ServingEngine
    eng = ServingEngine(cfg, params, num_blocks=256, block_size=8,
                        max_seqs=batch, dtype=jnp.float32, decode_mode=mode)
    rng = np.random.RandomState(0)
    for i in range(batch):
        eng.submit(i, rng.randint(0, cfg.vocab_size, PROMPT_LEN)
                   .astype(np.int32), new_tokens)
    eng.step()                      # prefill (same length -> one batch)
    eng.step()                      # warm the decode path
    t0 = time.perf_counter()
    steps = 0
    while eng.active:
        eng.step()
        steps += 1
    dt = time.perf_counter() - t0
    toks = steps * batch            # all sequences stay active to the end
    return {"mode": mode, "batch": batch, "decode_steps": steps,
            "step_ms": dt / max(steps, 1) * 1e3,
            "tokens_per_sec": toks / max(dt, 1e-9)}


def main(fast: bool = True) -> list[str]:
    batches = (1, 2, 4, 8) if fast else (1, 2, 4, 8, 16)
    new_tokens = 8 if fast else 16
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = []
    rows = []
    for batch in batches:
        per_batch = {}
        for mode in ("dense", "paged"):
            r = _time_mode(cfg, params, mode, batch, new_tokens)
            results.append(r)
            per_batch[mode] = r
            rows.append(f"engine/{mode}/b{batch},{r['step_ms'] * 1e3:.0f},"
                        f"tok_s={r['tokens_per_sec']:.2f}"
                        f";steps={r['decode_steps']}")
        gain = (per_batch["paged"]["tokens_per_sec"]
                / max(per_batch["dense"]["tokens_per_sec"], 1e-9))
        rows.append(f"engine/gain/b{batch},0,paged_x={gain:.2f}")
    BENCH_JSON.write_text(json.dumps({
        "bench": "engine_decode",
        "model": cfg.name,
        "backend": jax.default_backend(),
        "prompt_len": PROMPT_LEN,
        "new_tokens": new_tokens,
        "results": results,
    }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in main(fast=False):
        print(row)
