"""Engine decode throughput: paged vs dense gather, and the horizon sweep.

One replica, greedy decode on the CPU smoke model, two sweeps:

  * batch {1, 2, 4, 8}: the fused paged decode step vs the legacy
    dense-gather path (``decode_mode="dense"``).  The dense path pays a
    full KV materialization plus a fresh XLA compile per step (the cache
    shape grows every token); the paged path is one bucketed jitted step.
  * horizon H in {1, 4, 8, 16}: the fused multi-step decode loop
    (``decode_horizon=H``) — one jit dispatch + ONE device→host transfer
    per H tokens instead of per token.  Asserted invariants (run in CI):
    exactly one transfer per horizon (``decode_syncs`` matches the horizon
    schedule), token parity across horizons, and >= 2x tokens/sec for H=8
    vs the per-step paged path.

Emits the standard CSV rows and writes ``BENCH_engine.json`` at the repo
root.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params

PROMPT_LEN = 16
HORIZONS = (1, 4, 8, 16)
HORIZON_BATCH = 4
HORIZON_NEW_TOKENS = 65          # 64 decode token-steps: all H divide evenly
BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_engine.json"


def _time_mode(cfg, params, mode: str, batch: int, new_tokens: int) -> dict:
    import jax.numpy as jnp

    from repro.serving.engine import ServingEngine
    eng = ServingEngine(cfg, params, num_blocks=256, block_size=8,
                        max_seqs=batch, dtype=jnp.float32, decode_mode=mode)
    rng = np.random.RandomState(0)
    for i in range(batch):
        eng.submit(i, rng.randint(0, cfg.vocab_size, PROMPT_LEN)
                   .astype(np.int32), new_tokens)
    eng.step()                      # prefill (same length -> one batch)
    eng.step()                      # warm the decode path
    t0 = time.perf_counter()
    steps = 0
    while eng.active:
        eng.step()
        steps += 1
    dt = time.perf_counter() - t0
    toks = steps * batch            # all sequences stay active to the end
    return {"mode": mode, "batch": batch, "decode_steps": steps,
            "step_ms": dt / max(steps, 1) * 1e3,
            "tokens_per_sec": toks / max(dt, 1e-9)}


def _expected_syncs(new_tokens: int, horizon: int) -> int:
    """Fused dispatches a full run takes: prefill emits token 1, then the
    engine covers the remaining ``new_tokens - 1`` token-steps in horizons
    of ``min(H, remaining)`` floored to a power of two."""
    rem, syncs = new_tokens - 1, 0
    while rem > 0:
        h = min(horizon, rem)
        h = 1 << (h.bit_length() - 1)
        rem -= h
        syncs += 1
    return syncs


class _HorizonBench:
    """One warmed engine per horizon, timed in interleaved rounds.

    Interleaving (round r times EVERY horizon back to back) pairs the
    measurements so machine-load drift hits all horizons alike; the
    reported cost is the MEDIAN over every per-dispatch time pooled across
    rounds (see ``timed_round`` — outlier-robust without the
    sample-count bias a minimum would have).
    """

    def __init__(self, cfg, params, horizon: int, batch: int,
                 new_tokens: int):
        import jax.numpy as jnp

        from repro.serving.engine import ServingEngine
        self.eng = ServingEngine(cfg, params, num_blocks=256, block_size=8,
                                 max_seqs=batch, dtype=jnp.float32,
                                 decode_mode="paged", decode_horizon=horizon)
        self.horizon = horizon
        self.batch = batch
        self.new_tokens = new_tokens
        self.rep = 0
        rng = np.random.RandomState(0)
        self.prompts = [rng.randint(0, cfg.vocab_size, PROMPT_LEN)
                        .astype(np.int32) for _ in range(batch)]
        # warm pass: compiles every horizon/page bucket, records parity
        # tokens, and checks the one-transfer-per-horizon invariant
        self._submit()
        self.eng.step()
        self.tokens = {r.rid: list(map(int, r.generated))
                       for r in self.eng.run_to_completion()}
        expect = _expected_syncs(new_tokens, horizon)
        assert self.eng.decode_syncs == expect, (
            f"H={horizon}: {self.eng.decode_syncs} device→host transfers, "
            f"expected one per horizon = {expect}")
        self.times: list[float] = []
        self.syncs = 0

    def _submit(self):
        for i, p in enumerate(self.prompts):
            self.eng.submit(self.rep * self.batch + i, p, self.new_tokens)
        self.rep += 1

    def timed_round(self) -> None:
        """Time every decode dispatch individually.

        ``new_tokens - 1`` is divisible by every swept horizon, so each
        dispatch covers exactly ``horizon`` token-steps.  The MEDIAN
        per-dispatch time is the reported cost: robust to scheduler-noise
        outliers, and — unlike a minimum — not biased toward whichever
        horizon produced more samples to get lucky over.
        """
        self._submit()
        self.eng.step()                  # prefill (same length -> one batch)
        s0 = self.eng.decode_syncs
        while self.eng.active:
            t0 = time.perf_counter()
            self.eng.step()
            self.times.append(time.perf_counter() - t0)
        self.syncs = self.eng.decode_syncs - s0

    def result(self) -> dict:
        toks = self.batch * (self.new_tokens - 1)   # timed region: decode
        med = float(np.median(self.times))
        return {"mode": "paged", "horizon": self.horizon,
                "batch": self.batch, "decode_tokens": toks,
                "syncs": self.syncs,
                "step_ms": med * 1e3,
                "tokens_per_sec": (self.batch * self.horizon
                                   / max(med, 1e-9))}


def _sweep_once(cfg, params, new_tokens: int, rounds: int
                ) -> tuple[list[dict], float]:
    benches = [_HorizonBench(cfg, params, h, HORIZON_BATCH, new_tokens)
               for h in HORIZONS]
    base = benches[0].tokens             # HORIZONS[0] == 1: per-step stream
    for b in benches:                    # token parity across horizons
        assert b.tokens == base, (
            f"H={b.horizon} diverged from per-step tokens")
    for _ in range(rounds):
        for b in benches:
            b.timed_round()
    results = [b.result() for b in benches]
    by_h = {r["horizon"]: r for r in results}
    gain = (by_h[8]["tokens_per_sec"]
            / max(by_h[1]["tokens_per_sec"], 1e-9))
    return results, gain


def horizon_sweep(cfg, params, new_tokens: int = HORIZON_NEW_TOKENS,
                  rounds: int = 4, attempts: int = 4
                  ) -> tuple[list[dict], list[str]]:
    """H sweep + the CI-asserted invariants (transfer count, parity, 2x).

    Parity and the one-transfer-per-horizon count are deterministic and
    asserted on every attempt.  The >= 2x throughput gate is a *timing*
    measurement on whatever loaded CI box runs it, so a sub-threshold
    sweep is re-measured (up to ``attempts``) before failing — a real
    regression (horizon re-serialized, extra syncs) fails every attempt.
    """
    results, gain = _sweep_once(cfg, params, new_tokens, rounds)
    for _ in range(attempts - 1):
        if gain >= 2.0:
            break
        re_results, re_gain = _sweep_once(cfg, params, new_tokens, rounds)
        if re_gain > gain:               # keep the best-measured sweep
            results, gain = re_results, re_gain
    assert gain >= 2.0, (
        f"H=8 must be >= 2x tokens/sec over per-step paged decode, "
        f"got {gain:.2f}x")
    rows = []
    for r in results:
        rows.append(f"engine/horizon/h{r['horizon']},"
                    f"{r['step_ms'] * 1e3:.0f},"
                    f"tok_s={r['tokens_per_sec']:.2f};syncs={r['syncs']}")
    rows.append(f"engine/horizon/gain_h8,0,x={gain:.2f}")
    return results, rows


def telemetry_sweep(cfg, params, batch: int = 4, new_tokens: int = 17,
                    rounds: int = 3) -> tuple[dict, list[str]]:
    """Tracer overhead: identical decode runs with telemetry enabled vs the
    ``NULL_TELEMETRY`` no-op default.

    Events fire only at host-side boundaries (submit/admit/dispatch/sync/
    retire), so the enabled run should cost within noise of the disabled
    one; ``check_regression`` gates the measured ratio.  Rounds interleave
    the two engines so machine-load drift hits both alike, and the
    reported tokens/sec uses the per-round MEDIAN.
    """
    import jax.numpy as jnp

    from repro.serving.engine import ServingEngine
    from repro.serving.telemetry import Telemetry

    engines = {
        "disabled": ServingEngine(cfg, params, num_blocks=256, block_size=8,
                                  max_seqs=batch, dtype=jnp.float32),
        "enabled": ServingEngine(cfg, params, num_blocks=256, block_size=8,
                                 max_seqs=batch, dtype=jnp.float32,
                                 telemetry=Telemetry()),
    }
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
               for _ in range(batch)]
    rep = dict.fromkeys(engines, 0)
    times: dict[str, list[float]] = {name: [] for name in engines}

    def one_round(name: str, timed: bool) -> None:
        eng = engines[name]
        for i, p in enumerate(prompts):
            eng.submit(rep[name] * batch + i, p, new_tokens)
        rep[name] += 1
        eng.step()                   # prefill (same length -> one batch)
        t0 = time.perf_counter()
        while eng.active:
            eng.step()
        if timed:
            times[name].append(time.perf_counter() - t0)

    for name in engines:             # warm pass compiles both paths
        one_round(name, timed=False)
    for _ in range(rounds):
        for name in engines:
            one_round(name, timed=True)
    toks = batch * (new_tokens - 1)  # timed region covers decode only
    tps = {name: toks / max(float(np.median(ts)), 1e-9)
           for name, ts in times.items()}
    overhead = tps["disabled"] / max(tps["enabled"], 1e-9)
    result = {"batch": batch, "new_tokens": new_tokens,
              "disabled_tps": tps["disabled"],
              "enabled_tps": tps["enabled"],
              "overhead_x": overhead,
              "events": len(engines["enabled"].telemetry.tracer.events)}
    rows = [f"engine/telemetry/disabled,0,tok_s={tps['disabled']:.2f}",
            f"engine/telemetry/enabled,0,tok_s={tps['enabled']:.2f}"
            f";events={result['events']}",
            f"engine/telemetry/overhead,0,x={overhead:.3f}"]
    return result, rows


def main(fast: bool = True) -> list[str]:
    batches = (1, 2, 4, 8) if fast else (1, 2, 4, 8, 16)
    new_tokens = 8 if fast else 16
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = []
    rows = []
    for batch in batches:
        per_batch = {}
        for mode in ("dense", "paged"):
            r = _time_mode(cfg, params, mode, batch, new_tokens)
            results.append(r)
            per_batch[mode] = r
            rows.append(f"engine/{mode}/b{batch},{r['step_ms'] * 1e3:.0f},"
                        f"tok_s={r['tokens_per_sec']:.2f}"
                        f";steps={r['decode_steps']}")
        gain = (per_batch["paged"]["tokens_per_sec"]
                / max(per_batch["dense"]["tokens_per_sec"], 1e-9))
        rows.append(f"engine/gain/b{batch},0,paged_x={gain:.2f}")
    horizon_results, horizon_rows = horizon_sweep(cfg, params)
    rows.extend(horizon_rows)
    telemetry_result, telemetry_rows = telemetry_sweep(cfg, params)
    rows.extend(telemetry_rows)
    BENCH_JSON.write_text(json.dumps({
        "bench": "engine_decode",
        "model": cfg.name,
        "backend": jax.default_backend(),
        "prompt_len": PROMPT_LEN,
        "new_tokens": new_tokens,
        "results": results,
        "horizon": {
            "batch": HORIZON_BATCH,
            "new_tokens": HORIZON_NEW_TOKENS,
            "results": horizon_results,
        },
        "telemetry": telemetry_result,
    }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    import sys
    for row in main(fast="--fast" in sys.argv):
        print(row)
