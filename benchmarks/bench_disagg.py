"""Disaggregated prefill/decode serving vs a mixed-role baseline.

Runs the same seeded long-prompt-heavy burst twice on a 2-replica
shared-pool cluster:

  * **mixed** — two mixed-role replicas split the traffic; every request
    prefills AND decodes in place, so a decoding sequence holds its slot
    for its whole output length and queued long prompts wait behind it;
  * **disagg** — one ``prefill`` replica admits everything and hands each
    first-token-ready context to one ``decode`` replica through the
    same-pool page handoff (zero bytes, zero recomputed tokens); the
    prefill replica's slots free at first token, so the queue drains at
    prefill speed instead of decode speed.

The whole run is driven on a *virtual* clock (one unit per cluster tick)
threaded through ``Telemetry``, so every number here — TTFT/TPOT p95 in
tick units, handoff counts, recompute tokens — is deterministic and
machine-independent: ``check_regression.py`` gates them exactly against
the committed ``BENCH_disagg.json``.

Emits the standard CSV rows and writes ``BENCH_disagg.json`` at the repo
root.  Acceptance (asserted inline, re-checked by the regression gate):
greedy token parity between the two modes, every disagg context moves by
exactly one zero-recompute handoff, and disagg TTFT p95 beats mixed on
this long-prompt-heavy burst.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params

BENCH_JSON = (pathlib.Path(__file__).resolve().parents[1]
              / "BENCH_disagg.json")
BLOCK = 8


class _Plan:
    def __init__(self, rcs, fractions):
        from repro.core.types import Deployment
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions


class _TickClock:
    """Virtual time: the driver advances one unit per cluster tick."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _jobs(cfg, n: int, seed: int):
    """Long-prompt-heavy: prompts of 24-42 tokens, short 6-9 outputs."""
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, 24 + (i % 4) * 6)
             .astype(np.int32), 6 + (i % 4)) for i in range(n)]


def _run_mode(cfg, params, disagg: bool, n_requests: int, seed: int) -> dict:
    import jax.numpy as jnp

    from repro.core.types import ReplicaConfig
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.router import FlowRouter
    from repro.serving.telemetry import Telemetry

    if disagg:
        rcs = [ReplicaConfig(2, role="prefill"),
               ReplicaConfig(2, role="decode")]
        fractions = [[1.0], [0.0]]      # only the prefill replica admits
    else:
        rcs = [ReplicaConfig(2), ReplicaConfig(2)]
        fractions = [[0.5], [0.5]]
    clock = _TickClock()
    tm = Telemetry(clock=clock)
    rt = ClusterRuntime(
        cfg, params, total_chips=4, blocks_per_chip=32,
        seqs_per_chip=2, block_size=BLOCK, drain_steps=1,
        router=FlowRouter(fractions), telemetry=tm, dtype=jnp.float32)
    rt.apply_plan(_Plan(rcs, fractions))
    jobs = _jobs(cfg, n_requests, seed)
    for rid, (p, n) in enumerate(jobs):    # one burst: admission-bound
        rt.submit(rid, p, n)
    ticks = 0
    while rt.pending and ticks < 300:
        rt.step()
        clock.t += 1.0
        ticks += 1
    assert rt.pending == 0, "trace did not drain inside the tick budget"
    rep = rt.finish_span()
    ttft = tm.metrics.histograms["ttft_s"].summary()
    tpot = tm.metrics.histograms["tpot_s"].summary()
    prompt_tokens = sum(len(p) for p, _ in jobs)
    return {"mode": "disagg" if disagg else "mixed",
            "n_requests": n_requests,
            "completed": len(rt.results),
            "shed": len(rt.all_shed_rids),
            "ticks": ticks,
            "ttft_p95_ticks": ttft["p95"],
            "tpot_p95_ticks": tpot["p95"],
            "handoffs": rep.handoffs,
            "handoff_path": rep.handoff.handoff,
            "handoff_pages": rep.handoff.pages_handoff,
            "recompute_tokens": rep.handoff.recompute_tokens,
            "prefill_tokens": rt.total_prefill_tokens,
            "prompt_tokens": prompt_tokens,
            "role_util": rep.role_util,
            "tokens": {r: list(map(int, rt.results[r].generated))
                       for r in sorted(rt.results)}}


def main(fast: bool = True) -> list[str]:
    n_requests = 12 if fast else 24
    seed = 11
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = [_run_mode(cfg, params, disagg, n_requests, seed)
               for disagg in (False, True)]
    mixed, disagg = results
    rows = []
    for r in results:
        rows.append(f"disagg/{r['mode']}/n{n_requests},"
                    f"{r['ttft_p95_ticks']:.2f},"
                    f"ttft_p95={r['ttft_p95_ticks']:.2f}"
                    f";tpot_p95={r['tpot_p95_ticks']:.2f}"
                    f";handoffs={r['handoffs']}"
                    f";completed={r['completed']}")
    # the standing bar (CI runs this): greedy token parity across modes,
    # every context exactly one zero-recompute handoff, and a real TTFT win
    assert mixed["completed"] == disagg["completed"] == n_requests
    assert mixed["shed"] == 0 and disagg["shed"] == 0
    assert disagg["tokens"] == mixed["tokens"], \
        "disaggregation changed greedy outputs — parity broken"
    assert disagg["handoffs"] == n_requests, \
        f"expected every request handed off, got {disagg['handoffs']}"
    assert disagg["handoff_path"] == n_requests, \
        "a handoff left the zero-byte same-pool path"
    assert disagg["recompute_tokens"] == 0, \
        "the handoff path recomputed prefill tokens"
    assert disagg["prefill_tokens"] == disagg["prompt_tokens"], \
        (f"prefill forwards saw {disagg['prefill_tokens']} tokens for "
         f"{disagg['prompt_tokens']} prompt tokens — recompute leaked in")
    assert disagg["ttft_p95_ticks"] < mixed["ttft_p95_ticks"], \
        (f"disagg TTFT p95 {disagg['ttft_p95_ticks']} did not beat mixed "
         f"{mixed['ttft_p95_ticks']} on the long-prompt-heavy burst")
    rows.append(f"disagg/gain/n{n_requests},0,"
                f"ttft_mixed={mixed['ttft_p95_ticks']:.2f}"
                f";ttft_disagg={disagg['ttft_p95_ticks']:.2f}")
    # the per-request token dump exists for the parity assert; keep the
    # committed JSON small
    for r in results:
        del r["tokens"]
    BENCH_JSON.write_text(json.dumps({
        "bench": "disagg",
        "model": cfg.name,
        "backend": jax.default_backend(),
        "n_requests": n_requests,
        "results": results,
        "ttft_p95_mixed": mixed["ttft_p95_ticks"],
        "ttft_p95_disagg": disagg["ttft_p95_ticks"],
    }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in main(fast=True):
        print(row)
