"""Switch stall: page handoff vs device page copy vs re-prefill.

Measures the wall-clock stall a deployment switch imposes on migrated
in-flight requests for the three restore paths of
``repro.serving.migration``.  Two numbers per mode:

  * ``stall_ms`` — state-restoration stall: export start until every
    migrated sequence's context is resident on the destination and it can
    resume decoding (for re-prefill that is the prefill forward itself);
  * ``next_token_ms`` — until every migrated request has emitted its next
    token (adds the one decode step the handoff/copy paths still owe).

Restore paths:

  * ``handoff``   source and destination share one ``BlockPool``: ownership
                  re-registers, zero tokens recomputed, zero bytes moved;
  * ``copy``      separate pools, same geometry: jitted page gather/scatter;
  * ``reprefill`` token-state snapshot: the destination re-prefills
                  ``prompt + generated`` (the pre-migration design).

Several rounds per mode on the same engines — the first warms every jit
path, the best of the rest is reported (the handoff path is a handful of
sub-millisecond host/device ops, so per-round dispatch jitter on CPU is
large relative to its steady-state cost).  Emits the standard CSV rows and
writes ``BENCH_switch.json`` at the repo root.  Acceptance: page handoff
>= 5x lower stall than re-prefill on the smoke config.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_switch.json"
BLOCK = 8
NEW_TOKENS = 16


def _measure_mode(cfg, params, mode: str, ctx_len: int, batch: int,
                  rounds: int = 4) -> dict:
    import jax.numpy as jnp

    from repro.serving.engine import ServingEngine
    from repro.serving.kvcache import BlockPool
    from repro.serving.migration import migrate_batch

    blocks = 2 * batch * ((ctx_len + NEW_TOKENS) // BLOCK + 2)
    pool_a = BlockPool(cfg, blocks, BLOCK, jnp.float32)
    pool_b = pool_a if mode == "handoff" else BlockPool(
        cfg, blocks, BLOCK, jnp.float32)
    src = ServingEngine(cfg, params, block_size=BLOCK, max_seqs=batch,
                        pool=pool_a, kv_quota=blocks)
    dst = ServingEngine(cfg, params, block_size=BLOCK, max_seqs=batch,
                        pool=pool_b, kv_quota=blocks)
    rng = np.random.RandomState(0)
    rid = 0
    stalls: list[float] = []
    next_toks: list[float] = []
    report = None
    for _ in range(rounds):                   # round 1 warms every jit path
        ids = []
        for _ in range(batch):
            prompt = rng.randint(0, cfg.vocab_size, ctx_len).astype(np.int32)
            src.submit(rid, prompt, NEW_TOKENS)
            ids.append(rid)
            rid += 1
        src.step()                            # prefill (+ first token)
        src.step()                            # one decode step in flight
        before = {r.rid: len(r.generated) for r in src.active.values()}

        def all_emitted():
            live = {r.rid: r for r in
                    list(dst.active.values()) + dst.waiting}
            return all(len(live[i].generated) > before[i] for i in ids)

        jax.block_until_ready(src.cache.k)
        t0 = time.perf_counter()
        snaps = src.export_inflight(release=(mode == "reprefill"))
        src.release_all()
        report = migrate_batch(dst, snaps)
        if mode == "reprefill":
            # the restore IS the re-prefill forward (it emits the token)
            while not all_emitted():
                dst.step()
            jax.block_until_ready(dst.cache.k)
            stall = next_tok = time.perf_counter() - t0
        else:
            # pages adopted/copied: context is resident right here
            jax.block_until_ready(dst.cache.k)
            jax.block_until_ready(dst.cache.block_table_dev)
            stall = time.perf_counter() - t0
            while not all_emitted():          # + the decode step it owes
                dst.step()
            jax.block_until_ready(dst.cache.k)
            next_tok = time.perf_counter() - t0
        stalls.append(stall)
        next_toks.append(next_tok)
        dst.run_to_completion()               # drain before the next round
        src.resume_admission()
    return {"mode": mode, "ctx_len": ctx_len, "batch": batch,
            "stall_ms": min(stalls[1:]) * 1e3,       # best post-warmup round
            "next_token_ms": min(next_toks[1:]) * 1e3,
            "handoff": report.handoff, "copied": report.copied,
            "reprefilled": report.reprefilled,
            "pages_handoff": report.pages_handoff,
            "pages_copied": report.pages_copied,
            "recompute_tokens": report.recompute_tokens}


def main(fast: bool = True) -> list[str]:
    # smoke model context ceiling is 512: stay under it incl. new tokens
    ctx_len = 448
    batch = 2 if fast else 4
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = []
    rows = []
    for mode in ("handoff", "copy", "reprefill"):
        r = _measure_mode(cfg, params, mode, ctx_len, batch)
        results.append(r)
        rows.append(f"switch/{mode}/ctx{ctx_len}b{batch},"
                    f"{r['stall_ms'] * 1e3:.0f},"
                    f"stall_ms={r['stall_ms']:.2f}"
                    f";next_tok_ms={r['next_token_ms']:.2f}"
                    f";recompute={r['recompute_tokens']}")
    by = {r["mode"]: r for r in results}
    gain = by["reprefill"]["stall_ms"] / max(by["handoff"]["stall_ms"], 1e-9)
    gain_copy = by["reprefill"]["stall_ms"] / max(by["copy"]["stall_ms"], 1e-9)
    # regression guards (CI runs this): the zero-recompute paths must have
    # actually been taken, and handoff must hold its >=5x stall advantage
    assert by["handoff"]["handoff"] == batch, "handoff path not taken"
    assert by["handoff"]["recompute_tokens"] == 0
    assert by["copy"]["copied"] == batch and by["copy"]["recompute_tokens"] == 0
    assert by["reprefill"]["recompute_tokens"] > 0
    assert gain >= 5.0, f"handoff only {gain:.1f}x better than re-prefill"
    rows.append(f"switch/gain/ctx{ctx_len}b{batch},0,"
                f"handoff_x={gain:.1f};copy_x={gain_copy:.1f}")
    BENCH_JSON.write_text(json.dumps({
        "bench": "switch_stall",
        "model": cfg.name,
        "backend": jax.default_backend(),
        "ctx_len": ctx_len,
        "batch": batch,
        "new_tokens": NEW_TOKENS,
        "results": results,
        "handoff_vs_reprefill_x": gain,
        "copy_vs_reprefill_x": gain_copy,
    }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in main(fast=True):
        print(row)
