"""Paper Fig. 6 + S5.3 predictor study: per-type LSTM vs MA vs aggregate LSTM.

Reports held-out RRMSE per method (paper: LSTM ~5%, MA ~43%, aggregate ~40%)
and prediction wall time (paper: <30 ms per prediction).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.predictor import (LSTMWorkloadPredictor, MovingAveragePredictor,
                                  WorkloadClusterer, count_series, rrmse)
from repro.serving.request import span_of, synthesize_trace


def main(fast: bool = True, n_spans: int = 300, k: int = 4,
         epochs: int = 250) -> list[str]:
    reqs = synthesize_trace(n_spans, 400, trace_id=3, seed=0)
    il = np.array([r.in_len for r in reqs])
    ol = np.array([r.out_len for r in reqs])
    cl, _ = WorkloadClusterer.fit(il, ol, k, seed=0)
    labels = cl.assign(il, ol)
    series = count_series(labels, np.array([span_of(r) for r in reqs]),
                          k, n_spans)
    split = int(0.9 * n_spans)      # paper: 90/10 train/test
    rows = []

    lstm = LSTMWorkloadPredictor(k, window=50, hidden=32, seed=0)
    t0 = time.time()
    lstm.fit(series[:split], epochs=epochs)
    fit_s = time.time() - t0
    t0 = time.time()
    preds = lstm.predict_series(series)
    pred_ms = (time.time() - t0) / max(len(series) - 50, 1) * 1e3
    r = rrmse(preds[split - 50:], series[split:])
    rows.append(f"predictor/lstm-per-type,{pred_ms*1e3:.0f},"
                f"rrmse={100*r:.2f}%;fit={fit_s:.1f}s;pred={pred_ms:.1f}ms")

    ma = MovingAveragePredictor(k, window=5)
    r_ma = rrmse(ma.predict_series(series, start=50)[split - 50:],
                 series[split:])
    rows.append(f"predictor/moving-average,0,rrmse={100*r_ma:.2f}%")

    agg = LSTMWorkloadPredictor(k, window=50, hidden=32, per_type=False,
                                seed=0)
    agg.fit(series[:split], epochs=epochs)
    r_agg = rrmse(agg.predict_series(series)[split - 50:], series[split:])
    rows.append(f"predictor/lstm-aggregate,0,rrmse={100*r_agg:.2f}%")
    rows.append(f"predictor/ordering,0,"
                f"per_type<{'MA' if r < r_ma else 'FAIL'};"
                f"per_type<{'agg' if r < r_agg else 'FAIL'}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
