"""Replica-failure recovery stall: page handoff vs re-prefill-from-log.

Measures the wall-clock stall a replica death imposes on the requests it
was serving, for the two recovery paths of ``ClusterRuntime._fail``:

  * ``handoff``   the replica died but its device state is trusted (crash
                  at dispatch): survivors adopt the orphaned sequences'
                  live KV pages from the shared pool — zero tokens
                  recomputed, zero bytes moved;
  * ``reprefill`` the replica's device state is gone or untrusted
                  (``lose_pages``): survivors rebuild every request from
                  the cluster's host-side request log by re-prefilling
                  ``prompt + emitted`` — zero emitted tokens lost, but the
                  whole context goes through a prefill forward again.

Two numbers per mode, mirroring ``bench_switch``:

  * ``stall_ms`` — ``fail_replica`` until every orphaned sequence's state
    is resident on a survivor (for re-prefill: until it has re-emitted a
    token, since the restore IS the prefill);
  * ``next_token_ms`` — until every orphaned request has emitted its next
    token on the survivor.

Several rounds on one cluster (the dead replica is rebuilt between rounds
by re-applying the plan, so the survivor's jit caches stay warm); the
first round warms, the best of the rest is reported.  Emits the standard
CSV rows and writes ``BENCH_recovery.json`` at the repo root.
Acceptance: handoff recovery >= 5x lower stall than re-prefill on the
smoke config, and the zero-recompute path actually taken.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params

BENCH_JSON = (pathlib.Path(__file__).resolve().parents[1]
              / "BENCH_recovery.json")
BLOCK = 8
NEW_TOKENS = 16


class _Plan:
    def __init__(self, rcs, fractions):
        from repro.core.types import Deployment
        self.deployment = Deployment(tuple(rcs))
        self.fractions = fractions


def _measure_mode(cfg, params, mode: str, ctx_len: int, batch: int,
                  rounds: int = 4) -> dict:
    import jax.numpy as jnp

    from repro.core.types import ReplicaConfig
    from repro.serving.cluster import ClusterRuntime
    from repro.serving.router import FlowRouter

    # survivor must hold its own batch plus the victim's: size one chip's
    # quota/slots for 2*batch sequences of the full lifetime footprint
    blocks_per_seq = (ctx_len + NEW_TOKENS) // BLOCK + 2
    rt = ClusterRuntime(cfg, params, total_chips=2,
                        blocks_per_chip=2 * batch * blocks_per_seq,
                        seqs_per_chip=2 * batch, block_size=BLOCK,
                        drain_steps=0, router=FlowRouter([[0.5], [0.5]]),
                        dtype=jnp.float32)
    plan = _Plan([ReplicaConfig(1, 1), ReplicaConfig(1, 1)],
                 [[0.5], [0.5]])
    rt.apply_plan(plan)
    rng = np.random.RandomState(0)
    rid = 0
    stalls: list[float] = []
    next_toks: list[float] = []
    report = None
    n_victims = 0
    for _ in range(rounds):                   # round 1 warms every jit path
        victims = []
        for _ in range(2 * batch):
            prompt = rng.randint(0, cfg.vocab_size, ctx_len).astype(np.int32)
            k = rt.submit(rid, prompt, NEW_TOKENS)
            if k == 0:
                victims.append(rid)
            rid += 1
        assert victims, "flow router sent the victim replica no traffic"
        n_victims = len(victims)
        rt.step()                             # prefill (+ first token)
        rt.step()                             # one decode step in flight
        before = {r: len(rt.request_log[r].emitted) for r in victims}

        def advanced():
            return all(len(rt.request_log[r].emitted) > before[r]
                       or r in rt.results for r in victims)

        jax.block_until_ready(rt.pool.k)
        t0 = time.perf_counter()
        report = rt.fail_replica(0, lose_pages=(mode == "reprefill"))
        if mode == "reprefill":
            # the restore IS the re-prefill forward on the survivor
            while not advanced():
                rt.step()
            jax.block_until_ready(rt.pool.k)
            stall = next_tok = time.perf_counter() - t0
        else:
            # pages adopted in place: context is resident right here
            jax.block_until_ready(rt.pool.k)
            stall = time.perf_counter() - t0
            while not advanced():             # + the decode step it owes
                rt.step()
            jax.block_until_ready(rt.pool.k)
            next_tok = time.perf_counter() - t0
        assert report.dropped == 0, "survivor could not hold the victims"
        stalls.append(stall)
        next_toks.append(next_tok)
        rt.run_until_idle()                   # drain before the next round
        rt.apply_plan(plan)                   # rebuild the dead replica
    return {"mode": mode, "ctx_len": ctx_len, "batch": batch,
            "stall_ms": min(stalls[1:]) * 1e3,       # best post-warmup round
            "next_token_ms": min(next_toks[1:]) * 1e3,
            "recovered": n_victims,
            "handoff": report.handoff, "reprefilled": report.reprefilled,
            "pages_handoff": report.pages_handoff,
            "recompute_tokens": report.recompute_tokens}


def main(fast: bool = True) -> list[str]:
    # smoke model context ceiling is 512: stay under it incl. new tokens
    ctx_len = 448
    batch = 2 if fast else 4
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    results = []
    rows = []
    for mode in ("handoff", "reprefill"):
        r = _measure_mode(cfg, params, mode, ctx_len, batch)
        results.append(r)
        rows.append(f"recovery/{mode}/ctx{ctx_len}b{batch},"
                    f"{r['stall_ms'] * 1e3:.0f},"
                    f"stall_ms={r['stall_ms']:.2f}"
                    f";next_tok_ms={r['next_token_ms']:.2f}"
                    f";recompute={r['recompute_tokens']}")
    by = {r["mode"]: r for r in results}
    gain = by["reprefill"]["stall_ms"] / max(by["handoff"]["stall_ms"], 1e-9)
    # regression guards (CI runs this): the zero-recompute path must have
    # actually been taken, and it must hold its >= 5x stall advantage
    assert by["handoff"]["handoff"] == by["handoff"]["recovered"], \
        "handoff recovery path not taken"
    assert by["handoff"]["recompute_tokens"] == 0
    assert by["reprefill"]["reprefilled"] == by["reprefill"]["recovered"]
    assert by["reprefill"]["recompute_tokens"] > 0
    assert gain >= 5.0, f"handoff only {gain:.1f}x better than re-prefill"
    rows.append(f"recovery/gain/ctx{ctx_len}b{batch},0,"
                f"handoff_x={gain:.1f}")
    BENCH_JSON.write_text(json.dumps({
        "bench": "recovery_stall",
        "model": cfg.name,
        "backend": jax.default_backend(),
        "ctx_len": ctx_len,
        "batch": batch,
        "new_tokens": NEW_TOKENS,
        "results": results,
        "handoff_vs_reprefill_x": gain,
    }, indent=2) + "\n")
    return rows


if __name__ == "__main__":
    for row in main(fast=True):
        print(row)
