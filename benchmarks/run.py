"""Benchmark registry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the slow
variants (all models/cluster sizes); default keeps CI-friendly settings.

  bench_e2e        Fig 9-12   end-to-end latency/throughput vs baselines
  bench_switching  Fig 13     ad hoc switching vs naive reload
  bench_predictor  Fig 6/S5.3 per-type LSTM vs MA vs aggregate
  bench_scheduler  Fig 15     heuristic vs exhaustive search
  bench_ablation   Fig 14/AppD heterogeneous deployment + flow assignment
  bench_roofline   SRoofline  three-term roofline per (arch x shape)
  bench_engine     S4 engine  paged fused decode vs dense-gather decode
  bench_switch     S4.2 KV    switch stall: page handoff vs copy vs re-prefill
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = ["bench_predictor", "bench_scheduler", "bench_ablation",
           "bench_switching", "bench_e2e", "bench_roofline", "bench_engine",
           "bench_switch"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", choices=MODULES, default=None)
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = []
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            rows = mod.main(fast=not args.full)
            for row in rows:
                print(row, flush=True)
            print(f"{name}/_total,{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except Exception as e:  # keep the suite going
            failures.append((name, repr(e)))
            print(f"{name}/_total,{(time.time()-t0)*1e6:.0f},ERROR:{e!r}",
                  flush=True)
    if failures:
        print(f"# {len(failures)} benchmark module(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
