"""§Roofline: three-term roofline per (arch x shape) from the dry-run JSONs.

  compute    = HLO_FLOPs / (chips * peak)        [197 TFLOP/s bf16 / chip]
  memory     = HLO_bytes / (chips * hbm_bw)      [819 GB/s / chip]
  collective = collective_bytes / (chips * link) [~50 GB/s ICI / link]

cost_analysis / the HLO module are per-device after SPMD partitioning, so the
per-device quantities divide by one chip's rates directly.  MODEL_FLOPS uses
6*N_active*D (train) or 2*N_active*D (serve) per the assignment.
"""
from __future__ import annotations

import json
import os

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.launch.shapes import SHAPES, applicable

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    n_active = cfg.profile().active_param_count
    if sh.kind == "train":
        return 6.0 * n_active * sh.global_batch * sh.seq_len
    if sh.kind == "prefill":
        return 2.0 * n_active * sh.global_batch * sh.seq_len
    return 2.0 * n_active * sh.global_batch          # decode: 1 token/seq


def load_cell(dirpath: str, arch: str, shape: str, mesh: str = "single"
              ) -> dict | None:
    p = os.path.join(dirpath, f"{arch}_{shape}_{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def roofline_row(rec: dict) -> dict:
    chips = rec.get("n_devices", 256)
    t_c = rec["per_device_flops"] / PEAK
    t_m = rec["per_device_bytes"] / HBM
    coll = rec.get("collectives", {}).get("total_bytes", 0)
    t_x = coll / ICI
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = rec["per_device_flops"] * chips
    return dict(arch=rec["arch"], shape=rec["shape"],
                compute_s=t_c, memory_s=t_m, collective_s=t_x,
                bottleneck=dom,
                model_flops=mf, hlo_flops_global=hlo_global,
                useful_ratio=mf / hlo_global if hlo_global else 0.0,
                step_s=max(t_c, t_m, t_x))


def main(fast: bool = True, dirpath: str = "experiments/roofline"
         ) -> list[str]:
    rows = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = applicable(cfg, SHAPES[shape])
            if not ok:
                continue
            rec = load_cell(dirpath, arch, shape)
            if rec is None or not rec.get("ok"):
                rows.append(f"roofline/{arch}/{shape},0,pending")
                continue
            r = roofline_row(rec)
            rows.append(
                f"roofline/{arch}/{shape},{r['step_s']*1e6:.0f},"
                f"compute={r['compute_s']*1e3:.2f}ms"
                f";memory={r['memory_s']*1e3:.2f}ms"
                f";collective={r['collective_s']*1e3:.2f}ms"
                f";bound={r['bottleneck']}"
                f";useful={100*r['useful_ratio']:.0f}%")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
