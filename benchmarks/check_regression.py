"""Bench regression gate: freshly measured JSONs vs the committed baselines.

CI used to fail benchmarks only when they raised; this script turns the
numbers themselves into a gate.  The workflow stashes the committed
``BENCH_engine.json`` / ``BENCH_switch.json`` / ``BENCH_recovery.json`` /
``BENCH_prefix.json`` / ``BENCH_rebalance.json`` / ``BENCH_disagg.json``
before the bench steps overwrite them, then runs::

    python benchmarks/check_regression.py \
        --baseline-dir .bench-baseline --fresh-dir .

Two kinds of checks, because CI boxes are not the box that produced the
committed numbers:

  * **machine-independent ratios** (hard gates): paged decode must beat the
    dense-gather path by a wide margin, the H=8 horizon must keep its >= 2x
    over per-step decode, page handoff must stay >= 5x cheaper than
    re-prefill, the prefix cache must keep cutting prefill-forward tokens
    >= 5x on the shared-prefix trace, and the zero-recompute invariants
    (recompute_tokens, restore-path counts, cache hit/miss tallies) must
    match the baseline *exactly* — these ratios survive any change of
    hardware, so a violation is a real regression.
  * **absolute numbers vs baseline**, with a wide tolerance band
    (``--tolerance``, default: fresh throughput must reach 20% of baseline;
    ``--stall-tolerance``, default: fresh stalls must stay under 5x
    baseline).  The band absorbs machine variance while still catching
    order-of-magnitude cliffs (a path falling off its jitted fast path).

Exit code 1 lists every violated gate; 0 prints the compared metrics.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ENGINE_JSON = "BENCH_engine.json"
SWITCH_JSON = "BENCH_switch.json"
RECOVERY_JSON = "BENCH_recovery.json"
PREFIX_JSON = "BENCH_prefix.json"
REBALANCE_JSON = "BENCH_rebalance.json"
DISAGG_JSON = "BENCH_disagg.json"

# machine-independent ratio floors (hard gates)
PAGED_VS_DENSE_MIN = 10.0       # committed: ~80-250x on CPU smoke
HORIZON_H8_MIN = 2.0            # CI-asserted in bench_engine too
HANDOFF_VS_REPREFILL_MIN = 5.0  # CI-asserted in bench_switch too
RECOVERY_HANDOFF_MIN = 5.0      # CI-asserted in bench_recovery too
PREFIX_SAVINGS_MIN = 5.0        # CI-asserted in bench_prefix too
TELEMETRY_OVERHEAD_MAX = 1.5    # enabled-tracer decode vs NULL_TELEMETRY


def _load(d: pathlib.Path, name: str) -> dict:
    p = d / name
    if not p.exists():
        raise SystemExit(f"missing {p} — run the benchmark first")
    return json.loads(p.read_text())


def _index(rows: list[dict], *keys: str) -> dict[tuple, dict]:
    return {tuple(r[k] for k in keys): r for r in rows}


def check_engine(base: dict, fresh: dict, tol: float) -> list[str]:
    bad: list[str] = []
    b_rows = _index(base["results"], "mode", "batch")
    f_rows = _index(fresh["results"], "mode", "batch")
    for key, br in sorted(b_rows.items()):
        fr = f_rows.get(key)
        if fr is None:
            # sweep-scope difference (e.g. baseline from a non---fast run):
            # gate only the rows both runs produced
            print(f"engine/{key[0]}/b{key[1]}: not in fresh sweep, skipped")
            continue
        floor = tol * br["tokens_per_sec"]
        ok = fr["tokens_per_sec"] >= floor
        print(f"engine/{key[0]}/b{key[1]}: {fr['tokens_per_sec']:.1f} tok/s "
              f"(baseline {br['tokens_per_sec']:.1f}, floor {floor:.1f}) "
              f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            bad.append(f"engine {key}: {fr['tokens_per_sec']:.1f} tok/s "
                       f"< {tol:.2f}x baseline {br['tokens_per_sec']:.1f}")
    # paged vs dense: a machine-independent ratio within the fresh run
    for (mode, batch), fr in sorted(f_rows.items()):
        if mode != "paged" or ("dense", batch) not in f_rows:
            continue
        gain = fr["tokens_per_sec"] / max(
            f_rows[("dense", batch)]["tokens_per_sec"], 1e-9)
        print(f"engine/gain/b{batch}: paged {gain:.1f}x dense")
        if gain < PAGED_VS_DENSE_MIN:
            bad.append(f"engine b{batch}: paged only {gain:.1f}x dense "
                       f"(needs >= {PAGED_VS_DENSE_MIN}x)")

    bh = _index(base["horizon"]["results"], "horizon")
    fh = _index(fresh["horizon"]["results"], "horizon")
    for key, br in sorted(bh.items()):
        fr = fh.get(key)
        if fr is None:
            print(f"engine/horizon/h{key[0]}: not in fresh sweep, skipped")
            continue
        if fr["syncs"] != br["syncs"]:
            bad.append(f"horizon H={key[0]}: {fr['syncs']} device→host "
                       f"transfers, baseline {br['syncs']} (one per horizon)")
        floor = tol * br["tokens_per_sec"]
        if fr["tokens_per_sec"] < floor:
            bad.append(f"horizon H={key[0]}: {fr['tokens_per_sec']:.1f} "
                       f"tok/s < {tol:.2f}x baseline "
                       f"{br['tokens_per_sec']:.1f}")
    if (1,) in fh and (8,) in fh:
        gain = (fh[(8,)]["tokens_per_sec"]
                / max(fh[(1,)]["tokens_per_sec"], 1e-9))
        print(f"engine/horizon/gain_h8: {gain:.2f}x")
        if gain < HORIZON_H8_MIN:
            bad.append(f"horizon: H=8 only {gain:.2f}x per-step "
                       f"(needs >= {HORIZON_H8_MIN}x)")
    # tracer overhead: a machine-independent ratio within the fresh run
    # (baseline JSONs from before the telemetry layer lack the key)
    ft = fresh.get("telemetry")
    if ft is not None:
        print(f"engine/telemetry/overhead: {ft['overhead_x']:.3f}x "
              f"({ft['events']} events)")
        if ft["overhead_x"] > TELEMETRY_OVERHEAD_MAX:
            bad.append(f"telemetry: enabled tracer costs "
                       f"{ft['overhead_x']:.2f}x the no-op path "
                       f"(must stay <= {TELEMETRY_OVERHEAD_MAX}x)")
        if ft["events"] <= 0:
            bad.append("telemetry: enabled engine emitted no events — "
                       "instrumentation unwired")
    return bad


def check_switch(base: dict, fresh: dict, stall_tol: float) -> list[str]:
    bad: list[str] = []
    b_rows = _index(base["results"], "mode")
    f_rows = _index(fresh["results"], "mode")
    for key, br in sorted(b_rows.items()):
        fr = f_rows.get(key)
        if fr is None:
            bad.append(f"switch {key[0]}: restore path missing from fresh "
                       f"run")
            continue
        ceil = stall_tol * br["stall_ms"]
        ok = fr["stall_ms"] <= ceil
        print(f"switch/{key[0]}: stall {fr['stall_ms']:.2f}ms "
              f"(baseline {br['stall_ms']:.2f}, ceiling {ceil:.2f}) "
              f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            bad.append(f"switch {key[0]}: stall {fr['stall_ms']:.2f}ms "
                       f"> {stall_tol:.1f}x baseline {br['stall_ms']:.2f}ms")
        # restore-path structure is deterministic: must match exactly
        for field in ("handoff", "copied", "reprefilled", "pages_handoff",
                      "pages_copied", "recompute_tokens"):
            if fr.get(field) != br.get(field):
                bad.append(f"switch {key[0]}: {field} = {fr.get(field)} "
                           f"(baseline {br.get(field)}) — restore path "
                           f"changed")
    x = fresh.get("handoff_vs_reprefill_x", 0.0)
    print(f"switch/handoff_vs_reprefill: {x:.2f}x")
    if x < HANDOFF_VS_REPREFILL_MIN:
        bad.append(f"switch: handoff only {x:.2f}x cheaper than re-prefill "
                   f"(needs >= {HANDOFF_VS_REPREFILL_MIN}x)")
    return bad


def check_recovery(base: dict, fresh: dict, stall_tol: float) -> list[str]:
    bad: list[str] = []
    b_rows = _index(base["results"], "mode")
    f_rows = _index(fresh["results"], "mode")
    for key, br in sorted(b_rows.items()):
        fr = f_rows.get(key)
        if fr is None:
            bad.append(f"recovery {key[0]}: recovery path missing from "
                       f"fresh run")
            continue
        ceil = stall_tol * br["stall_ms"]
        ok = fr["stall_ms"] <= ceil
        print(f"recovery/{key[0]}: stall {fr['stall_ms']:.2f}ms "
              f"(baseline {br['stall_ms']:.2f}, ceiling {ceil:.2f}) "
              f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            bad.append(f"recovery {key[0]}: stall {fr['stall_ms']:.2f}ms "
                       f"> {stall_tol:.1f}x baseline {br['stall_ms']:.2f}ms")
        # recovery-path structure is deterministic: must match exactly
        for field in ("recovered", "handoff", "reprefilled",
                      "pages_handoff", "recompute_tokens"):
            if fr.get(field) != br.get(field):
                bad.append(f"recovery {key[0]}: {field} = {fr.get(field)} "
                           f"(baseline {br.get(field)}) — recovery path "
                           f"changed")
    x = fresh.get("handoff_vs_reprefill_x", 0.0)
    print(f"recovery/handoff_vs_reprefill: {x:.2f}x")
    if x < RECOVERY_HANDOFF_MIN:
        bad.append(f"recovery: handoff only {x:.2f}x cheaper than "
                   f"re-prefill (needs >= {RECOVERY_HANDOFF_MIN}x)")
    return bad


def check_prefix(base: dict, fresh: dict, tol: float,
                 stall_tol: float) -> list[str]:
    bad: list[str] = []
    b_rows = _index(base["results"], "mode")
    f_rows = _index(fresh["results"], "mode")
    for key, br in sorted(b_rows.items()):
        fr = f_rows.get(key)
        if fr is None:
            bad.append(f"prefix {key[0]}: mode missing from fresh run")
            continue
        # cache structure is deterministic (fixed trace, greedy decode):
        # prefill-forward token counts and hit/miss tallies match exactly
        for field in ("prefill_tokens", "n_requests", "hits", "misses",
                      "hit_tokens"):
            if fr.get(field) != br.get(field):
                bad.append(f"prefix {key[0]}: {field} = {fr.get(field)} "
                           f"(baseline {br.get(field)}) — cache attach "
                           f"path changed")
        ceil = stall_tol * br["mean_ttft_ms"]
        ok = fr["mean_ttft_ms"] <= ceil
        print(f"prefix/{key[0]}: ttft {fr['mean_ttft_ms']:.2f}ms "
              f"(baseline {br['mean_ttft_ms']:.2f}, ceiling {ceil:.2f}) "
              f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            bad.append(f"prefix {key[0]}: ttft {fr['mean_ttft_ms']:.2f}ms "
                       f"> {stall_tol:.1f}x baseline "
                       f"{br['mean_ttft_ms']:.2f}ms")
    # machine-independent ratios within the fresh run
    x = fresh.get("prefill_savings_x", 0.0)
    print(f"prefix/prefill_savings: {x:.1f}x")
    if x < PREFIX_SAVINGS_MIN:
        bad.append(f"prefix: cache only cut prefill tokens {x:.1f}x "
                   f"(needs >= {PREFIX_SAVINGS_MIN}x)")
    t = fresh.get("ttft_speedup_x", 0.0)
    print(f"prefix/ttft_speedup: {t:.2f}x")
    if t <= 1.0:
        bad.append(f"prefix: cache-on mean TTFT not under cache-off "
                   f"({t:.2f}x)")
    return bad


def check_rebalance(base: dict, fresh: dict) -> list[str]:
    """The rebalance bench runs on a virtual clock, so every number in it
    is deterministic and machine-independent: counts must match the
    committed baseline exactly, and the on-vs-off ordering gates hold
    within the fresh run alone."""
    bad: list[str] = []
    b_rows = _index(base["results"], "mode")
    f_rows = _index(fresh["results"], "mode")
    for key, br in sorted(b_rows.items()):
        fr = f_rows.get(key)
        if fr is None:
            bad.append(f"rebalance {key[0]}: mode missing from fresh run")
            continue
        print(f"rebalance/{key[0]}: shed {fr['total_shed']} "
              f"(baseline {br['total_shed']}), "
              f"ttft_p95 {fr['ttft_p95_ticks']:.2f} ticks "
              f"(baseline {br['ttft_p95_ticks']:.2f})")
        for field in ("total_shed", "completed", "rebalanced", "preempted",
                      "handoff", "requeued", "recompute_tokens"):
            if fr.get(field) != br.get(field):
                bad.append(f"rebalance {key[0]}: {field} = {fr.get(field)} "
                           f"(baseline {br.get(field)}) — virtual-time "
                           f"trace is deterministic, policy changed")
        for field in ("ttft_p95_ticks", "tpot_p95_ticks"):
            fv, bv = fr.get(field, 0.0), br.get(field, 0.0)
            if abs(fv - bv) > 0.05 * max(abs(bv), 1e-9):
                bad.append(f"rebalance {key[0]}: {field} = {fv:.3f} "
                           f"(baseline {bv:.3f})")
    off, on = f_rows.get(("off",)), f_rows.get(("on",))
    if off and on:
        print(f"rebalance/gain: shed {off['total_shed']} -> "
              f"{on['total_shed']}")
        if not on["total_shed"] < off["total_shed"]:
            bad.append(f"rebalance: on shed {on['total_shed']} >= off "
                       f"{off['total_shed']} — the rebalancer stopped "
                       f"paying for itself")
        if on["ttft_p95_ticks"] > off["ttft_p95_ticks"]:
            bad.append(f"rebalance: on TTFT p95 "
                       f"{on['ttft_p95_ticks']:.2f} > off "
                       f"{off['ttft_p95_ticks']:.2f} ticks")
        if on["handoff"] < 1:
            bad.append("rebalance: no drain rode the handoff path")
    return bad


def check_disagg(base: dict, fresh: dict) -> list[str]:
    """The disagg bench also runs on a virtual clock: handoff counts and
    the zero-recompute invariant must match the committed baseline
    exactly, and the disagg-vs-mixed TTFT ordering holds within the fresh
    run alone."""
    bad: list[str] = []
    b_rows = _index(base["results"], "mode")
    f_rows = _index(fresh["results"], "mode")
    for key, br in sorted(b_rows.items()):
        fr = f_rows.get(key)
        if fr is None:
            bad.append(f"disagg {key[0]}: mode missing from fresh run")
            continue
        print(f"disagg/{key[0]}: ttft_p95 {fr['ttft_p95_ticks']:.2f} ticks "
              f"(baseline {br['ttft_p95_ticks']:.2f}), "
              f"handoffs {fr['handoffs']} (baseline {br['handoffs']})")
        for field in ("completed", "shed", "handoffs", "handoff_path",
                      "recompute_tokens", "prefill_tokens",
                      "prompt_tokens"):
            if fr.get(field) != br.get(field):
                bad.append(f"disagg {key[0]}: {field} = {fr.get(field)} "
                           f"(baseline {br.get(field)}) — virtual-time "
                           f"trace is deterministic, handoff path changed")
        for field in ("ttft_p95_ticks", "tpot_p95_ticks"):
            fv, bv = fr.get(field, 0.0), br.get(field, 0.0)
            if abs(fv - bv) > 0.05 * max(abs(bv), 1e-9):
                bad.append(f"disagg {key[0]}: {field} = {fv:.3f} "
                           f"(baseline {bv:.3f})")
    mixed, disagg = f_rows.get(("mixed",)), f_rows.get(("disagg",))
    if mixed and disagg:
        print(f"disagg/gain: ttft_p95 {mixed['ttft_p95_ticks']:.2f} -> "
              f"{disagg['ttft_p95_ticks']:.2f} ticks")
        if not disagg["ttft_p95_ticks"] < mixed["ttft_p95_ticks"]:
            bad.append(f"disagg: TTFT p95 {disagg['ttft_p95_ticks']:.2f} "
                       f">= mixed {mixed['ttft_p95_ticks']:.2f} ticks — "
                       f"disaggregation stopped paying for itself")
        if disagg["recompute_tokens"] != 0:
            bad.append(f"disagg: handoffs recomputed "
                       f"{disagg['recompute_tokens']} prefill tokens "
                       f"(must be 0)")
        if disagg["handoffs"] < 1:
            bad.append("disagg: no context rode the handoff path")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", required=True, type=pathlib.Path,
                    help="directory holding the committed BENCH_*.json "
                         "(stash them before the bench steps overwrite)")
    ap.add_argument("--fresh-dir", default=".", type=pathlib.Path,
                    help="directory the benchmarks just wrote into")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="fresh throughput must reach this fraction of the "
                         "committed baseline (wide: CI boxes differ)")
    ap.add_argument("--stall-tolerance", type=float, default=5.0,
                    help="fresh switch stalls must stay under this multiple "
                         "of the committed baseline")
    args = ap.parse_args(argv)

    bad = check_engine(_load(args.baseline_dir, ENGINE_JSON),
                       _load(args.fresh_dir, ENGINE_JSON), args.tolerance)
    bad += check_switch(_load(args.baseline_dir, SWITCH_JSON),
                        _load(args.fresh_dir, SWITCH_JSON),
                        args.stall_tolerance)
    bad += check_recovery(_load(args.baseline_dir, RECOVERY_JSON),
                          _load(args.fresh_dir, RECOVERY_JSON),
                          args.stall_tolerance)
    bad += check_prefix(_load(args.baseline_dir, PREFIX_JSON),
                        _load(args.fresh_dir, PREFIX_JSON),
                        args.tolerance, args.stall_tolerance)
    bad += check_rebalance(_load(args.baseline_dir, REBALANCE_JSON),
                           _load(args.fresh_dir, REBALANCE_JSON))
    bad += check_disagg(_load(args.baseline_dir, DISAGG_JSON),
                        _load(args.fresh_dir, DISAGG_JSON))
    if bad:
        print("\nBENCH REGRESSIONS:", file=sys.stderr)
        for b in bad:
            print(f"  - {b}", file=sys.stderr)
        return 1
    print("\nall bench gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
