"""Paper Fig. 15: scheduling-algorithm efficiency and optimality.

Runtime of the flow-guided heuristic vs exhaustive search as the cluster
grows, and the throughput/latency gap between them (paper: heuristic 12s vs
exhaustive 50s at 16 GPUs, <6% P99 gap).
"""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.costmodel import CostModel
from repro.core.deployment import exhaustive_search, flow_guided_search
from repro.core.types import H100_SPEC, WorkloadType


def main(fast: bool = True) -> list[str]:
    cfg = get_config("opt-66b")
    cm = CostModel(cfg.profile(), hw=H100_SPEC)
    archetypes = [WorkloadType(1275, 287), WorkloadType(139, 133),
                  WorkloadType(1181, 1824), WorkloadType(282, 1121)]
    ws = [a.with_rate(2000.0) for a in archetypes]
    rows = []
    sizes = [8, 16, 24, 32] if not fast else [8, 16]
    for chips in sizes:
        t0 = time.time()
        fg = flow_guided_search(cm, chips, ws, max_tp=8, max_pp=4, seed=0)
        t_fg = time.time() - t0
        t0 = time.time()
        ex = exhaustive_search(cm, chips, ws, max_tp=8, max_pp=4)
        t_ex = time.time() - t0
        gap = 100 * (1 - fg.throughput / max(ex.throughput, 1e-9))
        rows.append(
            f"scheduler/{chips}gpus,{t_fg*1e6:.0f},"
            f"heuristic={t_fg:.2f}s;exhaustive={t_ex:.2f}s;"
            f"thr_gap={gap:.2f}%;evals={fg.evaluations};"
            f"dep={fg.deployment}")
    return rows


if __name__ == "__main__":
    for r in main(fast=False):
        print(r)
