"""Generate the data-driven sections of EXPERIMENTS.md from the dry-run /
roofline / hillclimb JSON artifacts.  Run after campaigns finish:

    PYTHONPATH=src python experiments/make_report.py > experiments/report.md
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.bench_roofline import roofline_row  # noqa: E402
from repro.configs import ASSIGNED_ARCHS, get_config              # noqa: E402
from repro.launch.shapes import SHAPES, applicable                 # noqa: E402


def load(path):
    with open(path) as f:
        return json.load(f)


def dryrun_table():
    print("### Dry-run matrix (lower + compile on the production meshes)\n")
    print("| arch | shape | single-pod (16x16) | multi-pod (2x16x16) | plan |")
    print("|---|---|---|---|---|")
    n_ok = n_cells = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = applicable(cfg, SHAPES[shape])
            if not ok:
                print(f"| {arch} | {shape} | skip | skip | "
                      f"long_500k needs sub-quadratic attention |")
                continue
            row = []
            plan = ""
            for mesh in ("single", "multi"):
                p = f"experiments/dryrun/{arch}_{shape}_{mesh}.json"
                if os.path.exists(p):
                    d = load(p)
                    n_cells += 1
                    if d.get("ok"):
                        n_ok += 1
                        mem = d.get("memory", {})
                        tot = (mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0))
                        row.append(f"OK {d.get('compile_s', '?')}s, "
                                   f"{tot/1e9:.1f}GB/dev")
                        plan = d.get("plan", "")
                    else:
                        row.append("FAIL")
                else:
                    row.append("pending")
            print(f"| {arch} | {shape} | {row[0]} | {row[1]} | {plan} |")
    print(f"\n**{n_ok}/{n_cells} mesh-cells compile OK.**\n")


def roofline_table():
    print("### Roofline (single-pod, unrolled HLO accounting)\n")
    print("| arch | shape | compute | memory | collective | bound |"
          " MODEL/HLO flops | next lever |")
    print("|---|---|---|---|---|---|---|---|")
    levers = {
        "collective": "cut the dominant collective (see §Perf)",
        "memory": "shard/cast the dominant HBM stream",
        "compute": "raise MXU utilization (larger tiles/fusion)",
    }
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = applicable(cfg, SHAPES[shape])
            if not ok:
                continue
            p = f"experiments/roofline/{arch}_{shape}_single.json"
            if not os.path.exists(p):
                print(f"| {arch} | {shape} | - | - | - | pending | - | - |")
                continue
            d = load(p)
            if not d.get("ok"):
                print(f"| {arch} | {shape} | - | - | - | FAIL | - | - |")
                continue
            r = roofline_row(d)
            print(f"| {arch} | {shape} "
                  f"| {r['compute_s']*1e3:.1f}ms "
                  f"| {r['memory_s']*1e3:.1f}ms "
                  f"| {r['collective_s']*1e3:.1f}ms "
                  f"| **{r['bottleneck']}** "
                  f"| {100*r['useful_ratio']:.0f}% "
                  f"| {levers[r['bottleneck']]} |")
    print()


def hillclimb_table():
    print("### Hillclimb variants (raw terms; narrative in §Perf)\n")
    print("| cell | variant | compute | memory | collective | step | ok |")
    print("|---|---|---|---|---|---|---|")
    for p in sorted(glob.glob("experiments/hillclimb/*.json")):
        d = load(p)
        name = os.path.basename(p)[:-5]
        cell, variant = name.split("__")
        if not d.get("ok"):
            print(f"| {cell} | {variant} | - | - | - | - |"
                  f" FAIL: {str(d.get('error'))[:60]} |")
            continue
        r = roofline_row(d)
        print(f"| {cell} | {variant} "
              f"| {r['compute_s']*1e3:.1f}ms | {r['memory_s']*1e3:.1f}ms "
              f"| {r['collective_s']*1e3:.1f}ms "
              f"| {r['step_s']*1e3:.1f}ms | OK |")
    print()


if __name__ == "__main__":
    dryrun_table()
    roofline_table()
    hillclimb_table()
