"""Perf hillclimb driver: named variants per cell, unrolled re-lower+compile,
terms recorded to experiments/hillclimb/<cell>__<variant>.json."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys

sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell  # noqa: E402

VARIANTS = {
    # rolled baselines for ratio comparisons
    ("olmoe-1b-7b@base", "train_4k"): {"baseline": dict()},
    ("qwen1.5-110b@base", "decode_32k"): {"baseline": dict()},
    ("gemma2-2b@base", "prefill_32k"): {"baseline": dict()},
    # A) olmoe train_4k: collective-bound (11.1s vs 6.3s memory)
    ("olmoe-1b-7b", "train_4k"): {
        "nosp_nofsdp": dict(fsdp=False, extra_rules={"act_seq": None}),
        "noremat": dict(remat=False),
        "nofsdp": dict(fsdp=False),
        "nosp": dict(extra_rules={"act_seq": None}),
        "expert_tp": dict(cfg_overrides={"expert_sharding": "tp"}),
        "nosp_noremat": dict(remat=False, extra_rules={"act_seq": None}),
        # round 2 (was a separate dict entry; merged — completed variants
        # are skipped via their recorded JSONs, so re-listing is free)
        "nosp_v2_nofsdp": dict(fsdp=False, extra_rules={"act_seq": None}),
    },
    # B) qwen decode_32k: collective-bound (4.0s vs 1.5s memory) from FSDP
    #    weight gathers; replicate the small batch + shard KV seq 2D instead
    ("qwen1.5-110b", "decode_32k"): {
        "repl_batch_kv2d": dict(extra_rules={
            "batch": None, "kv_seq": ("data", "model")}),
        "kv2d_only": dict(extra_rules={"kv_seq": ("data", "model")}),
        "nofsdp_kv2d": dict(fsdp=False, extra_rules={
            "batch": None, "kv_seq": ("data", "model")}),
        # fp8 KV cache halves KV bytes AND lets the weights fit without
        # FSDP row-sharding -> no per-step weight all-gathers at all
        "nofsdp_f8kv": dict(fsdp=False, cache_dtype="f8"),
        "f8kv": dict(cache_dtype="f8"),
    },
    # C) gemma2 prefill_32k: worst memory term (29.2s) from replicated attn
    ("gemma2-2b", "prefill_32k"): {
        "pad_heads": dict(cfg_overrides={"attn_sharding": "pad"}),
        "pad_heads_fsdp": dict(cfg_overrides={"attn_sharding": "pad"},
                               fsdp=True),
    },
}


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else None
    os.makedirs("experiments/hillclimb", exist_ok=True)
    for (arch, shape), variants in VARIANTS.items():
        for vname, kwargs in variants.items():
            tag = f"{arch}_{shape}__{vname}"
            if only and only not in tag:
                continue
            path = f"experiments/hillclimb/{tag}.json"
            if os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        print(f"EXISTS {tag}")
                        continue
            print(f"=== {tag} ===", flush=True)
            unroll = os.environ.get("HILLCLIMB_UNROLL", "0") == "1"
            import jax.numpy as jnp
            if kwargs.get("cache_dtype") == "f8":
                kwargs = dict(kwargs, cache_dtype=jnp.float8_e4m3fn)
            rec = run_cell(arch.split("@")[0], shape, multi_pod=False, out_dir=None,
                           verbose=False, unroll=unroll, **kwargs)
            rec["variant"] = vname
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("ok"):
                coll = rec["collectives"]["total_bytes"]
                print(f"  ok compile={rec.get('compile_s')}s "
                      f"flops/dev={rec['per_device_flops']:.3e} "
                      f"bytes/dev={rec['per_device_bytes']:.3e} "
                      f"coll/dev={coll/1e9:.2f}GB", flush=True)
            else:
                print(f"  FAIL {rec.get('error')}", flush=True)


if __name__ == "__main__":
    main()
